"""chatglm3-6b [dense] — 2-d (half) RoPE, GQA kv=2 (arXiv:2406.12793; hf).

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
ChatGLM's 2-d rope == rotary on half the head dims (rope_fraction=0.5).
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "chatglm3-6b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, head_dim=128,
    rope_theta=10_000.0, rope_fraction=0.5, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=112, vocab=263, head_dim=16, rope_fraction=0.5,
    dtype=jnp.float32, remat=False)
