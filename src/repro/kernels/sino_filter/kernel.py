"""Pallas TPU kernel: fused frequency-domain ramp-filter scale.

The FFT itself stays in XLA (fft is a first-class XLA op with a tuned
TPU implementation); what the kernel fuses is the complex
spectrum × real-filter scale for the whole frame block in one VMEM
pass, operating on the (re, im) planes jointly so the spectrum is read
once.  Complex arrays are carried as two real planes because Mosaic has
no complex register type.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_kernel(re_ref, im_ref, filt_ref, ore_ref, oim_ref):
    f = filt_ref[...]
    ore_ref[...] = re_ref[...] * f
    oim_ref[...] = im_ref[...] * f


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def scale_spectrum_pallas(re: jnp.ndarray, im: jnp.ndarray,
                          filt: jnp.ndarray, *, bf: int = 8,
                          interpret: bool = True):
    """re/im (F, NF) spectrum planes × filt (1, NF) -> scaled planes."""
    f, nf = re.shape
    bf = min(bf, f)
    while f % bf:
        bf //= 2
    bf = max(1, bf)
    grid = (f // bf,)
    return pl.pallas_call(
        _scale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bf, nf), lambda i: (i, 0)),
            pl.BlockSpec((bf, nf), lambda i: (i, 0)),
            pl.BlockSpec((1, nf), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bf, nf), lambda i: (i, 0)),
            pl.BlockSpec((bf, nf), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((f, nf), re.dtype),
                   jax.ShapeDtypeStruct((f, nf), im.dtype)],
        interpret=interpret,
    )(re, im, filt)
