"""Workflow DAGs (docs/workflows.md): jobs that depend on jobs, proven
by an adversarial DAG suite.

Queue layer: ``after=[...]`` fan-out/fan-in pop gating, failure /
cancel / eviction cascades with machine-readable ``cancel_reason``,
atomic ``submit_many`` admission, and exactly-once terminal hooks.

Envelope layer: every cyclic, dangling-ref, or malformed spec-v3
envelope is rejected with 400 at submit and NOTHING is enqueued;
property-tested over random DAG shapes (hypothesis).

Execution: random DAGs (≤12 nodes) always run in topological order
with downstream inputs resolved from upstream outputs — under BOTH the
in-process scheduler and the worker-pull broker.  A worker SIGKILLed
mid-downstream-node resumes without re-running its completed upstream
(one ``attempt`` span on the upstream, ≥2 on the victim node), final
volume bit-identical to the same stages submitted sequentially by hand.

Acceptance: the 3-stage recon -> downsample -> quantify workflow
submitted as ONE ``POST /workflows`` completes in broker mode with two
workers, per-node status via ``GET /workflows/{id}`` and a linked
workflow trace via ``GET /workflows/{id}/trace``.
"""
import os
import random
import signal
import time

import numpy as np
import pytest

import slow_plugins  # noqa: F401 — registers slow/failing test plugins
from repro.service import (JobQueue, PipelineClient, PipelineService,
                           PipelineWorker, ServiceError, WorkflowError,
                           WorkflowManager, from_spec, toposort)
from repro.service.job import JobState
from repro.service.worker import spawn_local_workers

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def _recon_spec(seed=0, n_det=16, n_angles=12, n_rows=2, fail=False):
    """A tiny root chain producing a ``recon`` volume; ``fail=True``
    injects a plugin that raises on the first frame."""
    plugins = [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": n_det, "n_angles": n_angles,
                    "n_rows": n_rows, "seed": seed},
         "out_datasets": ["tomo"]},
    ]
    if fail:
        plugins.append({"plugin": "failing_plugin",
                        "in_datasets": ["tomo"], "out_datasets": ["tomo"]})
    plugins += [
        {"plugin": "fbp_recon", "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["recon"]},
        {"plugin": "hdf5_saver", "in_datasets": ["recon"]},
    ]
    return {"version": 1, "plugins": plugins}


def _passthrough_spec(parent, dataset, delay=0.0):
    """A downstream chain re-saving its parent's output as ``vol`` —
    the minimal consumer of an upstream reference; ``delay`` > 0 slows
    it (per volume slice) so its worker can be killed mid-node."""
    plugins = [
        {"plugin": "upstream_loader",
         "params": {"data": {"from_job": parent, "dataset": dataset}},
         "out_datasets": ["vol"]},
    ]
    if delay:
        plugins.append({"plugin": "slow_volume_identity",
                        "params": {"delay": delay},
                        "in_datasets": ["vol"], "out_datasets": ["vol"]})
    plugins.append({"plugin": "hdf5_saver", "in_datasets": ["vol"]})
    return {"version": 1, "plugins": plugins}


def _downsample_spec(parent, dataset="recon", factor=2):
    return {"version": 1, "plugins": [
        {"plugin": "upstream_loader",
         "params": {"data": {"from_job": parent, "dataset": dataset}},
         "out_datasets": ["vol"]},
        {"plugin": "downsample", "params": {"factor": factor},
         "in_datasets": ["vol"], "out_datasets": ["small"]},
        {"plugin": "hdf5_saver", "in_datasets": ["small"]},
    ]}


def _quantify_spec(parent, dataset="small"):
    return {"version": 1, "plugins": [
        {"plugin": "upstream_loader",
         "params": {"data": {"from_job": parent, "dataset": dataset}},
         "out_datasets": ["vol"]},
        {"plugin": "quantify",
         "in_datasets": ["vol"], "out_datasets": ["stats"]},
        {"plugin": "hdf5_saver", "in_datasets": ["stats"]},
    ]}


def _pl(**kw):
    return from_spec(_recon_spec(**kw))


def _finish(q, job, state=JobState.DONE):
    """Drive a popped job terminal the way a scheduler would, then let
    the queue propagate through the dependency graph."""
    job.state = state
    job.finished_at = time.time()
    q.notify_terminal(job)


# ===================================================== queue-level DAG
def test_fan_out_fan_in_pop_gating():
    """a -> (b, c) -> d: only dependency-satisfied jobs are poppable;
    the fan-in node stays queued until EVERY upstream is DONE."""
    q = JobQueue()
    a = q.submit(_pl(), job_id="a")
    q.submit(_pl(), job_id="b", after=["a"])
    q.submit(_pl(), job_id="c", after=["a"])
    d = q.submit(_pl(), job_id="d", after=["b", "c"])
    assert q.get(timeout=0.1).job_id == "a"
    assert q.get(timeout=0.05) is None          # b, c, d all gated
    assert sorted(d.snapshot()["waiting_on"]) == ["b", "c"]
    _finish(q, a)                                # fan-out: b AND c wake
    got = {q.get(timeout=0.1).job_id, q.get(timeout=0.1).job_id}
    assert got == {"b", "c"}
    assert q.get(timeout=0.05) is None           # d still gated
    _finish(q, q.job("b"))
    assert q.get(timeout=0.05) is None           # fan-in: one of two
    assert d.snapshot()["waiting_on"] == ["c"]
    _finish(q, q.job("c"))
    assert q.get(timeout=0.1).job_id == "d"


def test_upstream_failure_cascades_with_reasons():
    """a FAILED cancels its whole downstream cone: the direct child
    carries ``upstream_failed``, the grandchild (whose own upstream was
    CANCELLED) carries ``upstream_cancelled`` — machine-readable in
    ``Job.snapshot()``."""
    q = JobQueue()
    a = q.submit(_pl(), job_id="a")
    b = q.submit(_pl(), job_id="b", after=["a"])
    c = q.submit(_pl(), job_id="c", after=["b"])
    assert q.get(timeout=0.1) is a
    _finish(q, a, JobState.FAILED)
    assert b.state is JobState.CANCELLED
    assert b.snapshot()["cancel_reason"] == "upstream_failed"
    assert "a" in b.snapshot()["error"]
    assert c.state is JobState.CANCELLED
    assert c.snapshot()["cancel_reason"] == "upstream_cancelled"


def test_user_cancel_cascades():
    """Cancelling a queued upstream cancels its downstream cone with
    the user/cascade reasons kept distinct."""
    q = JobQueue()
    a = q.submit(_pl(), job_id="a")
    b = q.submit(_pl(), job_id="b", after=["a"])
    assert q.cancel("a") is True
    assert a.snapshot()["cancel_reason"] == "user"
    assert b.state is JobState.CANCELLED
    assert b.snapshot()["cancel_reason"] == "upstream_cancelled"


def test_admission_against_terminal_upstream():
    """Submitting after an already-failed upstream admits the job, then
    cancels it by the same cascade rule; unknown/self upstreams are
    rejected outright."""
    q = JobQueue()
    a = q.submit(_pl(), job_id="a")
    assert q.get(timeout=0.1) is a
    _finish(q, a, JobState.FAILED)
    b = q.submit(_pl(), job_id="b", after=["a"])
    assert b.state is JobState.CANCELLED
    assert b.snapshot()["cancel_reason"] == "upstream_failed"
    # a DONE upstream satisfies immediately
    c = q.submit(_pl(), job_id="c")
    assert q.get(timeout=0.1) is c
    _finish(q, c)
    d = q.submit(_pl(), job_id="d", after=["c"])
    assert q.get(timeout=0.1) is d
    with pytest.raises(ValueError, match="unknown upstream"):
        q.submit(_pl(), job_id="e", after=["ghost"])
    with pytest.raises(ValueError, match="itself"):
        q.submit(_pl(), job_id="f", after=["f"])


def test_eviction_of_data_dep_cancels_downstream():
    """History eviction of a DONE upstream whose RESULT a queued
    downstream consumes cancels that downstream with
    ``upstream_evicted``."""
    q = JobQueue(max_history=1)
    up = q.submit(_pl(), job_id="up")
    assert q.get(timeout=0.1) is up
    _finish(q, up)
    down = q.submit(_pl(), job_id="down", data_deps=["up"])
    # fill history so the next submission prunes `up` out (fillers at
    # higher priority so they pop ahead of the satisfied `down`)
    f1 = q.submit(_pl(), job_id="f1", priority=1)
    assert q.get(timeout=0.1) is f1
    _finish(q, f1)
    q.submit(_pl(), job_id="f2")                 # triggers the prune
    with pytest.raises(KeyError):
        q.job("up")                              # evicted
    assert down.state is JobState.CANCELLED
    assert down.snapshot()["cancel_reason"] == "upstream_evicted"
    assert "evicted" in down.snapshot()["error"]


def test_terminal_hooks_fire_exactly_once_per_cascaded_job():
    """The queue's terminal hooks (metric attribution) fire exactly
    once per QUEUE-cancelled job and never for jobs whose terminal
    transition the scheduler/broker performed itself."""
    q = JobQueue()
    fired: dict[str, int] = {}
    q.add_terminal_hook(
        lambda j: fired.__setitem__(j.job_id, fired.get(j.job_id, 0) + 1))
    a = q.submit(_pl(), job_id="a")
    q.submit(_pl(), job_id="b", after=["a"])
    q.submit(_pl(), job_id="c", after=["b"])
    q.submit(_pl(), job_id="d", after=["b"])
    assert q.get(timeout=0.1) is a
    _finish(q, a, JobState.FAILED)               # scheduler-owned: no hook
    q.notify_terminal(a)                         # double notify is safe
    assert fired == {"b": 1, "c": 1, "d": 1}


def test_submit_many_is_atomic():
    """One bad dependency rejects the WHOLE group — nothing admitted."""
    q = JobQueue()
    with pytest.raises(ValueError, match="unknown upstream"):
        q.submit_many([_pl(), _pl()], job_ids=["x", "y"],
                      afters=[[], ["ghost"]])
    assert q.snapshot() == []
    # in-group forward references are fine regardless of order
    jobs = q.submit_many([_pl(), _pl()], job_ids=["y", "x"],
                         afters=[["x"], []])
    assert [j.job_id for j in jobs] == ["y", "x"]
    assert q.get(timeout=0.1).job_id == "x"


# ============================================== envelope validation
def test_toposort_orders_and_rejects_cycles():
    assert toposort({"a": [], "b": ["a"], "c": ["a", "b"]}) == \
        ["a", "b", "c"]
    with pytest.raises(WorkflowError, match="cycle"):
        toposort({"a": ["b"], "b": ["a"]})
    with pytest.raises(WorkflowError, match="cycle"):
        toposort({"a": ["a"]})


def test_http_rejects_bad_envelopes_atomically():
    """Cycle, dangling ref (explicit AND via an upstream-output
    reference), self-dep, bad node name, bad version — all 400 at
    ``POST /workflows``, and afterwards NOTHING is enqueued."""
    svc = PipelineService()                      # scheduler never started
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=30.0)
    r = _recon_spec()
    bad = [
        # dependency cycle via `after`
        {"version": 3, "workflow": {
            "a": {"process_list": r, "after": ["b"]},
            "b": {"process_list": r, "after": ["a"]}}},
        # dangling `after` reference
        {"version": 3, "workflow": {
            "a": {"process_list": r, "after": ["ghost"]}}},
        # dangling upstream-OUTPUT reference
        {"version": 3, "workflow": {
            "a": {"process_list": r},
            "b": {"process_list": _passthrough_spec("ghost", "recon")}}},
        # self-dependency
        {"version": 3, "workflow": {
            "a": {"process_list": r, "after": ["a"]}}},
        # invalid node name (job-id separator)
        {"version": 3, "workflow": {
            "bad/name": {"process_list": r}}},
        # wrong version
        {"version": 1, "workflow": {"a": {"process_list": r}}},
        # no nodes
        {"version": 3, "workflow": {}},
        # unparseable node spec
        {"version": 3, "workflow": {
            "a": {"process_list": {"version": 1, "plugins": [
                {"plugin": "no_such_plugin"}]}}}},
    ]
    try:
        for env in bad:
            with pytest.raises(ServiceError) as ei:
                client._request("POST", "/workflows", env)
            assert ei.value.status == 400, (env, ei.value)
        assert client.jobs() == []               # atomic: nothing admitted
        # duplicate ACTIVE workflow id -> 409 (and the dup's nodes are
        # not admitted either)
        ok = {"version": 3,
              "workflow": {"a": {"process_list": r}},
              "workflow_id": "wf-dup"}
        assert client._request("POST", "/workflows", ok)["n_nodes"] == 1
        with pytest.raises(ServiceError) as ei:
            client._request("POST", "/workflows", ok)
        assert ei.value.status == 409
        assert len(client.jobs()) == 1
        with pytest.raises(ServiceError) as ei:
            client.workflow_status("no-such-wf")
        assert ei.value.status == 404
    finally:
        svc.stop()


# ======================================== property: random DAG shapes
# Property tests run under hypothesis when it is installed; otherwise
# they fall back to a seeded deterministic generator so the adversarial
# DAG coverage runs everywhere (the container has no hypothesis and
# nothing may be pip-installed).
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_dag(rng, max_nodes=12):
    """A random DAG as ``{node: [upstream nodes]}`` — node i may only
    depend on earlier nodes, so the shape is acyclic by construction
    but covers chains, diamonds, fan-out and fan-in."""
    n = rng.randint(2, max_nodes)
    edges = {}
    for i in range(n):
        k = rng.randint(0, min(i, 3))
        ups = sorted(rng.sample(range(i), k)) if k else []
        edges[f"n{i}"] = [f"n{u}" for u in ups]
    return edges


if HAVE_HYPOTHESIS:
    @st.composite
    def _dags(draw, max_nodes=12):
        """Hypothesis wrapper over :func:`_random_dag`: the strategy
        draws sizes and parent sets directly so shrinking works."""
        n = draw(st.integers(min_value=2, max_value=max_nodes))
        edges = {}
        for i in range(n):
            ups = draw(st.lists(st.integers(0, i - 1), unique=True,
                                max_size=min(i, 3))) if i else []
            edges[f"n{i}"] = [f"n{u}" for u in sorted(ups)]
        return edges


def _property(max_examples, max_nodes):
    """Decorator: ``@given`` random DAGs under hypothesis, or a seeded
    ``parametrize`` sweep of the same shapes without it.  Either way
    the test function receives ``edges``."""
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(
                max_examples=max_examples, deadline=None,
                suppress_health_check=[HealthCheck.too_slow],
            )(given(edges=_dags(max_nodes=max_nodes))(fn))
        return deco

    def deco(fn):
        shapes = [_random_dag(random.Random(seed), max_nodes)
                  for seed in range(max_examples)]
        return pytest.mark.parametrize("edges", shapes)(fn)
    return deco


def _dag_envelope(edges, workflow_id):
    """Roots become tiny recon chains (seed = node index, so every
    root's volume is distinct); dependent nodes consume their FIRST
    parent's output and declare the rest via ``after``."""
    nodes, out_name = {}, {}
    for i, (name, ups) in enumerate(edges.items()):
        if not ups:
            nodes[name] = {"process_list": _recon_spec(seed=i)}
            out_name[name] = "recon"
        else:
            nodes[name] = {
                "process_list":
                    _passthrough_spec(ups[0], out_name[ups[0]]),
                "after": list(ups)}
            out_name[name] = "vol"
    return ({"version": 3, "workflow": nodes,
             "workflow_id": workflow_id}, out_name)


def _assert_topological(group):
    """Every node DONE, and no node started before every upstream had
    finished."""
    snap = group.snapshot()
    assert snap["state"] == "done", snap
    jobs = snap["node_jobs"]
    for node, ups in snap["edges"].items():
        for up in ups:
            assert jobs[up]["finished_at"] <= jobs[node]["started_at"], \
                (node, up, jobs[up], jobs[node])


def _assert_values_flow(svc, group, out_name):
    """Each dependent node's output is bit-identical to the upstream
    output it referenced."""
    snap = group.snapshot()
    for node, ups in snap["edges"].items():
        if not ups:
            continue
        parent = ups[0]
        got = np.asarray(_read(svc, group.workflow_id, node, "vol"))
        want = np.asarray(_read(svc, group.workflow_id, parent,
                                out_name[parent]))
        np.testing.assert_array_equal(got, want)


def _read(svc, workflow_id, node, dataset):
    ds, transport = svc.result_dataset(f"{workflow_id}/{node}", dataset)
    return transport.read(ds)


@_property(max_examples=6, max_nodes=12)
def test_random_dags_run_topologically_scheduler(edges):
    """Property (scheduler mode): ANY random DAG executes every node,
    in topological order, with downstream inputs bit-identical to the
    upstream outputs they reference."""
    svc = PipelineService(n_workers=2)
    env, out_name = _dag_envelope(edges, "wf-prop")
    try:
        group = svc.submit_workflow(env)
        svc.scheduler.start()
        deadline = time.time() + 120
        while not group.all_terminal():
            assert time.time() < deadline, group.snapshot()
            time.sleep(0.01)
        _assert_topological(group)
        _assert_values_flow(svc, group, out_name)
    finally:
        svc.stop()


@_property(max_examples=4, max_nodes=6)
def test_random_dags_run_topologically_broker(edges):
    """Property (broker mode): the same topological-order guarantee
    holds when dependency-aware leasing hands nodes to pull-based
    workers, with upstream outputs fetched over the wire."""
    svc = PipelineService(workers_remote=True, lease_ttl=10.0,
                          sweep_interval=0.2)
    host, port = svc.serve(port=0)
    env, out_name = _dag_envelope(edges, "wf-prop-b")
    try:
        group = svc.submit_workflow(env)
        w = PipelineWorker(f"http://{host}:{port}", worker_id="pw",
                           poll=0.01)
        w.register()
        deadline = time.time() + 120
        while not group.all_terminal():
            assert time.time() < deadline, group.snapshot()
            if not w.run_once():
                time.sleep(0.01)
        _assert_topological(group)
        # broker results are .npy spool files — compare over the store
        snap = group.snapshot()
        for node, ups in snap["edges"].items():
            if ups:
                got = svc.result_file(f"wf-prop-b/{node}", "vol")
                parent = ups[0]
                want = svc.result_file(f"wf-prop-b/{parent}",
                                       out_name[parent])
                np.testing.assert_array_equal(np.load(got[1]),
                                              np.load(want[1]))
    finally:
        svc.stop()


@_property(max_examples=20, max_nodes=8)
def test_random_broken_dags_rejected_atomically(edges):
    """Property: ANY random DAG corrupted with a back-edge (cycle) or a
    rewritten dangling upstream is rejected at validation and NOTHING
    is enqueued."""
    names = list(edges)
    # corruption 1: force a cycle — first and last node now depend on
    # each other (guaranteed loop whatever edges already exist)
    env, _ = _dag_envelope(edges, "wf-bad")
    env["workflow"][names[0]].setdefault("after", []).append(names[-1])
    env["workflow"][names[-1]].setdefault("after", []).append(names[0])
    q = JobQueue()
    with pytest.raises(WorkflowError):
        WorkflowManager(q).submit(env)
    assert q.snapshot() == []
    # corruption 2: a dangling upstream on every possible victim
    for victim in names:
        env, _ = _dag_envelope(edges, "wf-bad")
        env["workflow"][victim].setdefault("after", []).append("ghost")
        q = JobQueue()
        with pytest.raises(WorkflowError):
            WorkflowManager(q).submit(env)
        assert q.snapshot() == []


# ================================== fault injection: SIGKILL mid-DAG
def test_sigkill_mid_downstream_does_not_rerun_upstream(tmp_path):
    """SIGKILL the worker running a DOWNSTREAM node: the lease expires,
    the node requeues, and the resumed attempt consumes the upstream
    output already materialised in the result store — the upstream is
    NOT re-executed (exactly one ``attempt`` span on it, and its
    ``attempt`` counter stays 1) and the final volume is bit-identical
    to the same stages submitted sequentially by hand."""
    ckpt = str(tmp_path / "ckpts")
    svc = PipelineService(workers_remote=True, lease_ttl=1.5,
                          sweep_interval=0.1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(
        url, 2, transport="inmemory", checkpoint_dir=ckpt,
        poll=0.05, heartbeat=0.3, imports=("slow_plugins",),
        worker_ids=["w0", "w1"], pythonpath_extra=(TESTS_DIR,))
    by_id = dict(zip(["w0", "w1"], workers))
    try:
        reply = client.workflow({
            "up": {"process_list": _recon_spec(seed=11, n_rows=4)},
            "down": {"process_list":
                     _passthrough_spec("up", "recon", delay=0.4)},
        }, workflow_id="wf-kill")
        assert reply["nodes"] == ["up", "down"]
        # wait until the downstream node is running on a known worker
        deadline = time.time() + 120
        while True:
            snap = client.workflow_status("wf-kill")
            down = snap["node_jobs"]["down"]
            if down["state"] == "running" and down["worker_id"]:
                break
            assert down["state"] not in ("done", "failed"), snap
            assert time.time() < deadline, snap
            time.sleep(0.05)
        assert snap["node_jobs"]["up"]["state"] == "done"
        victim = down["worker_id"]
        time.sleep(0.5)                          # into the slow slices
        os.kill(by_id[victim].pid, signal.SIGKILL)

        snap = client.wait_workflow("wf-kill", timeout=120)
        assert snap["state"] == "done", snap
        up, down = snap["node_jobs"]["up"], snap["node_jobs"]["down"]
        assert down["attempt"] >= 2, down        # requeued after expiry
        assert down["worker_id"] != victim, down
        assert up["attempt"] == 1, up            # upstream NOT re-run
        # the spans agree: one attempt on `up`, >=2 on `down`, and the
        # resumed attempt re-fetched the materialised upstream output
        tr = client.workflow_trace("wf-kill")
        names_up = [s["name"] for s in tr["nodes"]["up"]["spans"]]
        names_down = [s["name"] for s in tr["nodes"]["down"]["spans"]]
        assert names_up.count("attempt") == 1
        # the SIGKILLed attempt's open spans die unshipped with the
        # worker; the resumed attempt restores from checkpoint instead
        # of starting over
        assert names_down.count("attempt") >= 1
        assert "checkpoint.restore" in names_down
        assert "upstream.fetch" in names_down
        # bit-identical to the sequential hand-submitted run
        wf_vol = client.result("wf-kill/down", "vol")
        jid = client.submit(_recon_spec(seed=11, n_rows=4),
                            job_id="seq-up")
        assert client.wait(jid, timeout=120)["state"] == "done"
        jid2 = client.submit(_passthrough_spec("seq-up", "recon"),
                             job_id="seq-down")
        assert client.wait(jid2, timeout=120)["state"] == "done"
        np.testing.assert_array_equal(wf_vol,
                                      client.result("seq-down", "vol"))
        assert client.stats()["leases_expired"] >= 1
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


# ========================================= failure-propagation matrix
def test_failure_propagation_matrix():
    """Upstream FAILED / CANCELLED / result-EVICTED each cancel the
    downstream with the right machine-readable ``cancel_reason``, and
    the ``jobs.cancelled`` counter attributes each cancelled job
    exactly once."""
    # --- upstream failed (executed in scheduler mode) ---------------
    svc = PipelineService()
    try:
        group = svc.submit_workflow({"version": 3, "workflow": {
            "up": {"process_list": _recon_spec(fail=True)},
            "down": {"process_list": _passthrough_spec("up", "recon")},
        }, "workflow_id": "wf-fail"})
        svc.scheduler.start()
        deadline = time.time() + 120
        while not group.all_terminal():
            assert time.time() < deadline, group.snapshot()
            time.sleep(0.01)
        snap = group.snapshot()
        assert snap["state"] == "failed", snap
        assert snap["node_jobs"]["up"]["state"] == "failed"
        down = snap["node_jobs"]["down"]
        assert down["state"] == "cancelled"
        assert down["cancel_reason"] == "upstream_failed"
        assert "up" in down["error"]
        # exactly-once attribution: ONE cancelled job -> counter == 1
        assert svc.metrics.counter("jobs.cancelled").value == 1
        assert svc.metrics.counter("jobs.failed").value == 1
    finally:
        svc.stop()

    # --- upstream cancelled (never dispatched) -----------------------
    svc = PipelineService()
    try:
        group = svc.submit_workflow({"version": 3, "workflow": {
            "up": {"process_list": _recon_spec()},
            "down": {"process_list": _passthrough_spec("up", "recon")},
        }, "workflow_id": "wf-cancel"})
        out = svc.cancel("wf-cancel/up")
        assert out["cancelled"] is True
        snap = group.snapshot()
        assert snap["node_jobs"]["up"]["cancel_reason"] == "user"
        down = snap["node_jobs"]["down"]
        assert down["state"] == "cancelled"
        assert down["cancel_reason"] == "upstream_cancelled"
        # both cancels attributed, each exactly once
        assert svc.metrics.counter("jobs.cancelled").value == 2
    finally:
        svc.stop()

    # --- upstream result evicted from history ------------------------
    svc = PipelineService(max_history=1)
    q = svc.queue
    try:
        up = q.submit(_pl(), job_id="up")
        assert q.get(timeout=0.1) is up
        _finish(q, up)
        down = q.submit(_pl(), job_id="down", data_deps=["up"])
        f1 = q.submit(_pl(), job_id="f1", priority=1)
        assert q.get(timeout=0.1) is f1
        _finish(q, f1)
        q.submit(_pl(), job_id="f2")             # prunes `up` out
        assert down.state is JobState.CANCELLED
        assert down.snapshot()["cancel_reason"] == "upstream_evicted"
        assert svc.metrics.counter("jobs.cancelled").value == 1
    finally:
        svc.stop()


# ============================== acceptance: 3-stage DAG, broker mode
def test_three_stage_workflow_broker_acceptance():
    """The PR acceptance path: recon -> downsample -> quantify as ONE
    ``POST /workflows`` in broker mode with two workers.  Downstream
    inputs resolve from upstream outputs over the wire, the final
    stats are bit-identical to the same stages submitted sequentially
    by hand, and ``GET /workflows/{id}`` + ``/trace`` report per-node
    status on one linked timeline."""
    svc = PipelineService(workers_remote=True, lease_ttl=10.0,
                          sweep_interval=0.2)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(url, 2, transport="inmemory",
                                  poll=0.05, worker_ids=["w0", "w1"])
    try:
        reply = client.workflow({
            "recon": {"process_list": _recon_spec(seed=3)},
            "downsample": {"process_list": _downsample_spec("recon")},
            "quantify": {"process_list": _quantify_spec("downsample"),
                         "after": ["downsample"]},
        }, workflow_id="wf-accept")
        assert reply["n_nodes"] == 3
        assert reply["nodes"] == ["recon", "downsample", "quantify"]
        snap = client.wait_workflow("wf-accept", timeout=120)
        assert snap["state"] == "done", snap
        assert snap["counts"] == {"done": 3}
        for node in ("recon", "downsample", "quantify"):
            assert snap["node_jobs"][node]["state"] == "done"
        # dependency edges reported (incl. the implied data edges)
        assert snap["edges"]["downsample"] == ["recon"]
        assert snap["edges"]["quantify"] == ["downsample"]
        # sequential-by-hand reference, stage outputs fed explicitly
        j1 = client.submit(_recon_spec(seed=3), job_id="s-recon")
        assert client.wait(j1, timeout=120)["state"] == "done"
        j2 = client.submit(_downsample_spec("s-recon"), job_id="s-down")
        assert client.wait(j2, timeout=120)["state"] == "done"
        j3 = client.submit(_quantify_spec("s-down"), job_id="s-quant")
        assert client.wait(j3, timeout=120)["state"] == "done"
        np.testing.assert_array_equal(
            client.result("wf-accept/quantify", "stats"),
            client.result("s-quant", "stats"))
        np.testing.assert_array_equal(
            client.result("wf-accept/downsample", "small"),
            client.result("s-down", "small"))
        # workflow-level trace links the three node timelines
        tr = client.workflow_trace("wf-accept")
        assert sorted(tr["nodes"]) == ["downsample", "quantify", "recon"]
        for node in ("downsample", "quantify"):
            names = [s["name"] for s in tr["nodes"][node]["spans"]]
            assert "upstream.fetch" in names, (node, names)
        # both workers participated or at least every node ran leased
        assert all(snap["node_jobs"][n]["worker_id"] in ("w0", "w1")
                   for n in snap["node_jobs"])
        assert "wf-accept" in [w["workflow_id"]
                               for w in client.workflows()]
        out = client.cancel_workflow("wf-accept")  # all done: all skipped
        assert out["cancelled"] == []
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()
