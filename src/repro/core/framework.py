"""The core framework — runs and controls the processing chain
(paper §III.D, Figs 5–7).

Phases:
  1. **check**  — the plugin-list check (delegated to ProcessList.check),
  2. **setup**  — loaders create lazy datasets; each processing plugin is
     "plugged in": its PluginData views are attached, its ``setup``
     describes the out_datasets, and the framework completes them by
     attaching backing storage via the transport (Fig 5),
  3. **main**   — per plugin: pre_process → frame loop (via transport) →
     post_process (MPI-barrier semantics = blocking jit), then the
     out_dataset *replaces* any in_dataset of the same name (Fig 6 (i)),
  4. **finalise** — savers persist surviving datasets; a NeXus-style JSON
     manifest links every intermediate file (paper §III.A).

Fusion (beyond paper): consecutive 1-in/1-out plugins that share a
driver are compiled as ONE jit on the sharded transport, so the
pattern-transition collective is scheduled by XLA inside a single
program instead of a host round-trip between plugins.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

import numpy as np

from .dataset import DataSet
from .plugin import BaseLoader, BasePlugin, BaseSaver, PluginData
from .process_list import ProcessList
from .profiler import Profiler
from .transport import (ChunkedFileTransport, InMemoryTransport,
                        ShardedTransport, Transport)


class PluginRunner:
    def __init__(self, process_list: ProcessList,
                 transport: Transport | None = None,
                 profiler: Profiler | None = None,
                 fuse: bool = False,
                 output_dir: str | None = None):
        self.process_list = process_list
        self.transport = transport or InMemoryTransport()
        self.profiler = profiler or Profiler()
        self.fuse = fuse and isinstance(self.transport, ShardedTransport)
        self.output_dir = output_dir
        #: name -> DataSet currently available for processing
        self.datasets: dict[str, DataSet] = {}
        #: every dataset ever produced (for the NeXus-style manifest)
        self.lineage: list[DataSet] = []
        self._prepared = False
        self._groups: list[list[BasePlugin]] = []
        self._step_i = 0
        self._in_step = False

    # ------------------------------------------------------------------
    def run(self) -> dict[str, DataSet]:
        self.prepare()
        while self.step():
            pass
        self.finalise()
        return self.datasets

    # -- resumable stepping interface (service layer) -------------------
    def prepare(self) -> "PluginRunner":
        """Check the process list and run the setup phase; after this the
        runner is a sequence of ``n_steps`` resumable plugin steps."""
        if self._prepared:
            return self
        self.process_list.check()
        self._loaders, self._processors, self._savers = self._split()
        self._setup_phase(self._loaders, self._processors, self._savers)
        self._groups = (self._fusion_groups(self._processors) if self.fuse
                        else [[p] for p in self._processors])
        self._compute_liveness()
        self._step_i = 0
        self._prepared = True
        return self

    @property
    def n_steps(self) -> int:
        return len(self._groups)

    @property
    def current_step(self) -> int:
        return self._step_i

    def step_labels(self) -> list[str]:
        return ["+".join(p.name for p in g) for g in self._groups]

    def result_names(self) -> list[str]:
        """Names of the datasets consumed by savers — the chain's
        outputs, in saver order.  These are what a service result
        endpoint should offer for download.  Requires :meth:`prepare`."""
        if not self._prepared:
            raise RuntimeError("result_names before prepare()")
        names: list[str] = []
        for sv in self._savers:
            for n in sv.in_dataset_names:
                if n not in names:
                    names.append(n)
        return names

    # -- dataset liveness ----------------------------------------------
    def _compute_liveness(self) -> None:
        """Per-dataset-object liveness over the step sequence: which step
        produces each dataset version and which step consumes it LAST.
        Savers count as consumers at the sentinel step ``n_steps`` (their
        datasets must survive the whole chain).  Donation and the
        checkpointer both read this instead of guessing."""
        producer: dict[int, int] = {}
        last_use: dict[int, int] = {}
        #: (consume_step, producer_step, dataset name) per use — producer
        #: is -1 for loader-created datasets
        uses: list[tuple[int, int, str]] = []
        for g, group in enumerate(self._groups):
            for p in group:
                for pd in p.in_data:
                    ds = pd.dataset
                    last_use[id(ds)] = g
                    uses.append((g, producer.get(id(ds), -1), ds.name))
                for pd in p.out_data:
                    producer[id(pd.dataset)] = g
        n = len(self._groups)
        for sv in self._savers:
            for name in sv.in_dataset_names:
                ds = self._final.get(name)
                if ds is not None:
                    last_use[id(ds)] = n
                    uses.append((n, producer.get(id(ds), -1), name))
        self._last_use = last_use
        self._uses = uses

    def required_live_names(self, step: int) -> set[str]:
        """Dataset names a resume from ``step`` completed steps must get
        back from a checkpoint: consumed at some step >= ``step`` (savers
        count as consuming at ``n_steps``) but produced BEFORE ``step`` —
        i.e. by a plugin that will not run again, or by a loader."""
        return {name for g, prod, name in self._uses
                if g >= step and prod < step}

    def begin_step(self) -> list[BasePlugin] | None:
        """Rebind the next group's in_data to the live dataset registry
        and run pre_process.  Returns the group, or None when exhausted.
        The caller must execute the group (via the transport) and then
        call :meth:`complete_step` — this split lets the service layer
        batch identical steps from several runners into one call."""
        if not self._prepared:
            self.prepare()
        if self._in_step:
            raise RuntimeError("begin_step called twice without "
                               "complete_step")
        if self._step_i >= len(self._groups):
            return None
        group = self._groups[self._step_i]
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        for p in group:
            for pd in p.in_data:
                if pd.dataset.name in self.datasets:
                    pd.dataset = self.datasets[pd.dataset.name]
                # donation hint: this step may consume the buffer only if
                # no later step (or saver) reads this dataset version
                lu = self._last_use.get(id(pd.dataset))
                pd.last_use = lu is not None and lu <= self._step_i
            with self.profiler.timer(p.name, "pre", devices):
                p.pre_process()
        self._in_step = True
        return group

    def complete_step(self) -> None:
        """Post-process + replacement semantics for the group started by
        :meth:`begin_step`, then advance the step cursor."""
        if not self._in_step:
            raise RuntimeError("complete_step without begin_step")
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        for p in self._groups[self._step_i]:
            with self.profiler.timer(p.name, "post", devices):
                p.post_process()
            self._replace(p)
        self._in_step = False
        self._step_i += 1

    def step(self) -> bool:
        """Run one plugin (or fused group).  Returns False when the chain
        is exhausted."""
        group = self.begin_step()
        if group is None:
            return False
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        if len(group) == 1:
            p = group[0]
            # cost analysis (when the transport offers it) runs BEFORE
            # the timer so its one-off AOT compile never pollutes the
            # process span it annotates
            cost = (self.transport.plugin_cost(p)
                    if hasattr(self.transport, "plugin_cost") else None)
            with self.profiler.timer(p.name, "process", devices,
                                     **(cost or {})):
                self.transport.run_plugin(p)
        else:
            label = "+".join(p.name for p in group)
            with self.profiler.timer(label, "process", devices, fused=True):
                self.transport.run_fused(group)
        self.complete_step()
        return True

    def skip_to(self, step: int,
                datasets: dict[str, Any] | None = None) -> None:
        """Resume support: mark the first ``step`` groups as already done
        (replaying their replacement semantics WITHOUT executing them) and
        restore the surviving datasets' contents from ``datasets``
        (name -> host array, e.g. loaded from a checkpoint)."""
        self.prepare()
        if self._step_i != 0:
            raise RuntimeError("skip_to on a runner that already stepped")
        if not 0 <= step <= len(self._groups):
            raise ValueError(f"step {step} outside 0..{len(self._groups)}")
        for group in self._groups[:step]:
            for p in group:
                self._replace(p)
        self._step_i = step
        for name, arr in (datasets or {}).items():
            if name not in self.datasets:
                continue
            ds = self.datasets[name]
            if hasattr(ds.backing, "write_all"):
                ds.backing.write_all(arr)
            else:
                ds.backing = arr

    def finalise(self) -> None:
        if self._step_i < len(self._groups):
            raise RuntimeError(
                f"finalise at step {self._step_i}/{len(self._groups)}")
        self._finalise(self._savers)

    # ------------------------------------------------------------------
    def _split(self):
        loaders, procs, savers = [], [], []
        for entry in self.process_list:
            plugin = entry.instantiate()
            if isinstance(plugin, BaseLoader):
                loaders.append(plugin)
            elif isinstance(plugin, BaseSaver):
                savers.append(plugin)
            else:
                procs.append(plugin)
        return loaders, procs, savers

    def _setup_phase(self, loaders, processors, savers):
        # Loaders first (lazy — they create dataset descriptions).
        for ld in loaders:
            with self.profiler.timer(ld.name, "setup"):
                for ds in ld.load():
                    if not ld.out_dataset_names:
                        ld.out_dataset_names = []
                    self.datasets[ds.name] = ds
                    self.lineage.append(ds)
        # Savers are plugged in directly after loaders (paper §III.F.2)
        # and retain their link until finalise.
        # Processing plugins: attach PluginData, call setup, register outs.
        self._planned: list[tuple[BasePlugin, list[DataSet]]] = []
        sym: dict[str, DataSet] = dict(self.datasets)
        for i, p in enumerate(processors):
            ins = [sym[n] for n in p.in_dataset_names]
            p.in_data = [PluginData(d) for d in ins]
            p.out_data = []          # filled after setup describes them
            with self.profiler.timer(p.name, "setup"):
                outs = p.setup(ins)
            if len(outs) != len(p.out_dataset_names):
                raise ValueError(
                    f"plugin {p.name}: setup returned {len(outs)} datasets, "
                    f"process list names {p.out_dataset_names}")
            for ds, name in zip(outs, p.out_dataset_names):
                ds.name = name
                ds.produced_by = f"p{i + 1}.{p.name}"
                p.out_data.append(PluginData(ds))
            # propagate pattern/frames choice made in setup to out views
            for pd in p.out_data:
                pd.pattern_name = (p.out_pattern_name or pd.pattern_name
                                   or p.in_data[0].pattern_name)
                pd.n_frames = p.in_data[0].n_frames
                if pd.pattern_name not in pd.dataset.patterns and \
                        pd.pattern_name in ins[0].patterns and \
                        pd.dataset.shape == ins[0].shape:
                    pd.dataset.patterns[pd.pattern_name] = \
                        ins[0].patterns[pd.pattern_name]
            # transport attaches backing (file/None) using now/next patterns
            nxt = processors[i + 1] if i + 1 < len(processors) else None
            for pd in p.out_data:
                now_pat = pd.dataset.patterns.get(pd.pattern_name)
                next_pat = None
                if nxt is not None and pd.dataset.name in nxt.in_dataset_names:
                    # the next plugin's requested pattern, if resolvable
                    cand = nxt.__class__.__dict__.get("pattern_name")
                    if cand and cand in pd.dataset.patterns:
                        next_pat = pd.dataset.patterns[cand]
                if now_pat is not None:
                    self.transport.allocate(pd.dataset, now_pat, next_pat)
                self.lineage.append(pd.dataset)
            self._planned.append((p, outs))
            for ds in outs:
                sym[ds.name] = ds
        #: final version of every dataset name (what savers will see)
        self._final = dict(sym)

    def _replace(self, p: BasePlugin):
        """out_dataset replaces in_dataset of the same name (Fig 6 (i))."""
        for pd in p.out_data:
            self.datasets[pd.dataset.name] = pd.dataset
        consumed = {pd.dataset.name for pd in p.in_data}
        produced = {pd.dataset.name for pd in p.out_data}
        # close in_datasets that were replaced (paper removes them)
        for name in consumed & produced:
            pass  # the registry overwrite above is the replacement

    def _fusion_groups(self, processors):
        """Group consecutive linear 1-in/1-out jax-traceable plugins."""
        groups: list[list[BasePlugin]] = []
        cur: list[BasePlugin] = []
        for p in processors:
            linear = (len(p.in_dataset_names) == 1
                      and len(p.out_dataset_names) == 1
                      and getattr(p, "fusable", True))
            chains = bool(cur) and \
                cur[-1].out_dataset_names[0] == p.in_dataset_names[0] and \
                cur[-1].driver == p.driver
            if linear and (not cur or chains):
                cur.append(p)
            else:
                if cur:
                    groups.append(cur)
                cur = [p] if linear else []
                if not linear:
                    groups.append([p])
        if cur:
            groups.append(cur)
        return groups

    # ------------------------------------------------------------------
    def _finalise(self, savers):
        for sv in savers:
            for name in sv.in_dataset_names:
                if name in self.datasets:
                    with self.profiler.timer(sv.name, "io"):
                        sv.save(self.datasets[name])
        if self.output_dir:
            os.makedirs(self.output_dir, exist_ok=True)
            manifest = {
                "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "datasets": [
                    {"name": d.name, "shape": list(d.shape),
                     "dtype": str(d.dtype), "axis_labels": list(d.axis_labels),
                     "produced_by": d.produced_by,
                     "patterns": sorted(d.patterns),
                     "file": getattr(getattr(d, "backing", None), "path", None)}
                    for d in self.lineage],
            }
            with open(os.path.join(self.output_dir, "savu_manifest.nxs.json"),
                      "w") as fh:
                json.dump(manifest, fh, indent=2)
        self.transport.close()


# convenience ----------------------------------------------------------
def run_process_list(process_list: ProcessList,
                     data: dict[str, Any] | None = None,
                     transport: Transport | None = None, **kw
                     ) -> dict[str, DataSet]:
    """One-shot helper used by examples/tests: ``data`` pre-populates
    loader-created datasets (name -> host array) before the chain steps,
    so a process list whose loader only *describes* a dataset can be fed
    inline arrays."""
    runner = PluginRunner(process_list, transport, **kw)
    runner.prepare()
    for name, arr in (data or {}).items():
        ds = runner.datasets.get(name)
        if ds is None or ds.produced_by:
            continue                      # only loader-created datasets
        if hasattr(ds.backing, "write_all"):
            ds.backing.write_all(np.asarray(arr))
        else:
            ds.backing = arr
    while runner.step():
        pass
    runner.finalise()
    return runner.datasets
