"""Transports — who moves the data (paper §III.A / §IV).

Three interchangeable backends, selected at runner construction:

* :class:`InMemoryTransport` — the paper's "serial on a PC" mode; numpy
  frame loop, no jit.  Reference semantics for every test.
* :class:`ShardedTransport` — the cluster mode, adapted to TPU: each
  plugin (or fused group of plugins) is compiled with ``jax.jit`` under a
  device mesh; patterns provide in/out ``NamedSharding``s; pattern
  transitions become XLA collectives instead of parallel-file round trips.
* :class:`ChunkedFileTransport` — the faithful out-of-core mode: every
  dataset is a chunk-addressed file (np.memmap standing in for parallel
  HDF5) with an LRU chunk cache of the paper's 1 MB default; chunk layout
  comes from the §IV.A optimiser.  Read/write statistics feed the
  chunking benchmark.
"""
from __future__ import annotations

import dataclasses
import math
import os
import shutil
import tempfile
import time
from collections import OrderedDict
from typing import Any, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..obs.trace import current_trace
from .chunking import DEFAULT_CACHE_BYTES, optimise_chunks
from .dataset import DataSet
from .patterns import Pattern
from .plugin import BasePlugin


def _as_list(x):
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


class Transport:
    """Interface: allocate out-dataset backing + run one plugin."""

    name = "base"

    def allocate(self, ds: DataSet, now: Pattern, next_: Pattern | None
                 ) -> None:
        raise NotImplementedError

    def run_plugin(self, plugin: BasePlugin) -> list[Any]:
        """Execute plugin.process_frames over all frames.  The plugin's
        PluginData views (in_data/out_data) define patterns + m."""
        raise NotImplementedError

    def read(self, ds: DataSet) -> np.ndarray:
        """Materialise a dataset to host numpy (tests / savers)."""
        out = ds.materialise()
        return np.asarray(out)

    def stats(self) -> dict[str, Any]:
        """Service-layer hook: transport-specific counters (IO traffic,
        compile-cache hits...).  Keys are transport-defined."""
        return {}

    def close(self) -> None:
        pass


class LocalCompileCache:
    """Minimal per-transport compiled-function cache.  The service layer
    substitutes a process-level, thread-safe
    :class:`repro.service.CompileCache` via the ``compile_cache``
    constructor argument so that many concurrent pipelines share one
    cache (same duck type: ``get_or_build`` + ``stats``)."""

    def __init__(self):
        self._entries: dict = {}
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key, builder, serializable=False):
        # ``serializable`` marks builders whose output could go to the
        # process-level cache's persistent tier; the local cache has no
        # such tier and ignores it
        try:
            fn = self._entries[key]
            self.hits += 1
            return fn
        except KeyError:
            self.misses += 1
            t0 = time.time()
            fn = self._entries[key] = builder()
            tr = current_trace()
            if tr is not None:
                # an actual build (not a hit) becomes a ``compile`` span
                # on whichever job is executing on this thread
                tr.record("compile", t0, time.time(),
                          attrs={"kind": key[0] if isinstance(key, tuple)
                                 and key else "plugin"})
            return fn

    def stats(self) -> dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}


# ======================================================================
class InMemoryTransport(Transport):
    """Serial PC mode — numpy loop over frames, reference semantics."""

    name = "inmemory"

    def allocate(self, ds: DataSet, now, next_) -> None:
        ds.backing = np.zeros(ds.shape, dtype=ds.dtype)

    def run_plugin(self, plugin: BasePlugin) -> list[Any]:
        ins = [pd.dataset.materialise() for pd in plugin.in_data]
        in_pats = [pd.pattern for pd in plugin.in_data]
        out_pats = [pd.pattern for pd in plugin.out_data]
        m = plugin.in_data[0].n_frames if plugin.in_data else 1

        in_frames = [np.asarray(p.to_frames(a))
                     for p, a in zip(in_pats, ins)]
        nf = in_frames[0].shape[0]
        out_accum: list[list[np.ndarray]] = [[] for _ in plugin.out_data]
        for start in range(0, nf, m):
            blocks = [f[start:start + m] for f in in_frames]
            res = _as_list(plugin.process_frames(blocks))
            for i, r in enumerate(res):
                out_accum[i].append(np.asarray(r))
        outs = []
        for pd, pieces, pat in zip(plugin.out_data, out_accum, out_pats):
            flat = np.concatenate(pieces, axis=0)
            outs.append(np.asarray(pat.from_frames(flat, pd.dataset.shape)))
        for pd, o in zip(plugin.out_data, outs):
            pd.dataset.backing = o.astype(pd.dataset.dtype, copy=False)
        return outs


# ======================================================================
class ShardedTransport(Transport):
    """Mesh mode — one jit per plugin (or fused group), shardings from
    patterns.  This is Savu's MPI layer re-expressed as SPMD compilation:
    the slice dims shard over the driver's data axis, and a pattern
    change between consecutive plugins lowers to an all-to-all instead of
    an HDF5 round-trip."""

    name = "sharded"

    def __init__(self, mesh: Mesh, donate: bool = True,
                 compile_cache=None, cost_analysis: bool = False):
        self.mesh = mesh
        self.donate = donate
        self.compile_cache = (compile_cache if compile_cache is not None
                              else LocalCompileCache())
        #: when True, :meth:`plugin_cost` AOT-lowers each distinct
        #: plugin step once and serves its HLO cost analysis (FLOPs /
        #: bytes accessed) — off by default: the extra compile is not
        #: free and only observability consumers want it
        self.cost_analysis = cost_analysis
        self._costs: dict = {}

    def allocate(self, ds: DataSet, now: Pattern, next_: Pattern | None
                 ) -> None:
        # jit outputs allocate themselves; nothing to do (lazy, like the
        # paper's loaders).
        ds.backing = None

    def _sharding(self, pat: Pattern, data_axis: str | None) -> NamedSharding:
        axes = set(self.mesh.axis_names)
        da = data_axis if data_axis in axes else None
        spec = [None] * pat.ndim
        if pat.slice_dims and da:
            spec[pat.slice_dims[0]] = da
        for d, ax in pat.shard_axes.items():
            if ax in axes:
                spec[d] = ax
        return NamedSharding(self.mesh, PartitionSpec(*spec))

    def device_put(self, ds: DataSet, pattern_name: str | None = None,
                   data_axis: str = "data"):
        """Place a host dataset onto the mesh with its pattern sharding."""
        pat = (ds.get_pattern(pattern_name) if pattern_name
               else next(iter(ds.patterns.values())))
        arr = ds.materialise()
        ds.backing = jax.device_put(np.asarray(arr),
                                    self._sharding(pat, data_axis))
        return ds.backing

    def _plugin_fn(self, plugin: BasePlugin):
        """Traceable (consts, *arrays) -> outs.  ``consts`` is the
        plugin's :meth:`jit_constants` dict passed as jit ARGUMENTS (not
        trace-time closure constants), so a compiled function can be
        replayed for a different plugin instance — same chain, new
        dataset — without retracing."""
        in_pats = [pd.pattern for pd in plugin.in_data]
        out_pats = [pd.pattern for pd in plugin.out_data]
        out_shapes = [pd.dataset.shape for pd in plugin.out_data]
        out_dtypes = [pd.dataset.dtype for pd in plugin.out_data]
        m = plugin.in_data[0].n_frames if plugin.in_data else 1
        const_keys = tuple(sorted(plugin.jit_constants()))

        def fn(consts, *arrays):
            saved = {k: getattr(plugin, k) for k in const_keys}
            for k in const_keys:
                setattr(plugin, k, consts[k])
            try:
                frames = [p.to_frames(a) for p, a in zip(in_pats, arrays)]
                nf = frames[0].shape[0]
                if m == 1:
                    res = jax.vmap(
                        lambda *fs: _as_list(
                            plugin.process_frames([f[None] for f in fs])),
                    )(*frames)
                    res = [r.reshape((nf,) + r.shape[2:]) for r in res]
                else:
                    if nf % m:
                        raise ValueError(
                            f"sharded transport requires n_frames({m}) | "
                            f"total frames({nf}) for plugin {plugin.name}")
                    grouped = [f.reshape((nf // m, m) + f.shape[1:])
                               for f in frames]
                    res = jax.vmap(
                        lambda *fs: _as_list(plugin.process_frames(list(fs))),
                    )(*grouped)
                    res = [r.reshape((nf,) + r.shape[2:]) for r in res]
                outs = []
                for r, pat, shp, dt in zip(res, out_pats, out_shapes,
                                           out_dtypes):
                    outs.append(pat.from_frames(r, shp).astype(dt))
                return tuple(outs)
            finally:
                for k, v in saved.items():
                    setattr(plugin, k, v)

        return fn

    def _donate_mask(self, plugin: BasePlugin) -> tuple[bool, ...]:
        """Per-input donation decision: donate only at the dataset's
        FINAL use (``PluginData.last_use``, set by the runner's liveness
        analysis; defaults True for direct transport use).  Donating
        earlier deletes a buffer a later plugin in a branching chain —
        or the checkpointer — still needs."""
        return tuple(self.donate and pd.last_use for pd in plugin.in_data)

    # -- compile-cache keys --------------------------------------------
    def _mesh_key(self) -> tuple:
        return (tuple(self.mesh.axis_names), tuple(self.mesh.devices.shape),
                tuple(int(d.id) for d in self.mesh.devices.flat))

    def _plugin_key(self, plugin: BasePlugin,
                    consts: dict | None = None) -> tuple:
        """Cache key: (plugin static identity, in/out dataset specs,
        consts structure, driver, mesh, donation).  Everything that
        selects a DIFFERENT compiled program must appear here."""
        def pd_meta(pd):
            return (pd.dataset.shape, str(np.dtype(pd.dataset.dtype)),
                    pd.pattern_name, pd.n_frames)
        if consts is None:
            consts = plugin.jit_constants()
        cmeta = tuple(
            (k, tuple(np.shape(v)), str(np.result_type(v)))
            for k, v in sorted(consts.items()))
        return ("plugin", plugin.cache_signature(),
                tuple(pd_meta(pd) for pd in plugin.in_data),
                tuple(pd_meta(pd) for pd in plugin.out_data),
                cmeta, plugin.driver.axes,
                tuple(sorted(plugin.driver.submesh.items())),
                self._mesh_key(), self._donate_mask(plugin))

    def _replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())

    def compile_plugin(self, plugin: BasePlugin, lower_only: bool = False,
                       consts: dict | None = None):
        """Compile one plugin step.  With ``consts`` given, compiles
        **ahead-of-time** (``jit(...).lower(...).compile()``) — the
        resulting executable is callable exactly like the jit wrapper
        AND serializable via ``jax.experimental.serialize_executable``
        for the persistent cache tier.  Consts are lowered as concrete
        values (not ShapeDtypeStructs) so python-float constants keep
        their weak types and call-time avals match."""
        da = plugin.driver.data_axis
        in_sh = tuple(self._sharding(pd.pattern, da) for pd in plugin.in_data)
        out_sh = tuple(self._sharding(pd.pattern, da)
                       for pd in plugin.out_data)
        fn = self._plugin_fn(plugin)
        mask = self._donate_mask(plugin)
        if lower_only:
            lconsts = plugin.jit_constants()
            jfn = jax.jit(lambda *arrays: fn(lconsts, *arrays),
                          in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=tuple(
                              i for i, m in enumerate(mask) if m))
            specs = [jax.ShapeDtypeStruct(pd.dataset.shape,
                                          pd.dataset.dtype, sharding=s)
                     for pd, s in zip(plugin.in_data, in_sh)]
            return jfn.lower(*specs)
        jfn = jax.jit(fn, in_shardings=(self._replicated(), *in_sh),
                      out_shardings=out_sh,
                      donate_argnums=tuple(
                          i + 1 for i, m in enumerate(mask) if m))
        if consts is None:
            return jfn
        specs = [jax.ShapeDtypeStruct(pd.dataset.shape, pd.dataset.dtype,
                                      sharding=s)
                 for pd, s in zip(plugin.in_data, in_sh)]
        return jfn.lower(consts, *specs).compile()

    def _device_in(self, plugin: BasePlugin) -> list[Any]:
        da = plugin.driver.data_axis
        arrays = []
        for pd in plugin.in_data:
            a = pd.dataset.materialise()
            if not isinstance(a, jax.Array):
                a = np.asarray(a)
            # unconditional: AOT-compiled executables (persistent cache
            # tier) are stricter than jit about input placement, so even
            # jax.Arrays are re-committed to the pattern sharding (a
            # no-op when already there)
            arrays.append(jax.device_put(a, self._sharding(pd.pattern, da)))
        return arrays

    def run_plugin(self, plugin: BasePlugin) -> list[Any]:
        arrays = self._device_in(plugin)
        consts = plugin.jit_constants()
        with self.mesh:
            jfn = self.compile_cache.get_or_build(
                self._plugin_key(plugin, consts),
                lambda: self.compile_plugin(plugin, consts=consts),
                serializable=True)
            outs = list(jfn(consts, *arrays))
        for pd, o in zip(plugin.out_data, outs):
            pd.dataset.backing = o
        return outs

    # -- fusion (beyond-paper): compile a run of plugins as ONE jit ----
    def run_fused(self, plugins: Sequence[BasePlugin]) -> list[Any]:
        """Fuse consecutive plugins into one compilation so XLA overlaps
        the pattern-transition collectives with compute.  Requires the
        chain to be linear (each plugin consumes the previous output)."""
        first, last = plugins[0], plugins[-1]
        in_sh = tuple(self._sharding(pd.pattern, first.driver.data_axis)
                      for pd in first.in_data)
        out_sh = tuple(self._sharding(pd.pattern, last.driver.data_axis)
                       for pd in last.out_data)

        def builder():
            fns = [self._plugin_fn(p) for p in plugins]
            mid_sh = [tuple(self._sharding(pd.pattern, p.driver.data_axis)
                            for pd in p.out_data) for p in plugins]

            def chain(all_consts, *arrays):
                cur = arrays
                for f, consts, shs in zip(fns, all_consts, mid_sh):
                    cur = f(consts, *cur)
                    cur = tuple(jax.lax.with_sharding_constraint(c, s)
                                for c, s in zip(cur, shs))
                return cur

            return jax.jit(chain,
                           in_shardings=(self._replicated(), *in_sh),
                           out_shardings=out_sh)

        arrays = self._device_in(first)
        key = ("fused", tuple(self._plugin_key(p) for p in plugins))
        with self.mesh:
            jfn = self.compile_cache.get_or_build(key, builder)
            outs = list(jfn(tuple(p.jit_constants() for p in plugins),
                            *arrays))
        for pd, o in zip(last.out_data, outs):
            pd.dataset.backing = o
        return outs

    # -- gang execution (service layer): N jobs, ONE compiled call -----
    def run_plugin_batch(self, plugins: Sequence[BasePlugin]) -> None:
        """Execute the SAME plugin step from several concurrent jobs as a
        single compiled call: inputs are stacked along a new leading job
        axis and the plugin function is vmapped over it — setup-derived
        constants (dark/flat fields, filter banks...) ride along as
        stacked arguments, so jobs with different calibration data still
        share the one program.  All plugins must agree on
        :meth:`_plugin_key` (identical chain step + shapes)."""
        p0 = plugins[0]
        k0 = self._plugin_key(p0)
        for p in plugins[1:]:
            if self._plugin_key(p) != k0:
                raise ValueError(
                    f"run_plugin_batch: plugin {p.name} does not match "
                    f"the batch signature of {p0.name}")
        n = len(plugins)
        da = p0.driver.data_axis

        def batched(sh: NamedSharding) -> NamedSharding:
            return NamedSharding(self.mesh, PartitionSpec(None, *sh.spec))

        in_sh = tuple(batched(self._sharding(pd.pattern, da))
                      for pd in p0.in_data)
        out_sh = tuple(batched(self._sharding(pd.pattern, da))
                       for pd in p0.out_data)

        def builder():
            fn = self._plugin_fn(p0)
            return jax.jit(
                lambda consts, *arrays: jax.vmap(fn)(consts, *arrays),
                in_shardings=(self._replicated(), *in_sh),
                out_shardings=out_sh)

        arrays = []
        for i in range(len(p0.in_data)):
            ins = [p.in_data[i].dataset.materialise() for p in plugins]
            if all(isinstance(a, jax.Array) for a in ins):
                stack = jnp.stack(ins)          # stays on device
            else:
                stack = np.stack([np.asarray(a) for a in ins])
            arrays.append(jax.device_put(stack, in_sh[i]))
        consts = [p.jit_constants() for p in plugins]
        stacked_consts = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *consts)
        with self.mesh:
            jfn = self.compile_cache.get_or_build(("batch", n, k0), builder)
            outs = list(jfn(stacked_consts, *arrays))
        for j, p in enumerate(plugins):
            for pd, o in zip(p.out_data, outs):
                pd.dataset.backing = o[j]

    def plugin_cost(self, plugin: BasePlugin) -> dict[str, float] | None:
        """HLO cost + memory analysis for one plugin step, from the
        AOT-compiled program: ``flops`` / ``bytes`` (legacy alias) /
        ``bytes_accessed`` from ``cost_analysis()``, plus
        ``peak_memory`` / ``temp_bytes`` / ``argument_bytes`` from
        ``memory_analysis()`` when the jax build exposes it.  None when
        disabled or neither analysis is available.  Cached per plugin
        key — the extra lower+compile happens once per distinct step;
        the profiler attaches the numbers to ``process`` spans so
        traces and ``/metrics`` can report per-plugin device profiles."""
        if not self.cost_analysis:
            return None
        key = ("cost", self._plugin_key(plugin))
        if key in self._costs:
            return self._costs[key]
        cost: dict[str, float] | None
        try:
            with self.mesh:
                compiled = self.compile_plugin(
                    plugin, lower_only=True).compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):    # older jax: per-device
                ca = ca[0] if ca else {}
            bytes_accessed = float(ca.get("bytes accessed", 0.0))
            cost = {"flops": float(ca.get("flops", 0.0)),
                    "bytes": bytes_accessed,          # legacy alias
                    "bytes_accessed": bytes_accessed}
            try:
                ma = compiled.memory_analysis()
                cost["peak_memory"] = float(
                    getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0))
                cost["temp_bytes"] = float(
                    getattr(ma, "temp_size_in_bytes", 0))
                cost["argument_bytes"] = float(
                    getattr(ma, "argument_size_in_bytes", 0))
            except Exception:        # noqa: BLE001 — telemetry only
                pass                 # cost_analysis alone still useful
        except Exception:            # noqa: BLE001 — telemetry only
            cost = None
        self._costs[key] = cost
        return cost

    def stats(self) -> dict[str, Any]:
        return {"compile_cache": self.compile_cache.stats()}


# ======================================================================
@dataclasses.dataclass
class IOStats:
    chunk_reads: int = 0          # cache-missing chunk fetches
    chunk_writes: int = 0
    cache_hits: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    wall: float = 0.0

    def merge(self, o: "IOStats") -> "IOStats":
        return IOStats(self.chunk_reads + o.chunk_reads,
                       self.chunk_writes + o.chunk_writes,
                       self.cache_hits + o.cache_hits,
                       self.bytes_read + o.bytes_read,
                       self.bytes_written + o.bytes_written,
                       self.wall + o.wall)


class ChunkedFile:
    """A chunk-addressed on-disk array: np.memmap standing in for a
    parallel-HDF5 dataset.  Chunks are stored contiguously in row-major
    chunk-grid order; an LRU cache of ``cache_bytes`` emulates the HDF5
    raw-chunk cache, and all traffic is counted in :class:`IOStats`."""

    def __init__(self, path: str, shape: Sequence[int], dtype,
                 chunks: Sequence[int],
                 cache_bytes: int = DEFAULT_CACHE_BYTES,
                 mode: str = "w+"):
        self.path = path
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.chunks = tuple(int(min(c, s))
                            for c, s in zip(chunks, self.shape))
        self.grid = tuple(-(-s // c) for s, c in zip(self.shape, self.chunks))
        self.chunk_items = int(np.prod(self.chunks))
        self.chunk_nbytes = self.chunk_items * self.dtype.itemsize
        self._n_items = int(np.prod(self.grid)) * self.chunk_items
        self._readonly = mode == "r"
        self._mm = np.memmap(path, dtype=self.dtype, mode=mode,
                             shape=(self._n_items,))
        self.stats = IOStats()
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self._cache_slots = max(1, cache_bytes // max(1, self.chunk_nbytes))
        #: flat chunk ids whose contents changed since last mark_clean()
        #: — the incremental-checkpoint increment
        self.dirty: set[int] = set()

    def mark_clean(self) -> None:
        """Reset dirty-chunk tracking (after a checkpoint captured the
        current contents)."""
        self.dirty = set()

    # -- chunk addressing ------------------------------------------------
    def _flat(self, cidx: tuple[int, ...]) -> int:
        f = 0
        for i, g in zip(cidx, self.grid):
            f = f * g + i
        return f

    def _get_chunk(self, cidx: tuple[int, ...]) -> np.ndarray:
        f = self._flat(cidx)
        if f in self._cache:
            self.stats.cache_hits += 1
            self._cache.move_to_end(f)
            return self._cache[f]
        t0 = time.perf_counter()
        raw = np.array(self._mm[f * self.chunk_items:
                                (f + 1) * self.chunk_items])
        self.stats.wall += time.perf_counter() - t0
        self.stats.chunk_reads += 1
        self.stats.bytes_read += self.chunk_nbytes
        chunk = raw.reshape(self.chunks)
        self._put_cache(f, chunk)
        return chunk

    def _put_cache(self, f: int, chunk: np.ndarray) -> None:
        self._cache[f] = chunk
        self._cache.move_to_end(f)
        while len(self._cache) > self._cache_slots:
            ef, ec = self._cache.popitem(last=False)
            self._flush_chunk(ef, ec)

    def _flush_chunk(self, f: int, chunk: np.ndarray) -> None:
        if self._readonly:
            return                        # reads never dirty a chunk
        t0 = time.perf_counter()
        self._mm[f * self.chunk_items:(f + 1) * self.chunk_items] = \
            chunk.reshape(-1)
        self.stats.wall += time.perf_counter() - t0
        self.stats.chunk_writes += 1
        self.stats.bytes_written += self.chunk_nbytes

    def flush(self) -> None:
        for f, c in list(self._cache.items()):
            self._flush_chunk(f, c)
        self._cache.clear()
        self._mm.flush()

    # -- region IO --------------------------------------------------------
    def _touched(self, region: tuple[slice, ...]):
        ranges = []
        for d, sl in enumerate(region):
            start = sl.start or 0
            stop = self.shape[d] if sl.stop is None else min(sl.stop,
                                                             self.shape[d])
            ranges.append(range(start // self.chunks[d],
                                (stop - 1) // self.chunks[d] + 1))
        return ranges

    def read(self, region: tuple[slice, ...]) -> np.ndarray:
        region = tuple(region)
        starts = [sl.start or 0 for sl in region]
        stops = [self.shape[d] if sl.stop is None else sl.stop
                 for d, sl in enumerate(region)]
        out = np.empty([b - a for a, b in zip(starts, stops)],
                       dtype=self.dtype)
        ranges = self._touched(region)
        for cidx in np.ndindex(*[len(r) for r in ranges]):
            c = tuple(ranges[d][cidx[d]] for d in range(len(cidx)))
            chunk = self._get_chunk(c)
            # intersection of chunk extent and region, in both coords
            src, dst = [], []
            for d in range(len(c)):
                c0 = c[d] * self.chunks[d]
                lo = max(starts[d], c0)
                hi = min(stops[d], c0 + self.chunks[d], self.shape[d])
                src.append(slice(lo - c0, hi - c0))
                dst.append(slice(lo - starts[d], hi - starts[d]))
            out[tuple(dst)] = chunk[tuple(src)]
        return out

    def write(self, region: tuple[slice, ...], values: np.ndarray) -> None:
        if self._readonly:
            raise OSError(f"{self.path} is open read-only")
        region = tuple(region)
        starts = [sl.start or 0 for sl in region]
        stops = [self.shape[d] if sl.stop is None else sl.stop
                 for d, sl in enumerate(region)]
        values = np.asarray(values, dtype=self.dtype).reshape(
            [b - a for a, b in zip(starts, stops)])
        ranges = self._touched(region)
        for cidx in np.ndindex(*[len(r) for r in ranges]):
            c = tuple(ranges[d][cidx[d]] for d in range(len(cidx)))
            src, dst = [], []
            full = True
            for d in range(len(c)):
                c0 = c[d] * self.chunks[d]
                lo = max(starts[d], c0)
                hi = min(stops[d], c0 + self.chunks[d], self.shape[d])
                if lo > c0 or hi < min(c0 + self.chunks[d], self.shape[d]):
                    full = False
                dst.append(slice(lo - c0, hi - c0))
                src.append(slice(lo - starts[d], hi - starts[d]))
            f = self._flat(c)
            if full and f not in self._cache:
                # whole-chunk write: no read-modify-write round trip
                chunk = np.zeros(self.chunks, dtype=self.dtype)
                self._put_cache(f, chunk)
            else:
                chunk = self._get_chunk(c)
            chunk[tuple(dst)] = values[tuple(src)]
            self.dirty.add(f)
        # cached chunks are flushed on eviction/flush (write-back cache)

    def read_all(self) -> np.ndarray:
        return self.read(tuple(slice(0, s) for s in self.shape))

    def write_all(self, values: np.ndarray) -> None:
        self.write(tuple(slice(0, s) for s in self.shape), values)
        self.flush()

    def load_from(self, path: str) -> None:
        """Replace this file's contents with another chunk file of the
        SAME shape/layout via an OS-level file copy — restores a
        checkpointed volume without round-tripping it through RAM
        (O(frames), not O(dataset), memory)."""
        if self._readonly:
            raise OSError(f"{self.path} is open read-only")
        if os.path.getsize(path) < self._n_items * self.dtype.itemsize:
            raise ValueError(f"{path} too small for layout {self.chunks} "
                             f"over {self.shape}")
        self._cache.clear()
        self._mm = None                   # release before overwriting
        shutil.copyfile(path, self.path)
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r+",
                             shape=(self._n_items,))
        self.dirty = set(range(int(np.prod(self.grid))))


class ChunkedFileTransport(Transport):
    """Out-of-core mode: every dataset is a ChunkedFile; chunk layouts
    come from the paper's optimiser given (now, next) patterns; plugins
    see m frames at a time read straight off file — RAM use is O(frames),
    never O(dataset) (paper §III.A)."""

    name = "chunked_file"

    def __init__(self, directory: str | None = None,
                 cache_bytes: int = DEFAULT_CACHE_BYTES,
                 optimise: bool = True, frames_hint: int = 8):
        self.dir = directory or tempfile.mkdtemp(prefix="savu_jax_")
        os.makedirs(self.dir, exist_ok=True)
        self.cache_bytes = cache_bytes
        self.optimise = optimise
        self.frames_hint = frames_hint
        self.files: dict[str, ChunkedFile] = {}
        self._counter = 0

    def _new_path(self, name: str) -> str:
        self._counter += 1
        return os.path.join(self.dir, f"{self._counter:03d}_{name}.dat")

    def chunk_for(self, ds: DataSet, now: Pattern, next_: Pattern | None
                  ) -> tuple[int, ...]:
        if not self.optimise:
            from .chunking import naive_chunks
            return naive_chunks(ds.shape, np.dtype(ds.dtype).itemsize,
                                self.cache_bytes)
        return optimise_chunks(
            ds.shape, now, next_, itemsize=np.dtype(ds.dtype).itemsize,
            frames=self.frames_hint, cache_bytes=self.cache_bytes)

    def allocate(self, ds: DataSet, now: Pattern, next_: Pattern | None
                 ) -> None:
        chunks = self.chunk_for(ds, now, next_)
        cf = ChunkedFile(self._new_path(ds.name), ds.shape, ds.dtype,
                         chunks, self.cache_bytes)
        self.files[ds.name] = cf
        ds.backing = cf
        ds.metadata["chunks"] = chunks

    def ingest(self, ds: DataSet, now: Pattern,
               next_: Pattern | None = None) -> None:
        """Copy a materialised dataset into a chunked file (loader side)."""
        data = np.asarray(ds.materialise())
        self.allocate(ds, now, next_)
        ds.backing.write_all(data)

    def run_plugin(self, plugin: BasePlugin) -> list[Any]:
        in_pds = plugin.in_data
        out_pds = plugin.out_data
        m = in_pds[0].n_frames
        in_pats = [pd.pattern for pd in in_pds]
        out_pats = [pd.pattern for pd in out_pds]
        shape0 = in_pds[0].dataset.shape
        slices_iters = [pd.pattern.frame_slices(pd.dataset.shape, m)
                        for pd in in_pds]
        out_iters = [pd.pattern.frame_slices(pd.dataset.shape, m)
                     for pd in out_pds]
        n_calls = 0
        for idx_tuple in zip(*slices_iters):
            blocks = []
            for pd, pat, idx in zip(in_pds, in_pats, idx_tuple):
                backing = pd.dataset.backing
                if isinstance(backing, ChunkedFile):
                    raw = backing.read(idx)
                else:
                    raw = np.asarray(pd.dataset.materialise())[idx]
                blocks.append(pat.to_frames(
                    raw, shape=[s.stop - (s.start or 0)
                                if isinstance(s, slice) else 1
                                for s in _norm_idx(idx, pd.dataset.shape)]))
            res = _as_list(plugin.process_frames(blocks))
            for pd, pat, r, it in zip(out_pds, out_pats, res, out_iters):
                oidx = next(it)
                oshape = [s.stop - (s.start or 0)
                          for s in _norm_idx(oidx, pd.dataset.shape)]
                val = pat.from_frames(np.asarray(r), oshape)
                pd.dataset.backing.write(_norm_idx(oidx, pd.dataset.shape),
                                         val)
            n_calls += 1
        for pd in out_pds:
            pd.dataset.backing.flush()
        return [pd.dataset.backing for pd in out_pds]

    def read(self, ds: DataSet) -> np.ndarray:
        b = ds.materialise()
        if isinstance(b, ChunkedFile):
            return b.read_all()
        return np.asarray(b)

    def total_stats(self) -> IOStats:
        s = IOStats()
        for cf in self.files.values():
            s = s.merge(cf.stats)
        return s

    def stats(self) -> dict[str, Any]:
        return {"io": dataclasses.asdict(self.total_stats())}

    def close(self) -> None:
        for cf in self.files.values():
            cf.flush()


def _norm_idx(idx: tuple, shape: Sequence[int]) -> tuple[slice, ...]:
    out = []
    for d, s in enumerate(idx):
        if isinstance(s, slice):
            out.append(slice(s.start or 0,
                             shape[d] if s.stop is None else s.stop))
        else:
            out.append(slice(int(s), int(s) + 1))
    return tuple(out)
