"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelisable)
and sLSTM (scalar memory, true recurrence), interleaved 7:1 as in the
released xLSTM-1.3b recipe (``slstm_every = 8``).

mLSTM maps onto the same chunked linear-recurrence engine as Mamba-2
(q→query, k→key, i_t folded into v, log σ(f̃) as decay); the
normaliser state n_t is carried as one extra value column appended to v
(state columns P+1), so one engine invocation yields both C_t·q and
n_t·q.  Denominator per the paper: max(|nᵀq|, 1).

sLSTM keeps the exponential-gate scalar recurrence with the m-state
stabiliser and a per-head recurrent matrix R — sequential by
construction (lax.scan over time).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, split_keys
from .layers import rms_norm
from .sharding import get_rules
from .ssd import chunked_linear_scan, linear_scan_step


# ======================================================================
# mLSTM
def init_mlstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    ks = split_keys(key, 7)
    return {
        "ln": jnp.ones((d,), cfg.param_dtype),
        "wq": dense_init(ks[0], d, (d, h, p), cfg.param_dtype),
        "wk": dense_init(ks[1], d, (d, h, p), cfg.param_dtype),
        "wv": dense_init(ks[2], d, (d, h, p), cfg.param_dtype),
        "w_if": dense_init(ks[3], d, (d, 2 * h), cfg.param_dtype),
        "w_o": dense_init(ks[4], d, (d, d), cfg.param_dtype),
        "w_out": dense_init(ks[5], d, (d, d), cfg.param_dtype),
        "norm": jnp.ones((d,), cfg.param_dtype),
    }


def _mlstm_gates(params, hx, dtype):
    gates = jnp.einsum("bsd,dg->bsg", hx, params["w_if"].astype(dtype))
    h2 = gates.shape[-1] // 2
    i_raw = gates[..., :h2].astype(jnp.float32)
    f_raw = gates[..., h2:].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_raw)                # decay ≤ 0
    log_i = -jax.nn.softplus(-i_raw)                 # = log σ(ĩ) ≤ 0
    return log_i, log_f


def mlstm_fwd(params, x: jnp.ndarray, cfg: ModelConfig, *,
              chunk: int = 64) -> jnp.ndarray:
    r = get_rules()
    b, s, d = x.shape
    h = cfg.n_heads
    p = d // h
    dt = cfg.dtype
    hx = rms_norm(x, params["ln"].astype(dt), cfg.norm_eps)
    q = jnp.einsum("bsd,dhp->bshp", hx, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhp->bshp", hx, params["wk"].astype(dt)) / \
        jnp.sqrt(jnp.asarray(p, dt))
    v = jnp.einsum("bsd,dhp->bshp", hx, params["wv"].astype(dt))
    q = r.constrain(q, "batch", None, "heads", None)
    log_i, log_f = _mlstm_gates(params, hx, dt)

    # fold input gate into v; append ones column for the normaliser n.
    vf = v.astype(jnp.float32) * jnp.exp(log_i)[..., None]
    ones = jnp.exp(log_i)[..., None]                  # n accumulates i_t·k
    v_ext = jnp.concatenate([vf, ones], axis=-1)      # (B,S,H,P+1)
    y_ext, _ = chunked_linear_scan(q.astype(jnp.float32),
                                   k.astype(jnp.float32), v_ext, log_f,
                                   chunk=chunk)
    y_num, y_den = y_ext[..., :p], y_ext[..., p:]
    denom = jnp.maximum(jnp.abs(y_den), 1.0)
    y = (y_num / denom).astype(dt).reshape(b, s, d)

    og = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", hx, params["w_o"].astype(dt))
        .astype(jnp.float32)).astype(dt)
    y = rms_norm(y * og, params["norm"].astype(dt), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"].astype(dt))
    return r.constrain(out, "batch", "seq", "embed_act")


class MLSTMCache(NamedTuple):
    state: jnp.ndarray     # (B, H, P, P+1)


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    h = cfg.n_heads
    p = cfg.d_model // h
    return MLSTMCache(jnp.zeros((batch, h, p, p + 1), jnp.float32))


def mlstm_step(params, x: jnp.ndarray, cache: MLSTMCache,
               cfg: ModelConfig) -> tuple[jnp.ndarray, MLSTMCache]:
    b, _, d = x.shape
    h = cfg.n_heads
    p = d // h
    dt = cfg.dtype
    hx = rms_norm(x, params["ln"].astype(dt), cfg.norm_eps)
    q = jnp.einsum("bsd,dhp->bshp", hx, params["wq"].astype(dt))[:, 0]
    k = (jnp.einsum("bsd,dhp->bshp", hx, params["wk"].astype(dt))
         / jnp.sqrt(jnp.asarray(p, dt)))[:, 0]
    v = jnp.einsum("bsd,dhp->bshp", hx, params["wv"].astype(dt))[:, 0]
    log_i, log_f = _mlstm_gates(params, hx, dt)
    log_i, log_f = log_i[:, 0], log_f[:, 0]           # (B, H)
    vf = v.astype(jnp.float32) * jnp.exp(log_i)[..., None]
    v_ext = jnp.concatenate([vf, jnp.exp(log_i)[..., None]], axis=-1)
    y_ext, new_state = linear_scan_step(q.astype(jnp.float32),
                                        k.astype(jnp.float32), v_ext,
                                        log_f, cache.state)
    y_num, y_den = y_ext[..., :p], y_ext[..., p:]
    y = (y_num / jnp.maximum(jnp.abs(y_den), 1.0)).astype(dt)
    y = y.reshape(b, 1, d)
    og = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", hx, params["w_o"].astype(dt))
        .astype(jnp.float32)).astype(dt)
    y = rms_norm(y * og, params["norm"].astype(dt), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"].astype(dt))
    return out, MLSTMCache(new_state)


# ======================================================================
# sLSTM
def init_slstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    ks = split_keys(key, 3)
    return {
        "ln": jnp.ones((d,), cfg.param_dtype),
        "w_gates": dense_init(ks[0], d, (d, 4 * d), cfg.param_dtype),
        "r_gates": dense_init(ks[1], p, (h, p, 4 * p), cfg.param_dtype),
        "w_out": dense_init(ks[2], d, (d, d), cfg.param_dtype),
        "norm": jnp.ones((d,), cfg.param_dtype),
    }


class SLSTMCache(NamedTuple):
    c: jnp.ndarray   # (B, H, P)
    n: jnp.ndarray   # (B, H, P)
    m: jnp.ndarray   # (B, H, P) stabiliser
    h: jnp.ndarray   # (B, H, P) hidden


def init_slstm_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    hh = cfg.n_heads
    p = cfg.d_model // hh
    z = jnp.zeros((batch, hh, p), jnp.float32)
    return SLSTMCache(z, z, z - 1e30, z)


def _slstm_cell(params, xt, cache: SLSTMCache, cfg: ModelConfig
                ) -> tuple[jnp.ndarray, SLSTMCache]:
    """xt: pre-computed gate inputs (B, H, 4P) fp32."""
    b, hh, _ = xt.shape
    p = xt.shape[-1] // 4
    rec = jnp.einsum("bhp,hpq->bhq", cache.h, params["r_gates"]
                     .astype(jnp.float32))
    g = xt + rec
    zr, ir, fr, orr = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zr)
    log_i = ir                                    # exp input gate (log dom)
    log_f = jax.nn.log_sigmoid(fr)
    m_new = jnp.maximum(log_f + cache.m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + cache.m - m_new)
    c_new = f_s * cache.c + i_s * z
    n_new = jnp.maximum(f_s * cache.n + i_s, 1e-6)
    h_tilde = c_new / n_new
    h_new = jax.nn.sigmoid(orr) * h_tilde
    return h_new, SLSTMCache(c_new, n_new, m_new, h_new)


def slstm_fwd(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    r = get_rules()
    b, s, d = x.shape
    hh = cfg.n_heads
    p = d // hh
    dt = cfg.dtype
    hx = rms_norm(x, params["ln"].astype(dt), cfg.norm_eps)
    gates_in = jnp.einsum("bsd,dg->bsg", hx, params["w_gates"].astype(dt))
    gates_in = gates_in.reshape(b, s, hh, 4 * p).astype(jnp.float32)

    def step(cache, gt):
        h_new, cache = _slstm_cell(params, gt, cache, cfg)
        return cache, h_new

    cache0 = init_slstm_cache(cfg, b)
    _, hs = jax.lax.scan(step, cache0, jnp.moveaxis(gates_in, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(dt)
    y = rms_norm(y, params["norm"].astype(dt), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"].astype(dt))
    return r.constrain(out, "batch", "seq", "embed_act")


def slstm_step(params, x: jnp.ndarray, cache: SLSTMCache,
               cfg: ModelConfig) -> tuple[jnp.ndarray, SLSTMCache]:
    b, _, d = x.shape
    hh = cfg.n_heads
    p = d // hh
    dt = cfg.dtype
    hx = rms_norm(x, params["ln"].astype(dt), cfg.norm_eps)
    gt = jnp.einsum("bsd,dg->bsg", hx, params["w_gates"].astype(dt))
    gt = gt.reshape(b, hh, 4 * p).astype(jnp.float32)
    h_new, cache = _slstm_cell(params, gt, cache, cfg)
    y = h_new.reshape(b, 1, d).astype(dt)
    y = rms_norm(y, params["norm"].astype(dt), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"].astype(dt))
    return out, cache
