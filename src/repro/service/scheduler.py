"""PipelineScheduler — many process lists, shared workers, one cache.

Savu runs one pipeline per MPI job; a facility runs *hundreds* of them a
day.  The scheduler closes that gap:

* ``n_workers`` threads pull jobs off the :class:`JobQueue` and drive
  each job's :class:`PluginRunner` through its resumable plugin steps —
  with ≥2 workers one job's host-side I/O (ChunkedFileTransport chunk
  reads, checkpoint writes) overlaps another job's jit compute, which
  releases the GIL while XLA executes.
* every job's transport shares one process-level
  :class:`~repro.service.compile_cache.CompileCache`, so resubmitting an
  identical process list skips every ``jax.jit`` retrace (the paper's
  "same pipeline, many datasets" case).
* ``batch_identical=True`` gang-schedules queued jobs whose chain
  signatures match: each plugin step executes as ONE compiled call over
  all gang members' datasets (``ShardedTransport.run_plugin_batch``),
  with per-job calibration constants riding along as stacked arguments.
* an optional :class:`CheckpointStore` persists per-plugin completion +
  surviving datasets after every step; a killed job resubmitted with the
  same id restarts at the last finished plugin (Savu's MPI
  checkpointing).
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable

from ..core.framework import PluginRunner
from ..core.transport import InMemoryTransport, Transport
from .checkpoint import CheckpointStore
from .job import Job, JobState
from .queue import JobQueue


class PipelineScheduler:
    """Drives jobs popped from a :class:`JobQueue` over shared worker
    threads — reproduces the paper's §I premise (one framework, many
    simultaneous datasets) as a long-lived multi-tenant service."""

    def __init__(self, queue: JobQueue, *,
                 transport_factory: Callable[[Job], Transport] | None = None,
                 n_workers: int = 2,
                 checkpoints: CheckpointStore | None = None,
                 batch_identical: bool = False,
                 batch_max: int = 4,
                 fuse: bool = False,
                 compile_cache=None):
        """Args:
            queue: the admission queue workers pull from.
            transport_factory: Job -> Transport for each dispatch
                (default: a fresh ``InMemoryTransport`` per job).
            n_workers: worker threads (≥2 overlaps one job's host I/O
                with another's jit compute; see module docstring).
            checkpoints: save after every plugin step + restore
                resubmitted job ids (None disables).
            batch_identical: gang queued jobs with matching chain
                signatures into one compiled call per step.
            batch_max: gang size bound.
            fuse: compile consecutive linear plugins as one jit.
            compile_cache: held only for ``stats()`` reporting — wire
                the SAME object into the transports the factory builds.
        """
        self.queue = queue
        self.transport_factory = (transport_factory
                                  or (lambda job: InMemoryTransport()))
        self.n_workers = max(1, n_workers)
        self.checkpoints = checkpoints
        self.batch_identical = batch_identical
        self.batch_max = max(2, batch_max)
        self.fuse = fuse
        self.compile_cache = compile_cache   # held for stats reporting
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self.jobs_done = 0
        self.jobs_failed = 0
        self.gangs_run = 0
        self._started_at: float | None = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "PipelineScheduler":
        """Start the worker threads (idempotent).  Returns self."""
        if self._threads:
            return self
        self._started_at = time.time()
        for i in range(self.n_workers):
            # workers poll the event they were STARTED with, so a
            # shutdown always reaches this generation even after _stop
            # is re-armed for the next start()
            t = threading.Thread(target=self._worker, args=(self._stop,),
                                 name=f"pipeline-w{i}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def drain(self, timeout: float | None = None) -> bool:
        """Wait for every submitted job to reach a terminal state.
        Returns False on timeout (seconds; None = wait forever)."""
        return self.queue.wait_all(timeout)

    def shutdown(self, wait: bool = True) -> None:
        """Stop the workers.  In-flight jobs finish their current run;
        queued jobs stay queued for the next ``start()``.  With
        ``wait=True`` blocks until the worker threads exit."""
        self._stop.set()
        if wait:
            for t in self._threads:
                t.join(timeout=30)
        self._threads = []
        self._stop = threading.Event()

    def stats(self) -> dict[str, Any]:
        """Aggregate counters (``GET /stats``): ``jobs_done``,
        ``jobs_failed``, ``gangs_run``, ``pending``, scheduler ``wall``
        since start, and the shared cache's ``compile_cache`` hit/miss
        counts when one was wired in."""
        out: dict[str, Any] = {
            "jobs_done": self.jobs_done, "jobs_failed": self.jobs_failed,
            "gangs_run": self.gangs_run, "pending": self.queue.pending(),
        }
        if self._started_at is not None:
            out["wall"] = time.time() - self._started_at
        if self.compile_cache is not None:
            out["compile_cache"] = self.compile_cache.stats()
        return out

    # -- worker loop ----------------------------------------------------
    def _worker(self, stop: threading.Event) -> None:
        while not stop.is_set():
            if self.batch_identical:
                jobs = self.queue.get_batch(self.batch_max, timeout=0.1)
            else:
                job = self.queue.get(timeout=0.1)
                jobs = [job] if job is not None else []
            if not jobs:
                continue
            if len(jobs) == 1:
                self._run_job(jobs[0])
            else:
                self._run_gang(jobs)

    # -- solo execution -------------------------------------------------
    def _fail(self, job: Job, exc: Exception) -> None:
        job.error = f"{type(exc).__name__}: {exc}"
        job.metadata["traceback"] = traceback.format_exc()
        job.state = JobState.FAILED

    def _drive(self, job: Job, runner: PluginRunner) -> None:
        """Step a PREPARED runner to completion (status + checkpoints)."""
        job.plugin_index = runner.current_step
        job.state = JobState.RUNNING
        while runner.step():
            job.plugin_index = runner.current_step
            if self.checkpoints is not None:
                self.checkpoints.save(job.job_id, runner)
        runner.finalise()
        job.state = JobState.DONE
        if self.checkpoints is not None:
            self.checkpoints.clear(job.job_id)

    def _run_job(self, job: Job) -> None:
        job.started_at = time.time()
        job.state = JobState.CHECKING
        try:
            runner = PluginRunner(job.process_list,
                                  self.transport_factory(job),
                                  fuse=self.fuse)
            job.runner = runner
            runner.prepare()
            if self.checkpoints is not None:
                job.resumed_from = self.checkpoints.restore(job.job_id,
                                                            runner)
            job.n_plugins = runner.n_steps
            self._drive(job, runner)
        except Exception as e:
            self._fail(job, e)
        finally:
            self._finish([job])

    # -- gang execution -------------------------------------------------
    def _run_gang(self, jobs: list[Job]) -> None:
        """Identical chains from several jobs step in lockstep; each
        single-plugin step becomes one batched compiled call.  Faults
        are isolated where possible: a job whose prepare fails is marked
        failed alone, and a batch-signature mismatch (chain signatures
        equal but runtime shapes differ, e.g. inline-scan loaders) falls
        back to per-job execution rather than failing the gang.  A job
        holding a checkpoint is restored here too (``resumed_from`` set
        like the solo path) and then driven solo — a gang would force it
        back into lockstep from step 0."""
        transport = self.transport_factory(jobs[0])
        runners: list[PluginRunner] = []
        live: list[Job] = []
        resumed: list[Job] = []
        for job in jobs:
            job.started_at = time.time()
            job.state = JobState.CHECKING
            try:
                r = PluginRunner(job.process_list, transport, fuse=self.fuse)
                job.runner = r
                r.prepare()
                if self.checkpoints is not None:
                    job.resumed_from = self.checkpoints.restore(job.job_id,
                                                                r)
                job.n_plugins = r.n_steps
                if job.resumed_from:
                    resumed.append(job)
                else:
                    runners.append(r)
                    live.append(job)
            except Exception as e:
                self._fail(job, e)
                self._finish([job])
        for job in resumed:
            try:
                self._drive(job, job.runner)
            except Exception as e:
                self._fail(job, e)
            finally:
                self._finish([job])
        jobs = live
        if not jobs:
            return
        if len(jobs) == 1:
            job = jobs[0]
            try:
                self._drive(job, job.runner)
            except Exception as e:
                self._fail(job, e)
            finally:
                self._finish([job])
            return
        try:
            for job in jobs:
                job.state = JobState.RUNNING
            can_batch = hasattr(transport, "run_plugin_batch")
            for _ in range(runners[0].n_steps):
                groups = [r.begin_step() for r in runners]
                if can_batch and len(groups[0]) == 1:
                    try:
                        transport.run_plugin_batch([g[0] for g in groups])
                    except ValueError:       # signature mismatch: solo
                        for g in groups:
                            transport.run_plugin(g[0])
                else:
                    for g in groups:
                        if len(g) > 1:
                            transport.run_fused(g)
                        else:
                            transport.run_plugin(g[0])
                for job, r in zip(jobs, runners):
                    r.complete_step()
                    job.plugin_index = r.current_step
                    if self.checkpoints is not None:
                        self.checkpoints.save(job.job_id, r)
            for job, r in zip(jobs, runners):
                r.finalise()
                job.state = JobState.DONE
                if self.checkpoints is not None:
                    self.checkpoints.clear(job.job_id)
            with self._lock:
                self.gangs_run += 1
        except Exception as e:
            err = f"{type(e).__name__}: {e}"
            tb = traceback.format_exc()
            for job in jobs:
                if not job.state.terminal():
                    job.error = err
                    job.metadata["traceback"] = tb
                    job.state = JobState.FAILED
        finally:
            self._finish(jobs)

    def _finish(self, jobs: list[Job]) -> None:
        now = time.time()
        with self._lock:
            for job in jobs:
                job.finished_at = job.finished_at or now
                if job.state is JobState.DONE:
                    self.jobs_done += 1
                elif job.state is JobState.FAILED:
                    self.jobs_failed += 1
        self.queue.notify_terminal()
