"""OTLP-shaped JSON export bridge — stdlib only.

The native trace/metrics wire formats (``Trace.to_wire()``,
``MetricsRegistry.snapshot()``) are this repo's own; real facilities
feed Jaeger/Tempo/Prometheus-compatible backends.  This module maps
both onto the OpenTelemetry OTLP/JSON shapes (`resourceSpans` /
`resourceMetrics`) WITHOUT taking an opentelemetry dependency: the
output is plain dicts that ``json.dumps`` straight into an OTLP/HTTP
collector body or a file an offline ingester replays.

Span mapping is 1:1 and lossless for our model: ids are zero-padded to
OTLP's 32-hex trace / 16-hex span ids (ours are 16-hex uuid4 prefixes),
timestamps become unix nanos, and ``attrs`` become OTLP keyValue lists.
Spans are grouped into one ``resourceSpans`` entry per recording
process (``worker_id``), so resource attributes carry worker/broker
identity the way OTLP intends.

:class:`OtlpSpool` writes export documents into a directory (atomic
tmp+rename, bounded like :class:`~repro.obs.trace.TraceSpool`) for
offline ingestion — the CI artifact path.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Iterable

#: instrumentation scope stamped on every export
SCOPE = {"name": "repro.obs", "version": "1"}


def _otlp_id(hex_id: str, width: int) -> str:
    """Zero-pad (or truncate) a hex id to OTLP's fixed width: 32 chars
    for trace ids, 16 for span ids.  Non-hex ids (user-supplied
    trace_ids) are hashed into range instead of rejected — export must
    never fail on telemetry."""
    s = (hex_id or "").lower()
    try:
        int(s, 16)
    except ValueError:
        s = f"{hash(s) & (16 ** width - 1):x}"
    return s[:width].rjust(width, "0")


def _nanos(t: float | None) -> str:
    """Unix nanos as a string (OTLP/JSON encodes uint64 as strings)."""
    return str(int((t or 0.0) * 1e9))


def _any_value(v: Any) -> dict[str, Any]:
    if isinstance(v, bool):
        return {"boolValue": v}
    if isinstance(v, int):
        return {"intValue": str(v)}
    if isinstance(v, float):
        return {"doubleValue": v}
    if isinstance(v, (list, tuple)):
        return {"arrayValue": {"values": [_any_value(x) for x in v]}}
    return {"stringValue": str(v)}


def _attributes(attrs: dict[str, Any]) -> list[dict[str, Any]]:
    return [{"key": str(k), "value": _any_value(v)}
            for k, v in attrs.items()]


def _resource(identity: str, extra: dict[str, Any] | None = None
              ) -> dict[str, Any]:
    """OTLP resource for one recording process: ``service.name`` is the
    pipeline service, ``service.instance.id`` the worker/broker id."""
    return {"attributes": _attributes({
        "service.name": "repro.pipeline",
        "service.instance.id": identity,
        **(extra or {})})}


def _wire_spans(trace: Any) -> tuple[str, list[dict[str, Any]]]:
    """Normalise a :class:`~repro.obs.trace.Trace` OR its wire document
    (``{"trace_id", "spans": [...]}``) to ``(trace_id, wire spans)``."""
    if isinstance(trace, dict):
        return str(trace.get("trace_id") or ""), \
            list(trace.get("spans") or ())
    return trace.trace_id, [s.to_wire() for s in trace.spans()]


def trace_to_otlp(trace: Any,
                  resource_attrs: dict[str, Any] | None = None
                  ) -> dict[str, Any]:
    """One job's trace as an OTLP/JSON ``ExportTraceServiceRequest``.

    Args:
        trace: a live :class:`~repro.obs.trace.Trace` or the wire dict
            ``GET /jobs/{id}/trace`` serves.
        resource_attrs: extra resource attributes stamped on every
            ``resourceSpans`` entry (e.g. ``{"job.id": ...}``).

    Spans map 1:1 — every native span becomes exactly one OTLP span
    (same count, padded ids) — grouped by recording ``worker_id`` into
    per-process ``resourceSpans`` entries ("broker" for spans recorded
    service-side).
    """
    trace_id, spans = _wire_spans(trace)
    tid = _otlp_id(trace_id, 32)
    by_proc: dict[str, list[dict[str, Any]]] = {}
    for d in spans:
        end = d.get("end")
        span = {
            "traceId": tid,
            "spanId": _otlp_id(str(d.get("span_id") or ""), 16),
            "name": str(d.get("name") or ""),
            "kind": 1,                       # SPAN_KIND_INTERNAL
            "startTimeUnixNano": _nanos(d.get("start")),
            # an open span exports end == start: OTLP has no "open"
            "endTimeUnixNano": _nanos(end if end is not None
                                      else d.get("start")),
        }
        if d.get("parent_id"):
            span["parentSpanId"] = _otlp_id(str(d["parent_id"]), 16)
        if d.get("attrs"):
            span["attributes"] = _attributes(d["attrs"])
        by_proc.setdefault(str(d.get("worker_id") or "broker"),
                           []).append(span)
    return {"resourceSpans": [
        {"resource": _resource(proc, resource_attrs),
         "scopeSpans": [{"scope": SCOPE, "spans": procspans}]}
        for proc, procspans in sorted(by_proc.items())]}


def metrics_to_otlp(snapshot: dict[str, Any], identity: str = "broker",
                    now: float | None = None) -> dict[str, Any]:
    """A registry snapshot (``MetricsRegistry.snapshot()``) as an
    OTLP/JSON ``ExportMetricsServiceRequest``: counters become
    monotonic cumulative sums, gauges become gauges, histogram
    summaries become OTLP summaries with quantile values."""
    ts = _nanos(now if now is not None else time.time())
    metrics: list[dict[str, Any]] = []
    for name, value in sorted(snapshot.items()):
        if isinstance(value, dict):          # histogram summary view
            qvals = [{"quantile": q / 100.0,
                      "value": float(value[f"p{q}"])}
                     for q in (50, 95, 99)
                     if value.get(f"p{q}") is not None]
            metrics.append({"name": name, "summary": {"dataPoints": [
                {"timeUnixNano": ts,
                 "count": str(int(value.get("count", 0))),
                 "sum": float(value.get("sum", 0.0)),
                 "quantileValues": qvals}]}})
        elif isinstance(value, bool) or not isinstance(value,
                                                       (int, float)):
            continue                         # not a metric sample
        elif isinstance(value, int):         # counters are ints
            metrics.append({"name": name, "sum": {
                "aggregationTemporality": 2,     # CUMULATIVE
                "isMonotonic": True,
                "dataPoints": [{"timeUnixNano": ts,
                                "asDouble": float(value)}]}})
        else:                                # gauges are floats
            if value != value:               # NaN scrape: skip sample
                metrics.append({"name": name,
                                "gauge": {"dataPoints": []}})
                continue
            metrics.append({"name": name, "gauge": {
                "dataPoints": [{"timeUnixNano": ts,
                                "asDouble": float(value)}]}})
    return {"resourceMetrics": [
        {"resource": _resource(identity),
         "scopeMetrics": [{"scope": SCOPE, "metrics": metrics}]}]}


class OtlpSpool:
    """Bounded directory of OTLP/JSON export documents for offline
    ingestion (``cat *.otlp.json | curl collector`` or the CI artifact
    upload).  Files are written atomically; past ``max_files`` the
    oldest (mtime) are deleted."""

    def __init__(self, root: str, max_files: int = 256):
        if max_files < 1:
            raise ValueError(f"max_files must be >= 1, got {max_files}")
        self.root = root
        self.max_files = max_files
        self._lock = threading.Lock()
        os.makedirs(root, exist_ok=True)

    def put(self, name: str, doc: dict[str, Any]) -> str:
        """Write one export document as ``<name>.otlp.json`` (name is
        sanitised); returns the path."""
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in name) or "export"
        path = os.path.join(self.root, f"{safe}.otlp.json")
        tmp = path + ".tmp"
        with self._lock:
            with open(tmp, "w") as fh:
                json.dump(doc, fh)
            os.replace(tmp, path)
            self._evict_locked()
        return path

    def export_trace(self, job_id: str, trace: Any, **resource_attrs
                     ) -> str:
        return self.put(f"trace-{job_id}",
                        trace_to_otlp(trace, {"job.id": job_id,
                                              **resource_attrs}))

    def export_metrics(self, snapshot: dict[str, Any],
                       identity: str = "broker") -> str:
        return self.put("metrics",
                        metrics_to_otlp(snapshot, identity=identity))

    def _evict_locked(self) -> None:
        try:
            files = [os.path.join(self.root, f)
                     for f in os.listdir(self.root)
                     if f.endswith(".otlp.json")]
        except OSError:
            return
        if len(files) <= self.max_files:
            return
        files.sort(key=lambda p: (os.path.getmtime(p), p))
        for p in files[:len(files) - self.max_files]:
            try:
                os.remove(p)
            except OSError:
                pass

    def __len__(self) -> int:
        try:
            return sum(1 for f in os.listdir(self.root)
                       if f.endswith(".otlp.json"))
        except OSError:
            return 0


def iter_spans(otlp_doc: dict[str, Any]) -> Iterable[dict[str, Any]]:
    """Flatten an OTLP trace document back to its span dicts — the
    1:1 check in tests/bench walks this."""
    for rs in otlp_doc.get("resourceSpans", ()):
        for ss in rs.get("scopeSpans", ()):
            yield from ss.get("spans", ())
