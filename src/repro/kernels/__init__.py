# Pallas TPU kernels for the perf-critical compute layers.
