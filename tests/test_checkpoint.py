"""Checkpoint v2 + liveness-aware donation.

The regression class under test: a branching chain (raw data consumed by
an early correction AND a late quality-check) used to (a) crash the
sharded transport, which donated every input buffer at its FIRST use,
and (b) silently drop the donated dataset from checkpoints
(`service/checkpoint.py:57-61` in the seed), so a resume was missing
data a later plugin still needed.  Liveness now donates only at the
final use, the checkpointer knows exactly which datasets a resume
requires, and an interrupted job resumes to bit-identical outputs."""
import json
import os

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import (BaseFilter, BaseLoader, BasePlugin, BaseSaver,
                        ChunkedFile, ChunkedFileTransport, DataSet,
                        InMemoryTransport, PluginRunner, ProcessList,
                        ShardedTransport)
from repro.service import CheckpointError, CheckpointStore


# ---------------------------------------------------------------- helpers
class VolLoader(BaseLoader):
    name = "vol_loader"
    parameters = {"array": None}
    data_params = ("array",)

    def load(self):
        a = self.params["array"]
        d = DataSet(self.out_dataset_names[0], a.shape, a.dtype,
                    ("theta", "y", "x"), backing=a)
        d.add_pattern("PROJECTION", core=("y", "x"), slice_=("theta",))
        return [d]


class AddF(BaseFilter):
    name = "add_f"
    parameters = {"add": 0.0}

    def process_frames(self, frames):
        return frames[0] + self.params["add"]


class Combine(BasePlugin):
    """2-in quality check: the late consumer that keeps its inputs live."""
    name = "combine"
    n_in_datasets = 2

    def setup(self, in_datasets):
        dout = in_datasets[0].like(self.out_dataset_names[0])
        self.chunk_frames(self.default_pattern(in_datasets[0]))
        return [dout]

    def process_frames(self, frames):
        return frames[0] - 0.5 * frames[1]


class NullSaver(BaseSaver):
    name = "null_saver"

    def save(self, ds):
        ds.metadata["saved"] = True


def branching_chain(a) -> ProcessList:
    """raw -> a -> b, then combine(b, a): 'a' is read again AFTER its
    replacement-chain successor was produced."""
    pl = ProcessList()
    pl.add(VolLoader, params={"array": a}, out_datasets=("raw",))
    pl.add(AddF, params={"add": 1.0},
           in_datasets=("raw",), out_datasets=("a",))
    pl.add(AddF, params={"add": 2.0},
           in_datasets=("a",), out_datasets=("b",))
    pl.add(Combine, in_datasets=("b", "a"), out_datasets=("out",))
    pl.add(NullSaver, in_datasets=("out",))
    return pl


def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


@pytest.fixture
def data(rng):
    return rng.normal(size=(4, 6, 5)).astype(np.float32)


def _want(a):
    return (a + 3.0) - 0.5 * (a + 1.0)


# ---------------------------------------------------------------- liveness
def test_required_live_names(data):
    r = PluginRunner(branching_chain(data), InMemoryTransport())
    r.prepare()
    assert r.n_steps == 3
    # resume from step 1: step 1 (a->b) and step 2 (combine) read 'a'
    assert r.required_live_names(1) == {"a"}
    # resume from step 2: combine reads both 'a' and 'b'
    assert r.required_live_names(2) == {"a", "b"}
    # resume from step 3 (all done): only the saver's dataset remains
    assert r.required_live_names(3) == {"out"}


def test_last_use_flags_set_per_step(data):
    seen = {}

    class SpyCombine(Combine):
        def pre_process(self):
            seen[self.name] = [pd.last_use for pd in self.in_data]

    class SpyAdd(AddF):
        def pre_process(self):
            seen[self.params["add"]] = [pd.last_use
                                        for pd in self.in_data]

    pl = ProcessList()
    pl.add(VolLoader, params={"array": data}, out_datasets=("raw",))
    pl.add(SpyAdd, params={"add": 1.0},
           in_datasets=("raw",), out_datasets=("a",))
    pl.add(SpyAdd, params={"add": 2.0},
           in_datasets=("a",), out_datasets=("b",))
    pl.add(SpyCombine, in_datasets=("b", "a"), out_datasets=("out",))
    pl.add(NullSaver, in_datasets=("out",))
    PluginRunner(pl, InMemoryTransport()).run()
    assert seen[1.0] == [True]       # raw: never read again -> donatable
    assert seen[2.0] == [False]      # 'a' is read again by the combiner
    assert seen["combine"] == [True, True]   # final use of both


def test_sharded_branching_chain_survives_donation(data):
    """Seed regression: donate=True deleted 'a' at its first use; the
    combiner then read a dead buffer."""
    tr = ShardedTransport(_mesh1(), donate=True)
    r = PluginRunner(branching_chain(data), tr)
    r.run()
    got = tr.read(r.datasets["out"])
    np.testing.assert_allclose(got, _want(data), rtol=1e-6)


# ------------------------------------------------------- kill/resume
def _interrupted_run(chain_fn, a, transport_factory, store, job_id,
                     kill_after=2):
    ref = PluginRunner(chain_fn(a), transport_factory())
    ref.run()
    want = np.asarray(ref.transport.read(ref.datasets["out"]))

    r1 = PluginRunner(chain_fn(a), transport_factory())
    r1.prepare()
    for _ in range(kill_after):
        r1.step()
        store.save(job_id, r1)
    # "kill" r1; a fresh runner resumes from the store
    r2 = PluginRunner(chain_fn(a), transport_factory())
    assert store.restore(job_id, r2) == kill_after
    while r2.step():
        pass
    r2.finalise()
    got = np.asarray(r2.transport.read(r2.datasets["out"]))
    return got, want


def test_kill_resume_bit_identical_sharded_donate(tmp_path, data):
    """The checkpoint.py:57-61 regression: with donation ON, the
    interrupted-then-resumed run must still see every dataset a later
    plugin needs, and reproduce the uninterrupted result exactly."""
    store = CheckpointStore(str(tmp_path))
    mesh = _mesh1()
    got, want = _interrupted_run(
        branching_chain, data,
        lambda: ShardedTransport(mesh, donate=True), store, "jS")
    np.testing.assert_array_equal(got, want)


def test_kill_resume_bit_identical_chunked(tmp_path, data):
    store = CheckpointStore(str(tmp_path / "store"))
    dirs = iter(range(100))

    def factory():
        return ChunkedFileTransport(
            directory=str(tmp_path / f"tr{next(dirs)}"))

    got, want = _interrupted_run(branching_chain, data, factory,
                                 store, "jC")
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------- incremental behaviour
def test_incremental_checkpoint_skips_unchanged_dense_datasets(
        tmp_path, data):
    store = CheckpointStore(str(tmp_path))
    r = PluginRunner(branching_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    s1 = store.save("j1", r)
    r.step()
    s2 = store.save("j1", r)
    # first checkpoint wrote raw + a; second writes ONLY the new 'b'
    assert s1["files_written"] == 2 and s1["files_reused"] == 0
    assert s2["files_written"] == 1 and s2["files_reused"] == 2
    assert s2["bytes_written"] < s1["bytes_written"]
    man = store.load("j1")
    assert man["version"] == 2
    by_name = {e["name"]: e for e in man["datasets"]}
    assert by_name["raw"]["chunks_written"] == []      # increment: none
    assert by_name["b"]["chunks_written"] == "all"
    assert set(man["required"]) == {"a", "b"}


def test_chunked_backing_is_linked_not_copied(tmp_path, data):
    store = CheckpointStore(str(tmp_path / "store"))
    tr = ChunkedFileTransport(directory=str(tmp_path / "tr"))
    r = PluginRunner(branching_chain(data), tr)
    r.prepare()
    r.step()
    s1 = store.save("j1", r)
    assert s1["files_linked"] >= 1                     # 'a' hard-linked
    cf = r.datasets["a"].backing
    assert isinstance(cf, ChunkedFile)
    ckpt = os.path.join(str(tmp_path / "store"), "j1", "a.ckpt")
    assert os.path.samefile(cf.path, ckpt)
    assert cf.dirty == set()                           # marked clean
    # steady state: nothing changed -> zero-byte increment for 'a'
    r.step()
    s2 = store.save("j1", r)
    man = store.load("j1")
    by_name = {e["name"]: e for e in man["datasets"]}
    assert by_name["a"]["chunks_written"] == []
    assert s2["files_reused"] >= 1


def test_v1_npy_checkpoints_remain_restorable(tmp_path, data):
    v1 = CheckpointStore(str(tmp_path), format="npy")
    r = PluginRunner(branching_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    r.step()
    st = v1.save("j1", r)
    assert st["files_written"] == 3                    # dense: rewrites all
    man = v1.load("j1")
    assert all(e["format"] == "npy" for e in man["datasets"])
    # a default (chunked) store reads the v1 manifest + files
    r2 = PluginRunner(branching_chain(data), InMemoryTransport())
    assert CheckpointStore(str(tmp_path)).restore("j1", r2) == 2
    while r2.step():
        pass
    r2.finalise()
    got = np.asarray(r2.transport.read(r2.datasets["out"]))
    ref = PluginRunner(branching_chain(data), InMemoryTransport()).run()
    np.testing.assert_array_equal(got, np.asarray(ref["out"].materialise()))


# ----------------------------------------------- ChunkedFile IO paths
def test_chunked_file_full_chunk_write_skips_read(tmp_path):
    """A write that covers a whole chunk must not read-modify-write; the
    edge chunks (clipped by the array bounds) count as fully covered."""
    cf = ChunkedFile(str(tmp_path / "t.dat"), (6, 6), np.float32, (4, 4),
                     cache_bytes=64)                  # 1 chunk cached
    cf.write_all(np.ones((6, 6), np.float32))
    assert cf.stats.chunk_reads == 0 and cf.stats.bytes_read == 0
    # a partial write still needs the round trip
    cf.write((slice(1, 3), slice(0, 6)), np.zeros((2, 6), np.float32))
    assert cf.stats.chunk_reads > 0


def test_chunked_file_dirty_tracking(tmp_path):
    cf = ChunkedFile(str(tmp_path / "t.dat"), (8, 8), np.float32, (4, 4))
    cf.write_all(np.ones((8, 8), np.float32))
    assert cf.dirty == {0, 1, 2, 3}                  # every chunk touched
    cf.mark_clean()
    assert cf.dirty == set()
    cf.write((slice(0, 2), slice(0, 2)), np.zeros((2, 2), np.float32))
    assert cf.dirty == {0}                           # only the increment
    # flushing persists but does NOT reset the increment
    cf.flush()
    assert cf.dirty == {0}


def test_chunked_file_readonly_mode(tmp_path):
    path = str(tmp_path / "t.dat")
    cf = ChunkedFile(path, (4, 4), np.float32, (2, 2))
    ref = np.arange(16, dtype=np.float32).reshape(4, 4)
    cf.write_all(ref)
    ro = ChunkedFile(path, (4, 4), np.float32, (2, 2), mode="r")
    np.testing.assert_array_equal(ro.read_all(), ref)
    with pytest.raises(OSError):
        ro.write((slice(0, 2), slice(0, 2)), np.zeros((2, 2)))


def test_chunked_file_load_from(tmp_path):
    ref = np.arange(64, dtype=np.float32).reshape(8, 8)
    src = ChunkedFile(str(tmp_path / "src.dat"), (8, 8), np.float32,
                      (4, 4))
    src.write_all(ref)
    dst = ChunkedFile(str(tmp_path / "dst.dat"), (8, 8), np.float32,
                      (4, 4))
    dst.load_from(src.path)
    np.testing.assert_array_equal(dst.read_all(), ref)


# ------------------------------------------------------- loud failures
def test_restore_raises_when_required_dataset_missing(tmp_path, data):
    store = CheckpointStore(str(tmp_path))
    r = PluginRunner(branching_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    r.step()
    store.save("j1", r)
    # corrupt the manifest: drop 'a', which the combiner still needs
    mpath = os.path.join(str(tmp_path), "j1", "checkpoint.nxs.json")
    man = json.load(open(mpath))
    man["datasets"] = [e for e in man["datasets"] if e["name"] != "a"]
    json.dump(man, open(mpath, "w"))
    r2 = PluginRunner(branching_chain(data), InMemoryTransport())
    with pytest.raises(CheckpointError, match="required dataset"):
        store.restore("j1", r2)


def test_restore_raises_when_required_file_unreadable(tmp_path, data):
    store = CheckpointStore(str(tmp_path))
    r = PluginRunner(branching_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    r.step()
    store.save("j1", r)
    os.remove(os.path.join(str(tmp_path), "j1", "a.ckpt"))
    r2 = PluginRunner(branching_chain(data), InMemoryTransport())
    with pytest.raises(CheckpointError, match="unreadable"):
        store.restore("j1", r2)


def test_save_refuses_dead_required_dataset(tmp_path, data):
    """If a transport donated a buffer the resume still needs, the
    checkpoint must refuse — an unresumable checkpoint is worse than
    none."""
    class Dead:
        shape, dtype = (2,), np.float32

        def is_deleted(self):
            return True

    store = CheckpointStore(str(tmp_path))
    r = PluginRunner(branching_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    r.datasets["a"].backing = Dead()
    with pytest.raises(CheckpointError, match="donated"):
        store.save("j1", r)
