"""Pallas TPU kernel: fused dark/flat correction + −log linearisation.

One VMEM round-trip instead of four elementwise HLOs (sub, sub, div,
log) — the raw uint16 projections are upcast in-register, so the HBM
read stays at 2 bytes/pixel (the paper notes raw data "is immediately
doubled on processing"; fusing the cast into the kernel avoids
materialising the fp32 copy).

Grid: (frames, Y/by); dark/flat blocks are broadcast across the frame
grid dim (index_map drops the frame index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _corr_kernel(raw_ref, dark_ref, flat_ref, out_ref, *, eps: float,
                 hi: float):
    raw = raw_ref[...].astype(jnp.float32)
    dark = dark_ref[...].astype(jnp.float32)
    flat = flat_ref[...].astype(jnp.float32)
    denom = jnp.maximum(flat - dark, eps)
    trans = jnp.clip((raw - dark) / denom, eps, hi)
    out_ref[...] = -jnp.log(trans)


@functools.partial(jax.jit, static_argnames=("eps", "hi", "by",
                                             "interpret"))
def correct_pallas(raw: jnp.ndarray, dark: jnp.ndarray, flat: jnp.ndarray,
                   *, eps: float = 1e-6, hi: float = 10.0, by: int = 32,
                   interpret: bool = True) -> jnp.ndarray:
    """raw (F, Y, X) any real dtype; dark/flat (Y, X) -> (F, Y, X) fp32."""
    f, y, x = raw.shape
    by = min(by, y)
    while y % by:
        by //= 2
    by = max(1, by)
    grid = (f, y // by)
    kernel = functools.partial(_corr_kernel, eps=eps, hi=hi)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, by, x), lambda i, j: (i, j, 0)),
            pl.BlockSpec((by, x), lambda i, j: (j, 0)),
            pl.BlockSpec((by, x), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, by, x), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((f, y, x), jnp.float32),
        interpret=interpret,
    )(raw, dark, flat)
