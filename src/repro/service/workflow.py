"""Workflow DAGs — jobs that depend on jobs (docs/workflows.md).

Savu chains plugins inside ONE process list; a beamline campaign chains
*jobs*: recon feeds downsampling feeds quantification, each stage a
process list of its own (Ot2Rec's staged projects, Daisy's multi-stage
X-ray workflows).  The service layer makes that a first-class workload:

* a spec-v3 envelope (``POST /workflows``) names a DAG of nodes, each
  carrying a v1/v2 process-list spec plus ``"after"`` edges;
* admission is **atomic** (``JobQueue.submit_many``) after cycle and
  dangling-reference detection — an invalid DAG is rejected with 400
  and NOTHING is enqueued;
* stage outputs are addressable as downstream inputs: an
  ``upstream_loader`` entry referencing ``{"from_job": "<node>",
  "dataset": "<name>"}`` is rewritten to the node's job id here and
  resolved at dispatch/lease time by the scheduler or broker;
* downstream nodes become poppable only when every upstream is
  terminal-ok; upstream failure/cancellation cascades ``cancelled``
  with a machine-readable reason (``JobQueue`` owns the propagation).

Envelope::

    {"version": 3,
     "workflow": {
       "recon":      {"process_list": {spec v1}},
       "downsample": {"process_list": {... upstream_loader
                                       {"from_job": "recon"} ...}},
       "quantify":   {"process_list": {...},
                      "after": ["downsample"]}},
     "workflow_id": null, "priority": 0, "metadata": {}}

``after`` edges may be explicit, implied by upstream references, or
both; the union is validated.  See ``docs/workflows.md``.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
import threading
import time
from typing import Any, Callable

from ..core.plugin import _is_jsonable
from ..core.process_list import ProcessList
from .job import Job
from .queue import JobQueue
from .wire import WIRE_VERSION_WORKFLOW, from_spec

#: node-count bound per workflow — DAG validation is O(nodes + edges)
#: but every node is a whole pipeline job; admission control
#: (``max_pending``) applies on top
MAX_NODES = 32

#: node names become job-id components (``<workflow_id>/<node>``) and
#: path components in result spools: word chars, dots and dashes only
_NODE_NAME = re.compile(r"^[A-Za-z0-9_][\w.\-]*$")


class WorkflowError(ValueError):
    """A workflow envelope cannot be admitted: malformed document,
    invalid node name, dangling ``after``/upstream reference, self
    dependency, or a dependency cycle (HTTP 400)."""


def _entry_ref(params: dict[str, Any]) -> tuple[str, str | None] | None:
    """The ``(from_job, dataset)`` upstream reference of an entry's
    params, in either wire form, or None.  Mirrors the scheduler's
    resolver so validation and execution agree on what counts as a
    reference."""
    data = params.get("data")
    if isinstance(data, dict) and data.get("from_job"):
        return str(data["from_job"]), data.get("dataset")
    if data is not None or params.get("path"):
        return None
    fj = params.get("from_job")
    if fj:
        return str(fj), params.get("dataset")
    return None


def toposort(edges: dict[str, list[str]]) -> list[str]:
    """Kahn's algorithm over ``node -> upstream nodes``.  Returns one
    topological order (submission order used as the tiebreak so the
    queue's FIFO seq respects it).  Raises WorkflowError naming the
    cycle members when the graph is not a DAG."""
    indeg = {n: len(ups) for n, ups in edges.items()}
    down: dict[str, list[str]] = {n: [] for n in edges}
    for n, ups in edges.items():
        for u in ups:
            down[u].append(n)
    ready = [n for n, d in indeg.items() if d == 0]
    order: list[str] = []
    while ready:
        n = ready.pop(0)
        order.append(n)
        for d in down[n]:
            indeg[d] -= 1
            if indeg[d] == 0:
                ready.append(d)
    if len(order) != len(edges):
        cyclic = sorted(n for n, d in indeg.items() if d > 0)
        raise WorkflowError(
            f"workflow has a dependency cycle involving {cyclic}")
    return order


# ----------------------------------------------------------------------
@dataclasses.dataclass
class WorkflowGroup:
    """One admitted workflow: the node jobs plus the DAG bookkeeping."""

    workflow_id: str
    nodes: list[str]                    # submission (= topological) order
    jobs: list[Job]                     # parallel to ``nodes``
    edges: dict[str, list[str]]         # node -> upstream node names
    metadata: dict[str, Any] = dataclasses.field(default_factory=dict)
    created_at: float = dataclasses.field(default_factory=time.time)

    @property
    def n_nodes(self) -> int:
        return len(self.jobs)

    def job_of(self, node: str) -> Job:
        return self.jobs[self.nodes.index(node)]

    def all_terminal(self) -> bool:
        return all(j.state.terminal() for j in self.jobs)

    def state(self) -> str:
        """Aggregate state: ``queued`` (nothing started) / ``running`` /
        all-terminal ``done`` | ``cancelled`` | ``failed`` (any node
        failed) | ``partial`` (mixed done+cancelled)."""
        states = {j.state.value for j in self.jobs}
        if not self.all_terminal():
            return "queued" if states == {"queued"} else "running"
        if states == {"done"}:
            return "done"
        if states == {"cancelled"}:
            return "cancelled"
        if "failed" in states:
            return "failed"
        return "partial"

    def snapshot(self, full: bool = True) -> dict[str, Any]:
        """JSON-able group view (``GET /workflows/{id}``): aggregate
        state, per-state counts, the DAG edges, and (``full``) one job
        snapshot per node keyed by node name."""
        counts: dict[str, int] = {}
        for j in self.jobs:
            counts[j.state.value] = counts.get(j.state.value, 0) + 1
        out: dict[str, Any] = {
            "workflow_id": self.workflow_id, "state": self.state(),
            "all_terminal": self.all_terminal(),
            "n_nodes": self.n_nodes, "nodes": list(self.nodes),
            "edges": {n: list(u) for n, u in self.edges.items()},
            "created_at": self.created_at, "counts": counts,
            "metadata": {k: v for k, v in self.metadata.items()
                         if _is_jsonable(v)},
        }
        if full:
            out["node_jobs"] = {n: j.snapshot()
                                for n, j in zip(self.nodes, self.jobs)}
        return out


# ----------------------------------------------------------------------
class WorkflowManager:
    """Validates spec-v3 envelopes into atomically-admitted node jobs
    and tracks them as :class:`WorkflowGroup`\\ s — the service-side
    owner of the ``/workflows`` endpoints.

    Args:
        queue: the admission queue node jobs are submitted to.
        max_nodes: per-workflow node bound (400 past it).
        max_history: retained terminal groups; beyond it the oldest
            all-terminal groups are dropped (their node jobs remain
            subject to the queue's own ``max_history``).
    """

    def __init__(self, queue: JobQueue, *, max_nodes: int = MAX_NODES,
                 max_history: int | None = 64):
        self.queue = queue
        self.max_nodes = max_nodes
        self.max_history = max_history
        self._groups: dict[str, WorkflowGroup] = {}
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self.workflows_submitted = 0
        self.nodes_submitted = 0

    # -- admission ------------------------------------------------------
    def submit(self, envelope: dict[str, Any]) -> WorkflowGroup:
        """Admit one workflow envelope (module docstring for the
        shape).  Validates every node's process list, the DAG structure
        (cycles, dangling references, self-dependencies), and submits
        all node jobs **atomically** — an invalid DAG enqueues nothing.

        Returns: the recorded :class:`WorkflowGroup`.
        Raises:
            WorkflowError / WireError / ProcessListError: invalid
                envelope, node spec, or DAG (HTTP 400).
            ValueError: duplicate active workflow/job id (HTTP 409).
            QueueFull: admission control rejected the whole group
                (HTTP 429).
        """
        if not isinstance(envelope, dict):
            raise WorkflowError("body must be a JSON object")
        version = envelope.get("version", WIRE_VERSION_WORKFLOW)
        if version != WIRE_VERSION_WORKFLOW:
            raise WorkflowError(
                f"workflow envelopes are spec v{WIRE_VERSION_WORKFLOW}, "
                f"got version {version!r}")
        nodes_spec = envelope.get("workflow", envelope.get("nodes"))
        if not isinstance(nodes_spec, dict) or not nodes_spec:
            raise WorkflowError(
                'body needs a non-empty "workflow" object mapping node '
                'names to {"process_list": ..., "after": [...]}')
        if len(nodes_spec) > self.max_nodes:
            raise WorkflowError(
                f"workflow has {len(nodes_spec)} nodes "
                f"(max_nodes={self.max_nodes})")
        priority = envelope.get("priority", 0)
        if not isinstance(priority, int) or isinstance(priority, bool):
            raise WorkflowError(f"priority must be an integer, got "
                                f"{priority!r}")
        workflow_id = envelope.get("workflow_id")
        if workflow_id is not None and not isinstance(workflow_id, str):
            raise WorkflowError(f"workflow_id must be a string, got "
                                f"{workflow_id!r}")
        metadata = envelope.get("metadata") or {}
        if not isinstance(metadata, dict):
            raise WorkflowError("metadata must be an object")

        # -- per-node validation + edge collection ----------------------
        names = list(nodes_spec)
        pls: dict[str, ProcessList] = {}
        edges: dict[str, list[str]] = {}
        for name in names:
            if not isinstance(name, str) or not _NODE_NAME.match(name):
                raise WorkflowError(
                    f"node name {name!r} is invalid (it becomes a job-id "
                    f"component: letters/digits/._- only)")
            node = nodes_spec[name]
            if not isinstance(node, dict) or "process_list" not in node:
                raise WorkflowError(
                    f'node {name!r} must be an object with a '
                    f'"process_list"')
            pl = node["process_list"]
            if not isinstance(pl, ProcessList):
                pl = from_spec(pl)
            pl.check()
            pls[name] = pl
            after = node.get("after") or []
            if not isinstance(after, (list, tuple)) or \
                    not all(isinstance(a, str) for a in after):
                raise WorkflowError(
                    f'node {name!r}: "after" must be a list of node '
                    f"names, got {after!r}")
            ups = list(dict.fromkeys(after))
            # upstream-result references imply edges too
            for e in pl.entries:
                ref = _entry_ref(e.params)
                if ref is not None and ref[0] not in ups:
                    ups.append(ref[0])
            for u in ups:
                if u == name:
                    raise WorkflowError(
                        f"node {name!r} depends on itself")
                if u not in nodes_spec:
                    raise WorkflowError(
                        f"node {name!r} references unknown node {u!r} "
                        f"(nodes: {sorted(names)})")
            edges[name] = ups
        order = toposort(edges)

        with self._lock:
            self._prune_locked()
            if workflow_id is None:
                workflow_id = f"wf-{next(self._seq):04d}"
            existing = self._groups.get(workflow_id)
            if existing is not None and not existing.all_terminal():
                raise ValueError(
                    f"workflow id {workflow_id!r} already active")

        # -- rewrite node-name references to full job ids ---------------
        jid = {n: f"{workflow_id}/{n}" for n in names}
        data_deps: dict[str, list[str]] = {n: [] for n in names}
        for name in names:
            for e in pls[name].entries:
                ref = _entry_ref(e.params)
                if ref is None:
                    continue
                from_node, dataset = ref
                data_deps[name].append(jid[from_node])
                if isinstance(e.params.get("data"), dict):
                    e.params["data"] = {"from_job": jid[from_node],
                                        "dataset": dataset}
                else:
                    e.params["from_job"] = jid[from_node]

        metadatas = []
        for name in order:
            md = dict(metadata)
            md["workflow"] = {"workflow_id": workflow_id, "node": name,
                              "after": list(edges[name])}
            metadatas.append(md)
        jobs = self.queue.submit_many(
            [pls[n] for n in order], priority=priority,
            job_ids=[jid[n] for n in order], metadatas=metadatas,
            afters=[[jid[u] for u in edges[n]] for n in order],
            data_deps=[data_deps[n] for n in order])
        group = WorkflowGroup(workflow_id, list(order), jobs,
                              {n: list(edges[n]) for n in order},
                              metadata=dict(metadata))
        with self._lock:
            self._groups[workflow_id] = group
            self.workflows_submitted += 1
            self.nodes_submitted += len(jobs)
        return group

    def _prune_locked(self) -> None:
        if self.max_history is None:
            return
        terminal = [g for g in self._groups.values() if g.all_terminal()]
        terminal.sort(key=lambda g: g.created_at)
        for g in terminal[:max(0, len(terminal) - self.max_history)]:
            del self._groups[g.workflow_id]

    # -- lookup ----------------------------------------------------------
    def group(self, workflow_id: str) -> WorkflowGroup:
        """Raises KeyError for an unknown (or pruned) workflow id."""
        with self._lock:
            return self._groups[workflow_id]

    def status(self, workflow_id: str, full: bool = True
               ) -> dict[str, Any]:
        return self.group(workflow_id).snapshot(full=full)

    def snapshot_all(self) -> list[dict[str, Any]]:
        """Summary snapshot of every retained group (``GET
        /workflows``)."""
        with self._lock:
            groups = sorted(self._groups.values(),
                            key=lambda g: g.created_at)
        return [g.snapshot(full=False) for g in groups]

    # -- traces -----------------------------------------------------------
    def trace(self, workflow_id: str,
              fetch_trace: Callable[[str], dict[str, Any]]
              ) -> dict[str, Any]:
        """Workflow-level trace (``GET /workflows/{id}/trace``): one
        linked document with each node's span timeline keyed by node
        name.  ``fetch_trace`` is the service's per-job trace resolver
        (live trace or spool), so a workflow trace survives queue
        eviction exactly as long as its node traces do."""
        g = self.group(workflow_id)
        nodes = {}
        for name, job in zip(g.nodes, g.jobs):
            try:
                nodes[name] = fetch_trace(job.job_id)
            except KeyError:
                nodes[name] = None
        return {"workflow_id": workflow_id, "state": g.state(),
                "edges": {n: list(u) for n, u in g.edges.items()},
                "nodes": nodes}

    # -- cancellation -----------------------------------------------------
    def cancel(self, workflow_id: str,
               cancel_job: Callable[[str], dict[str, Any]]
               ) -> dict[str, Any]:
        """Cancel every live node via ``cancel_job`` (the service's
        per-job cancel: queued AND leased jobs).  Queued downstream
        nodes cascade automatically when their upstream cancels, so
        cancelling in topological order converges in one pass."""
        g = self.group(workflow_id)
        cancelled, skipped = [], []
        for j in g.jobs:
            if j.state.terminal():
                skipped.append(j.job_id)
                continue
            try:
                out = cancel_job(j.job_id)
            except KeyError:          # evicted mid-loop
                skipped.append(j.job_id)
                continue
            (cancelled if out.get("cancelled") else skipped).append(
                j.job_id)
        return {"workflow_id": workflow_id, "state": g.state(),
                "cancelled": cancelled, "skipped": skipped}

    def stats(self) -> dict[str, Any]:
        """Counters for ``GET /stats``: groups retained/active plus
        lifetime ``workflows_submitted`` / ``nodes_submitted``."""
        with self._lock:
            groups = list(self._groups.values())
            return {"workflows_submitted": self.workflows_submitted,
                    "nodes_submitted": self.nodes_submitted,
                    "groups": len(groups),
                    "active": sum(1 for g in groups
                                  if not g.all_terminal())}
