import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape
× mesh) cell, print memory/cost analysis, and derive the roofline
terms.  The two lines above MUST stay first — jax locks the device
count on first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh pod                              # one cell
    ... --mesh both --out experiments/dryrun                     # default

Results are cached as JSON per cell; reruns skip completed cells unless
--force.
"""
import argparse
import json
import time
import traceback
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import (ARCH_IDS, SHAPES, all_cells, cell_supported,
                       get_config, input_specs)
from ..distributed.param_sharding import (batch_shardings, param_shardings,
                                          replicated)
from ..models import build_model, make_rules, use_rules
from ..models.model_zoo import Model
from ..optim import AdamWConfig, init_opt_state
from ..roofline.analysis import analyse, summarise
from ..training import make_serve_step, make_train_step
from .mesh import make_production_mesh

OUT_DIR = "experiments/dryrun"


def _tree_size_bytes(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree) if hasattr(l, "shape"))


def lower_cell(arch_id: str, shape_name: str, mesh, *,
               microbatch: int | None = None,
               remat_policy: str = "dots",
               moments: str = "fp32",
               sp: bool = True,
               seq_fallback: bool = False,
               moe_grouped: bool = False,
               param_dtype=None,
               rules_overrides: dict | None = None,
               serve_params: str = "train",
               donate: bool = True) -> dict:
    """Lower + compile one cell; return the dry-run record."""
    import dataclasses
    extra = {}
    if param_dtype is not None:
        extra["param_dtype"] = param_dtype
    cfg = dataclasses.replace(get_config(arch_id),
                              remat_policy=remat_policy,
                              seq_shard_fallback=seq_fallback,
                              moe_grouped=moe_grouped, **extra)
    spec = input_specs(arch_id, shape_name, cfg=cfg)
    model = build_model(cfg)
    overrides = dict(rules_overrides or {})
    if not sp:
        overrides["seq_sp"] = None
    rules = make_rules(mesh, overrides)
    t0 = time.time()

    with use_rules(rules), mesh:
        params_shape = jax.eval_shape(model.init, jax.random.key(0))
        p_sh = param_shardings(params_shape, mesh, mode=serve_params)

        if spec.kind == "train":
            opt_shape = jax.eval_shape(
                lambda p: init_opt_state(p, moments), params_shape)
            o_sh = param_shardings(opt_shape, mesh)
            b_sh = batch_shardings(spec.batch, mesh)
            opt_cfg = AdamWConfig(moments_dtype=moments)
            step = make_train_step(model, opt_cfg, microbatch=microbatch)
            jfn = jax.jit(step,
                          in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1) if donate else ())
            lowered = jfn.lower(params_shape, opt_shape, spec.batch)
            state_bytes = (_tree_size_bytes(params_shape) +
                           _tree_size_bytes(opt_shape))
        elif spec.kind == "prefill":
            def prefill(params, batch):
                return model.prefill(params, batch, spec.seq_len)
            b_sh = batch_shardings(spec.batch, mesh)
            jfn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            lowered = jfn.lower(params_shape, spec.batch)
            state_bytes = _tree_size_bytes(params_shape)
        else:  # decode
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(spec.global_batch, spec.seq_len))
            # cache shardings: lower+compile the (pure-constraint) cache
            # initialiser and read its output shardings — exercises the
            # same pattern-constraint logic the serving path uses.
            cache_init = jax.jit(
                lambda: model.init_cache(spec.global_batch, spec.seq_len))
            c_sh = cache_init.lower().compile().output_shardings
            from jax.sharding import NamedSharding, PartitionSpec
            c_sh = jax.tree.map(
                lambda s: s if isinstance(s, NamedSharding) and
                s.mesh.shape == mesh.shape
                else NamedSharding(mesh, PartitionSpec()), c_sh,
                is_leaf=lambda s: hasattr(s, "device_set"))
            b_sh = batch_shardings(spec.batch, mesh)
            serve = make_serve_step(model)
            jfn = jax.jit(serve,
                          in_shardings=(p_sh, b_sh["token"], c_sh),
                          out_shardings=(b_sh["token"], c_sh),
                          donate_argnums=(2,) if donate else ())
            lowered = jfn.lower(params_shape, spec.batch["token"],
                                cache_shape)
            state_bytes = (_tree_size_bytes(params_shape) +
                           _tree_size_bytes(cache_shape))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    n_dev = mesh.size
    # model flops: 6·N_active·D tokens for train (×3 for bwd already in 6ND);
    # 2·N_active per token forward-only for prefill/decode.
    n_active = cfg.active_param_count()
    if spec.kind == "train":
        tokens = spec.seq_len * spec.global_batch
        model_flops = 6.0 * n_active * tokens
    elif spec.kind == "prefill":
        tokens = spec.seq_len * spec.global_batch
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * spec.global_batch
    roof = analyse(cost, hlo, n_devices=n_dev, model_flops=model_flops)

    rec = {
        "arch": arch_id, "shape": shape_name, "kind": spec.kind,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "n_devices": n_dev,
        "seq_len": spec.seq_len, "global_batch": spec.global_batch,
        "params_total": cfg.param_count(),
        "params_active": n_active,
        "state_bytes_global": state_bytes,
        "state_bytes_per_device": state_bytes // n_dev,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate": mem.argument_size_in_bytes +
            mem.output_size_in_bytes + mem.temp_size_in_bytes -
            mem.alias_size_in_bytes,
        },
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "roofline": roof.to_json(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    return rec


HBM_PER_CHIP = 16 * 2**30      # v5e


def run_cells(cells, meshes: list[str], out_dir: str, force: bool,
              microbatch: int | None = None) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
        for arch, shape, ok, why in cells:
            tag = f"{arch}__{shape}__{mesh_name}"
            path = os.path.join(out_dir, tag + ".json")
            if not ok:
                print(f"SKIP {tag}: {why}")
                continue
            if os.path.exists(path) and not force:
                with open(path) as fh:
                    results.append(json.load(fh))
                print(f"CACHED {tag}")
                continue
            print(f"LOWER {tag} ...", flush=True)
            try:
                _, gb, kind = SHAPES[shape]
                mb = microbatch if kind == "train" else None
                if mb is None and kind == "train":
                    mb = 8
                remat, moments = "dots", "fp32"
                rec = lower_cell(arch, shape, mesh, microbatch=mb)
                # memory ladder: (1) more grad accumulation while the
                # per-chunk batch still divides the FULL dp extent
                # (pod x data), (2) tighter remat, (3) 8-bit moments.
                sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
                dp = sizes.get("data", 1) * sizes.get("pod", 1)
                while (kind == "train"
                       and rec["memory"]["peak_estimate"] > HBM_PER_CHIP):
                    if (gb // (mb * 2)) % dp == 0:
                        mb *= 2
                    elif remat == "dots":
                        remat = "nothing"
                    elif moments == "fp32":
                        moments = "int8"
                    else:
                        break
                    print(f"  over HBM "
                          f"({rec['memory']['peak_estimate'] / 2**30:.1f}"
                          f"GiB); retry microbatch={mb} remat={remat} "
                          f"moments={moments}", flush=True)
                    rec = lower_cell(arch, shape, mesh, microbatch=mb,
                                     remat_policy=remat, moments=moments)
                rec["microbatch"] = mb
                rec["remat_policy"] = remat
                rec["moments"] = moments
                rec["tag"] = tag
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                results.append(rec)
                r = rec["roofline"]
                print(f"  OK compile={rec['compile_s']}s "
                      f"mem/dev={rec['memory']['peak_estimate'] / 2**30:.2f}GiB "
                      f"compute={r['compute_s'] * 1e3:.1f}ms "
                      f"mem={r['memory_s'] * 1e3:.1f}ms "
                      f"coll={r['collective_s'] * 1e3:.1f}ms "
                      f"-> {r['bottleneck']}", flush=True)
            except Exception as e:
                print(f"  FAIL {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
                with open(os.path.join(out_dir, tag + ".FAIL"), "w") as fh:
                    fh.write(traceback.format_exc())
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + ["all"])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "pod2", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    if args.arch in (None, "all") and args.shape in (None, "all"):
        cells = all_cells(include_skipped=True)
    else:
        archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
        shapes = list(SHAPES) if args.shape in (None, "all") \
            else [args.shape]
        cells = []
        for a in archs:
            for s in shapes:
                ok, why = cell_supported(a, s)
                cells.append((a, s, ok, why))
    meshes = ["pod", "pod2"] if args.mesh == "both" else [args.mesh]
    results = run_cells(cells, meshes, args.out, args.force,
                        microbatch=args.microbatch)
    print(f"\n{len(results)} cells recorded in {args.out}")


if __name__ == "__main__":
    main()
