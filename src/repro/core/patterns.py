"""Data access patterns — the heart of the Savu design.

A *pattern* partitions the dimensions of an N-d dataset into

  * ``core`` dims  — delivered whole to a plugin (one "frame"),
  * ``slice`` dims — iterated over / parallelised across the mesh; the
    first slice dim is the fastest-changing one and the primary
    distribution axis.

On the TPU adaptation the slice dims are what gets sharded: the first
slice dim maps to the ``data`` mesh axis (optionally a dict maps further
slice/core dims to other axes, e.g. heads → ``model``).  The pattern is
the single source of truth for every ``PartitionSpec`` in the framework.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Mapping, Sequence

from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Standard pattern names from the paper (tomography) plus the LM-substrate
# names used by the model zoo.  Loaders may register new names freely —
# the framework only requires that equal names have equal core-dim counts
# within one dataset collection (checked in process_list validation).
PROJECTION = "PROJECTION"
SINOGRAM = "SINOGRAM"
SPECTRUM = "SPECTRUM"
DIFFRACTION = "DIFFRACTION"
VOLUME_XZ = "VOLUME_XZ"
TIMESERIES = "TIMESERIES"
# LM substrate patterns
BATCH = "BATCH"
SEQUENCE = "SEQUENCE"
TOKENS = "TOKENS"
EXPERT = "EXPERT"
HEADS = "HEADS"


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A named core/slice partition of an ``ndim``-dimensional dataset.

    ``shard_axes`` optionally maps dim index -> mesh axis name for dims
    that should be distributed (beyond the default first-slice-dim ->
    ``data`` rule).  ``None`` values mean "local / replicated".
    """

    name: str
    core_dims: tuple[int, ...]
    slice_dims: tuple[int, ...]
    shard_axes: Mapping[int, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        dims = tuple(self.core_dims) + tuple(self.slice_dims)
        if len(set(dims)) != len(dims):
            raise ValueError(
                f"pattern {self.name!r}: core and slice dims overlap: "
                f"core={self.core_dims} slice={self.slice_dims}")
        if sorted(dims) != list(range(len(dims))):
            raise ValueError(
                f"pattern {self.name!r}: dims must cover 0..ndim-1 exactly, "
                f"got core={self.core_dims} slice={self.slice_dims}")
        for d in self.shard_axes:
            if d not in dims:
                raise ValueError(
                    f"pattern {self.name!r}: shard axis for unknown dim {d}")

    # ------------------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.core_dims) + len(self.slice_dims)

    def dim_type(self, dim: int) -> str:
        """'core' | 'slice' (first slice dim) | 'other' (remaining)."""
        if dim in self.core_dims:
            return "core"
        if self.slice_dims and dim == self.slice_dims[0]:
            return "slice"
        if dim in self.slice_dims:
            return "other"
        raise ValueError(f"dim {dim} not in pattern {self.name!r}")

    def frame_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        self._check_shape(shape)
        return tuple(shape[d] for d in self.core_dims)

    def n_frames(self, shape: Sequence[int]) -> int:
        self._check_shape(shape)
        return math.prod(shape[d] for d in self.slice_dims) if self.slice_dims else 1

    def _check_shape(self, shape: Sequence[int]) -> None:
        if len(shape) != self.ndim:
            raise ValueError(
                f"pattern {self.name!r} is {self.ndim}-d but shape {shape} "
                f"is {len(shape)}-d")

    # ------------------------------------------------------------------
    # Frame-major view: transpose order that puts slice dims first (in
    # slice_dims order, first = fastest-changing so it is iterated last in
    # row-major terms; we put it *last among the slice dims* so that
    # flattening gives frames in the paper's order).
    def frame_major_axes(self) -> tuple[int, ...]:
        slow_to_fast = tuple(reversed(self.slice_dims))
        return slow_to_fast + tuple(self.core_dims)

    def to_frames(self, array, shape: Sequence[int] | None = None):
        """Reshape ``array`` -> (n_frames, *frame_shape).  Pure jnp/np ok."""
        shape = tuple(array.shape) if shape is None else tuple(shape)
        self._check_shape(shape)
        perm = self.frame_major_axes()
        arr = array.transpose(perm)
        nf = self.n_frames(shape)
        return arr.reshape((nf,) + self.frame_shape(shape))

    def from_frames(self, frames, shape: Sequence[int]):
        """Inverse of :meth:`to_frames` for an output dataset of ``shape``."""
        shape = tuple(shape)
        self._check_shape(shape)
        perm = self.frame_major_axes()
        fm_shape = tuple(shape[d] for d in perm)
        arr = frames.reshape(fm_shape)
        inv = [0] * len(perm)
        for i, p in enumerate(perm):
            inv[p] = i
        return arr.transpose(inv)

    def frame_slices(self, shape: Sequence[int], m: int = 1
                     ) -> Iterator[tuple[slice, ...]]:
        """Yield index tuples selecting ``m`` frames at a time.

        Frames advance fastest along ``slice_dims[0]`` (paper §III.C).
        Groups of m are only contiguous along the first slice dim; if m
        does not divide it, the tail group is smaller.
        """
        self._check_shape(shape)
        if not self.slice_dims:
            yield tuple(slice(None) for _ in shape)
            return
        first = self.slice_dims[0]
        rest = self.slice_dims[1:]
        rest_sizes = [shape[d] for d in rest]
        for rest_idx in _ndindex(rest_sizes):
            for start in range(0, shape[first], m):
                idx: list = [slice(None)] * len(shape)
                idx[first] = slice(start, min(start + m, shape[first]))
                for d, i in zip(rest, rest_idx):
                    idx[d] = slice(i, i + 1)
                yield tuple(idx)

    # ------------------------------------------------------------------
    # Sharding
    def to_pspec(self, data_axis: str | None = "data") -> PartitionSpec:
        """PartitionSpec for the canonical (un-transposed) dataset layout.

        Default rule: first slice dim -> ``data_axis``; any explicit
        ``shard_axes`` entries override/extend.  Core dims replicate.
        """
        spec: list = [None] * self.ndim
        if self.slice_dims and data_axis is not None:
            spec[self.slice_dims[0]] = data_axis
        for d, ax in self.shard_axes.items():
            spec[d] = ax
        return PartitionSpec(*spec)

    def to_sharding(self, mesh: Mesh, data_axis: str | None = "data"
                    ) -> NamedSharding:
        return NamedSharding(mesh, self.to_pspec(data_axis))

    def with_shard_axes(self, shard_axes: Mapping[int, str]) -> "Pattern":
        return dataclasses.replace(self, shard_axes=dict(shard_axes))


def _ndindex(sizes: Sequence[int]) -> Iterator[tuple[int, ...]]:
    if not sizes:
        yield ()
        return
    total = math.prod(sizes)
    for flat in range(total):
        idx = []
        rem = flat
        for s in reversed(sizes):
            idx.append(rem % s)
            rem //= s
        yield tuple(reversed(idx))


# ----------------------------------------------------------------------
# Convenience constructors used by loaders (axis-label based).
def pattern_from_labels(name: str, axis_labels: Sequence[str],
                        core: Sequence[str], slice_: Sequence[str],
                        shard_axes: Mapping[str, str] | None = None) -> Pattern:
    """Build a Pattern from axis labels rather than dim indices."""
    index = {lab: i for i, lab in enumerate(axis_labels)}
    missing = [l for l in tuple(core) + tuple(slice_) if l not in index]
    if missing:
        raise ValueError(f"labels {missing} not in axis_labels {axis_labels}")
    sa = {index[k]: v for k, v in (shard_axes or {}).items()}
    return Pattern(name,
                   tuple(index[l] for l in core),
                   tuple(index[l] for l in slice_),
                   sa)
