"""llama4-maverick-400b-a17b [moe] — MoE top-1, early fusion
[hf:meta-llama/Llama-4-*; unverified].

48L d_model=5120 40H (GQA kv=8) dense d_ff=8192 vocab=202048,
MoE 128 experts top-1 + 1 shared expert, alternating dense/MoE layers
(moe_every=2, the released interleave pattern).
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "llama4-maverick-400b-a17b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    n_experts=128, top_k=1, moe_d_ff=8192, moe_every=2,
    n_shared_experts=1, capacity_factor=1.25, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=271, head_dim=16,
    n_experts=4, top_k=1, moe_d_ff=96, moe_every=2,
    n_shared_experts=1, dtype=jnp.float32, remat=False)
