"""Feed-forward blocks: SwiGLU (llama-family default) and GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, split_keys
from .sharding import get_rules


def init_mlp(key, d_model: int, d_ff: int, param_dtype, gated: bool = True):
    ks = split_keys(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, (d_model, d_ff), param_dtype),
        "w_down": dense_init(ks[1], d_ff, (d_ff, d_model), param_dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, (d_model, d_ff),
                                 param_dtype)
    return p


def mlp_fwd(params, x: jnp.ndarray, dtype, activation: str = "silu"
            ) -> jnp.ndarray:
    """x (..., d) -> (..., d); SwiGLU when w_gate present, else GELU."""
    r = get_rules()
    lead = ("batch", "seq") if x.ndim == 3 else ("batch",) * (x.ndim - 1)
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dtype))
    up = r.constrain(up, *lead, "ffn_act")
    if "w_gate" in params:
        gate = jnp.einsum("...d,df->...f", x,
                          params["w_gate"].astype(dtype))
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    else:
        if activation == "gelu":
            act = jax.nn.gelu(up.astype(jnp.float32)).astype(dtype)
        else:
            act = jax.nn.silu(up.astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("...f,fd->...d", act, params["w_down"].astype(dtype))
    return r.constrain(out, *lead, "embed_act")
