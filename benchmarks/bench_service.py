"""Service-layer benchmark: jobs/sec for 1 vs many concurrent pipelines,
the compiled-plugin cache effect — resubmitting an identical process
list must skip every jax.jit retrace, so the cache-hit job's wall time
sits well under the first (cold) job's — multi-worker-process
throughput through the broker (``--workers-remote N``), and parameter
sweeps (``--sweep``): an N-point gang-batched sweep vs N sequential
solo jobs on a warm cache.

Standalone:   PYTHONPATH=src python benchmarks/bench_service.py
CI smoke:     PYTHONPATH=src python benchmarks/bench_service.py \\
                  --smoke --sweep --workers-remote 2
Harness:      python -m benchmarks.run   (row prefix ``service_``)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
from jax.sharding import Mesh

from repro.service import (CompileCache, JobQueue, PipelineClient,
                           PipelineScheduler, PipelineService,
                           SweepManager)
from repro.service.worker import spawn_local_workers
from repro.core import ShardedTransport
from repro.tomo import standard_chain

N_DET, N_ANGLES, N_ROWS = 48, 48, 2


def _chain(seed: int):
    return standard_chain(n_det=N_DET, n_angles=N_ANGLES, n_rows=N_ROWS,
                          seed=seed)


def _mk_sched(n_workers: int, cache: CompileCache, batch: bool = False
              ) -> tuple[JobQueue, PipelineScheduler]:
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    q = JobQueue()
    sched = PipelineScheduler(
        q, n_workers=n_workers, compile_cache=cache,
        batch_identical=batch, batch_max=8,
        transport_factory=lambda job: ShardedTransport(
            mesh, donate=not batch, compile_cache=cache))
    return q, sched


def _run_jobs(q, sched, seeds) -> list:
    jobs = [q.submit(_chain(s)) for s in seeds]
    sched.start()
    assert sched.drain(timeout=600), "benchmark jobs timed out"
    sched.shutdown()
    bad = [j for j in jobs if j.state.value != "done"]
    assert not bad, [j.snapshot() for j in bad]
    return jobs

def run(report, smoke: bool = False):
    # -- compile-cache: cold first job vs identical resubmission -------
    cache = CompileCache()
    q, sched = _mk_sched(1, cache)
    (first,) = _run_jobs(q, sched, [0])
    q2, sched2 = _mk_sched(1, cache)
    (resub,) = _run_jobs(q2, sched2, [1])     # same chain, new dataset
    st = cache.stats()
    report("service_first_job", first.wall * 1e6,
           f"cold: {st['misses']} plugin compiles")
    report("service_cache_hit_job", resub.wall * 1e6,
           f"hits={st['hits']} speedup={first.wall / resub.wall:.1f}x "
           f"(MUST be < first-job wall)")
    assert resub.wall < first.wall, (
        f"cache-hit job ({resub.wall:.2f}s) not faster than cold job "
        f"({first.wall:.2f}s)")

    # -- throughput: 1 worker vs many, warmed cache --------------------
    n_jobs = 3 if smoke else 6
    base = None
    for workers in ((1, 2) if smoke else (1, 2, 4)):
        qn, schedn = _mk_sched(workers, cache)
        jobs = _run_jobs(qn, schedn, range(2, 2 + n_jobs))
        wall = max(j.finished_at for j in jobs) - min(j.started_at
                                                      for j in jobs)
        jps = n_jobs / wall
        base = base or jps
        report(f"service_throughput_w{workers}", wall / n_jobs * 1e6,
               f"{jps:.2f} jobs/s ({jps / base:.2f}x vs 1 worker)")
    if smoke:
        return

    # -- gang batching: N jobs, one compiled call per plugin step ------
    gcache = CompileCache()
    qg, schedg = _mk_sched(1, gcache, batch=True)
    jobs = _run_jobs(qg, schedg, range(20, 24))
    wall = max(j.finished_at for j in jobs) - min(j.started_at
                                                  for j in jobs)
    report("service_gang_4jobs", wall / 4 * 1e6,
           f"{4 / wall:.2f} jobs/s, {schedg.gangs_run} gang(s), "
           f"{gcache.stats()['misses']} compiles total")

    # -- HTTP round-trip: same warmed cache, but submit/poll/result ----
    # over the wire — measures the front end's overhead vs in-process
    # (spec serialisation + JSON + npy body per job)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    svc = PipelineService(
        n_workers=2, compile_cache=cache,
        transport_factory=lambda job: ShardedTransport(
            mesh, donate=True, compile_cache=cache))
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}")
    try:
        import time
        t0 = time.perf_counter()
        ids = [client.submit(_chain(s)) for s in range(30, 30 + n_jobs)]
        for jid in ids:
            snap = client.wait(jid, timeout=600, poll=0.02)
            assert snap["state"] == "done", snap
            client.result(jid)
        wall = time.perf_counter() - t0
    finally:
        svc.stop()
    report("service_http_roundtrip", wall / n_jobs * 1e6,
           f"{n_jobs / wall:.2f} jobs/s over HTTP (submit+poll+result, "
           f"warmed cache; compare service_throughput_w2)")


def _sweep_axis(n: int) -> dict:
    return {"plugin": "sinogram_filter", "param": "cutoff",
            "values": [float(v) for v in np.linspace(0.4, 1.0, n)]}


def _sweep_chain(seed: int, cutoff: float):
    pl = _chain(seed)
    for e in pl.entries:
        if e.cls.name == "sinogram_filter":
            e.params["cutoff"] = cutoff
    return pl


def run_sweep(report, smoke: bool = False) -> None:
    """Parameter tuning: one N-point sweep (gang-batched, one compiled
    call per plugin step over all variants) vs N sequential solo jobs —
    both on a warm cache.  The sweep must land well above N/2x."""
    n = 4 if smoke else 8
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    cache = CompileCache()

    def mk(batch: bool):
        q = JobQueue()
        sched = PipelineScheduler(
            q, n_workers=1, compile_cache=cache,
            batch_identical=batch, batch_max=n,
            transport_factory=lambda job: ShardedTransport(
                mesh, donate=False, compile_cache=cache))
        return q, sched

    def envelope(seed: int):
        return {"process_list": _chain(seed), "sweep": _sweep_axis(n)}

    # warm BOTH program families: solo per-plugin jits and the batched
    # (vmapped) gang programs
    q, sched = mk(False)
    _run_jobs(q, sched, [0])
    q, sched = mk(True)
    mgr = SweepManager(q)
    sched.start()
    mgr.submit(envelope(1))
    assert q.wait_all(timeout=600)
    sched.shutdown()

    # timed: N solo jobs, strictly sequential (submit -> drain -> next)
    q, sched = mk(False)
    sched.start()
    t0 = time.perf_counter()
    for v in _sweep_axis(n)["values"]:
        q.submit(_sweep_chain(2, v))
        assert q.wait_all(timeout=600)
    t_seq = time.perf_counter() - t0
    sched.shutdown()

    # timed: ONE sweep over the same values (atomic admission -> gang)
    q, sched = mk(True)
    mgr = SweepManager(q)
    sched.start()
    t0 = time.perf_counter()
    g = mgr.submit(envelope(2))
    assert q.wait_all(timeout=600)
    t_sweep = time.perf_counter() - t0
    sched.shutdown()
    bad = [j.job_id for j in g.jobs if j.state.value != "done"]
    assert not bad, bad
    speedup = t_seq / t_sweep
    report("service_sweep_gang", t_sweep / n * 1e6,
           f"{n}-pt sweep {speedup:.1f}x vs {n} sequential solo "
           f"(target >={n / 2:.0f}x), {sched.gangs_run} gang(s)")


def run_sweep_remote(report, n_workers: int, smoke: bool = False) -> None:
    """A sweep through the broker: the variants gang-lease across
    ``n_workers`` sharded worker subprocesses, each batch gang-executing
    worker-side (``run_plugin_batch``); the broker streams the stacked
    result back."""
    n = 4 if smoke else 8
    svc = PipelineService(workers_remote=True, lease_ttl=60.0,
                          max_pending=n + 1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    workers = spawn_local_workers(
        url, n_workers, transport="sharded", poll=0.05,
        max_batch=max(1, n // n_workers))
    client = PipelineClient(url, timeout=120.0)
    try:
        t0 = time.perf_counter()
        reply = client.sweep(_chain(60), _sweep_axis(n),
                             metric="sharpness")
        snap = client.wait_sweep(reply["sweep_id"], timeout=600,
                                 poll=0.05)
        assert snap["state"] == "done", snap
        stacked = client.sweep_result(reply["sweep_id"])
        wall = time.perf_counter() - t0
        assert stacked.shape[0] == n, stacked.shape
        best = snap["best_variant"]["values"]
        report(f"service_sweep_remote_w{n_workers}", wall / n * 1e6,
               f"{n}-pt sweep over {n_workers} gang workers, stacked "
               f"{'x'.join(map(str, stacked.shape))}, best={best}")
    finally:
        for p in workers:
            p.terminate()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


def run_remote(report, n_workers: int, smoke: bool = False) -> None:
    """Multi-worker-PROCESS throughput through the broker: one queue,
    ``n_workers`` subprocesses pulling leases over HTTP (compare
    ``service_throughput_w{N}``, which is threads in one process)."""
    n_jobs = 4 if smoke else 8
    svc = PipelineService(workers_remote=True, lease_ttl=30.0,
                          max_pending=n_jobs + 1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    workers = spawn_local_workers(url, n_workers, transport="inmemory",
                                  poll=0.05)
    client = PipelineClient(url)
    try:
        t0 = time.perf_counter()
        ids = [client.submit(_chain(s)) for s in range(50, 50 + n_jobs)]
        for jid in ids:
            snap = client.wait(jid, timeout=600, poll=0.05)
            assert snap["state"] == "done", snap
            client.result(jid)
        wall = time.perf_counter() - t0
        st = client.stats()
        busy = sum(1 for w in st["workers"].values() if w["jobs_done"])
        report(f"service_remote_w{n_workers}", wall / n_jobs * 1e6,
               f"{n_jobs / wall:.2f} jobs/s over {n_workers} worker "
               f"processes ({busy} took jobs, "
               f"{st['jobs_requeued']} requeues)")
    finally:
        for p in workers:
            p.terminate()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized problem + reduced row set")
    ap.add_argument("--workers-remote", type=int, default=0, metavar="N",
                    help="add a broker row with N worker subprocesses")
    ap.add_argument("--sweep", action="store_true",
                    help="add the parameter-sweep rows (gang-batched "
                         "sweep vs sequential solo; with "
                         "--workers-remote also a remote sweep row)")
    args = ap.parse_args(argv)
    global N_DET, N_ANGLES, N_ROWS
    if args.smoke:
        N_DET, N_ANGLES, N_ROWS = 24, 24, 1
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, smoke=args.smoke)
    if args.sweep:
        run_sweep(report, smoke=args.smoke)
    if args.workers_remote:
        run_remote(report, args.workers_remote, smoke=args.smoke)
        if args.sweep:
            run_sweep_remote(report, args.workers_remote,
                             smoke=args.smoke)


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
