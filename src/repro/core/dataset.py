"""DataSet — the framework's named, pattern-carrying array handle.

Mirrors the paper's ``Data`` object: every dataset must carry a link to a
data source (``backing``), a name, a shape, axis labels and data-access
patterns; a free-form ``metadata`` dict carries physical units, geometry,
etc.  ``in`` vs ``out`` status is a property of where the dataset sits in
the processing chain (framework.py), not of the object itself.

The backing is deliberately loose — loaders are *lazy* (paper §III.F.2):
a dataset may be backed by nothing but a ShapeDtypeStruct until the first
plugin touches it, by a numpy array, a jax.Array (possibly sharded over
the production mesh), or a chunked file (transport.ChunkedFile).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from .patterns import Pattern, pattern_from_labels


@dataclasses.dataclass
class DataSet:
    name: str
    shape: tuple[int, ...]
    dtype: Any
    axis_labels: tuple[str, ...]
    patterns: dict[str, Pattern] = dataclasses.field(default_factory=dict)
    metadata: dict[str, Any] = dataclasses.field(default_factory=dict)
    #: None (unpopulated out_dataset), np.ndarray / jax.Array (materialised),
    #: a zero-arg callable (lazy loader thunk), or a transport handle.
    backing: Any = None
    #: provenance: which plugin produced it ('' for loader-created)
    produced_by: str = ""
    #: streaming (arrival-driven) extent: how many slots along
    #: ``stream_axis`` hold real data.  None means the dataset is
    #: complete-on-open (the batch assumption every transport makes).
    available_extent: int | None = None
    #: axis label the dataset grows along while streaming (None: static)
    stream_axis: str | None = None

    def __post_init__(self):
        self.shape = tuple(int(s) for s in self.shape)
        self.axis_labels = tuple(self.axis_labels)
        if len(self.axis_labels) != len(self.shape):
            raise ValueError(
                f"dataset {self.name!r}: {len(self.axis_labels)} axis labels "
                f"for {len(self.shape)}-d shape {self.shape}")

    # ------------------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def label_index(self, label: str) -> int:
        try:
            return self.axis_labels.index(label)
        except ValueError:
            raise KeyError(
                f"dataset {self.name!r} has no axis {label!r} "
                f"(labels: {self.axis_labels})") from None

    # ------------------------------------------------------------------
    def add_pattern(self, name: str, *, core: Sequence[str],
                    slice_: Sequence[str],
                    shard_axes: Mapping[str, str] | None = None) -> Pattern:
        """Register a pattern by axis *labels* (the paper's add_pattern)."""
        pat = pattern_from_labels(name, self.axis_labels, core, slice_,
                                  shard_axes)
        self.patterns[name] = pat
        return pat

    def add_pattern_by_dims(self, name: str, *, core_dims: Sequence[int],
                            slice_dims: Sequence[int],
                            shard_axes: Mapping[int, str] | None = None
                            ) -> Pattern:
        pat = Pattern(name, tuple(core_dims), tuple(slice_dims),
                      dict(shard_axes or {}))
        if pat.ndim != self.ndim:
            raise ValueError(
                f"pattern {name!r} covers {pat.ndim} dims, dataset "
                f"{self.name!r} has {self.ndim}")
        self.patterns[name] = pat
        return pat

    def get_pattern(self, name: str) -> Pattern:
        if name not in self.patterns:
            raise KeyError(
                f"dataset {self.name!r} has no pattern {name!r} "
                f"(available: {sorted(self.patterns)})")
        return self.patterns[name]

    # ------------------------------------------------------------------
    def materialise(self):
        """Resolve lazy backing to an array (loaders are lazy, paper §III.F.2)."""
        if self.backing is None:
            raise RuntimeError(f"dataset {self.name!r} has no data yet")
        if callable(self.backing) and not hasattr(self.backing, "shape"):
            self.backing = self.backing()
        return self.backing

    @property
    def is_populated(self) -> bool:
        return self.backing is not None

    def like(self, name: str | None = None, *, shape=None, dtype=None,
             axis_labels=None, patterns: bool = True) -> "DataSet":
        """Template a new (empty) dataset from this one — used by plugin
        ``setup`` to describe out_datasets."""
        new = DataSet(
            name=name or self.name,
            shape=tuple(shape) if shape is not None else self.shape,
            dtype=dtype if dtype is not None else self.dtype,
            axis_labels=tuple(axis_labels) if axis_labels is not None
            else self.axis_labels,
            metadata=dict(self.metadata),
        )
        if patterns and new.shape == self.shape:
            new.patterns = dict(self.patterns)
        return new

    def __repr__(self):
        state = "populated" if self.is_populated else "empty"
        return (f"DataSet({self.name!r}, shape={self.shape}, "
                f"dtype={np.dtype(self.dtype).name}, "
                f"patterns={sorted(self.patterns)}, {state})")
