"""Pure-jnp oracle: fused dark/flat-field correction + linearisation.

corrected = clip((raw - dark) / (flat - dark), eps, hi)
out       = -log(corrected)

This is the first plugin of every full-field chain (paper §II.A:
"a simple correction, linearisation").
"""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-6
HI = 10.0  # transmission clip ceiling (dead/hot pixels)


def correct_ref(raw: jnp.ndarray, dark: jnp.ndarray, flat: jnp.ndarray,
                eps: float = EPS, hi: float = HI) -> jnp.ndarray:
    raw = raw.astype(jnp.float32)
    dark = dark.astype(jnp.float32)
    flat = flat.astype(jnp.float32)
    denom = jnp.maximum(flat - dark, eps)
    trans = jnp.clip((raw - dark) / denom, eps, hi)
    return -jnp.log(trans)
