"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --smoke --steps 100 --batch 8 --seq 128

Full-size configs target the production mesh (run under the dry-run's
XLA device-count override or on real hardware); --smoke runs the
reduced config end-to-end on whatever devices exist.  Includes
checkpoint/restart (resumes from the latest step automatically),
straggler monitoring and the Savu profiler.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from ..configs import ARCH_IDS, get_config, smoke_batch
from ..distributed import CheckpointManager, StragglerMonitor
from ..distributed.param_sharding import batch_shardings, param_shardings
from ..models import build_model, make_rules, use_rules
from ..optim import AdamWConfig, init_opt_state
from ..training import make_train_step
from .mesh import make_host_mesh


def make_batches(cfg, batch: int, seq: int, seed: int):
    """LM data pipeline: deterministic + restart-safe (pure function of
    the step index — resume replays the identical remaining stream)."""
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        from ..data import token_stream

        def at_step(step: int):
            return token_stream(cfg.vocab, batch, seq, seed=seed,
                                step=step)
        return at_step

    def at_step(step: int):
        return smoke_batch(cfg, batch=batch, seq=seq, seed=seed + step)

    return at_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="out/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                          total_steps=args.steps)

    with use_rules(make_rules(mesh)), mesh:
        params = model.init(jax.random.key(0))
        opt_state = init_opt_state(params)
        p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
        o_sh = param_shardings(jax.eval_shape(lambda: opt_state), mesh)
        step_fn = jax.jit(
            make_train_step(model, opt_cfg, microbatch=args.microbatch),
            in_shardings=(p_sh, o_sh, None),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))

        cm = CheckpointManager(args.ckpt_dir, keep=3)
        start = 0
        if cm.latest_step() is not None:
            (restored, man) = cm.restore({"params": params,
                                          "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            start = man["step"] + 1
            print(f"resumed from step {man['step']}")

        batches = make_batches(cfg, args.batch, args.seq, seed=1234)
        mon = StragglerMonitor(
            on_warn=lambda e: print(f"  [straggler] step {e.step} "
                                    f"{e.ratio:.1f}x median"))
        t_start = time.time()
        for step in range(start, args.steps):
            mon.start_step(step)
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batches(step))
            jax.block_until_ready(metrics["loss"])
            mon.end_step()
            if step % args.log_every == 0 or step == args.steps - 1:
                toks = args.batch * args.seq
                dt = (time.time() - t_start) / max(1, step - start + 1)
                print(f"step {step:5d}  loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"{toks / dt:.0f} tok/s")
            if step % args.ckpt_every == args.ckpt_every - 1:
                cm.save(step, {"params": params, "opt": opt_state},
                        extra={"loss": float(metrics["loss"])})
        cm.save(args.steps - 1, {"params": params, "opt": opt_state},
                blocking=True)
        print(f"done: {args.steps - start} steps in "
              f"{time.time() - t_start:.1f}s; checkpoints in "
              f"{args.ckpt_dir}")


if __name__ == "__main__":
    main()
