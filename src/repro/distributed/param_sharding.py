"""Parameter/cache sharding assignment for the production mesh.

Name-aware rules for the known module layouts (attention, MLP, MoE,
embeddings, SSM) with a generic largest-dims fallback, all divisibility-
checked.  The result feeds jit in_shardings for the dry-run and the
real launcher; moments inherit parameter shardings by construction.
"""
from __future__ import annotations

from typing import Any

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

# name -> per-dim logical spec, counted FROM THE TRAILING dims (stacked
# layer dims in front are replicated automatically).
_NAME_RULES: dict[str, tuple[str | None, ...]] = {
    "embed": ("model", "data"),            # (vocab, d_model)
    "unembed": ("model", "data"),
    "wq": ("data", "model", None),         # (d, H, hd)
    "wk": ("data", "model", None),         # kv heads: divisibility-gated
    "wv": ("data", "model", None),
    "wo": ("model", None, "data"),         # (H, hd, d)
    "w_up": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_down": ("model", "data"),
    "router": ("data", None),              # (d, E): replicate experts dim
    "w_in": ("data", "model"),             # mamba in-proj
    "w_out": ("model", "data"),
    "w_if": ("data", "model"),
    "w_o": ("data", "model"),
    "w_gates": ("data", "model"),
    "r_gates": (None, None, None),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
}
# MoE stacked expert weights: (E, d, ff) / (E, ff, d) — expert dim first
_MOE_RULES = {
    "w_gate": (("pod", "model"), "data", None),
    "w_up": (("pod", "model"), "data", None),
    "w_down": (("pod", "model"), None, "data"),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _gate(mesh: Mesh, dim: int, axis: str | None) -> str | None:
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _key_name(k) -> str:
    if isinstance(k, DictKey):
        return str(k.key)
    if isinstance(k, SequenceKey):
        return str(k.idx)
    if isinstance(k, GetAttrKey):
        return str(k.name)
    return str(k)


def spec_for(path: tuple, shape: tuple[int, ...], mesh: Mesh
             ) -> PartitionSpec:
    names = [_key_name(k) for k in path]
    leaf = names[-1] if names else ""
    in_moe = "moe" in names
    rules = None
    if in_moe and leaf in _MOE_RULES:
        rules = _MOE_RULES[leaf]
    elif leaf in _NAME_RULES:
        rules = _NAME_RULES[leaf]

    nd = len(shape)
    spec: list = [None] * nd
    if rules is not None and nd >= len(rules):
        off = nd - len(rules)
        used = set()
        for i, want in enumerate(rules):
            if isinstance(want, tuple):
                cands = tuple(c for c in want if c in mesh.axis_names
                              and c not in used)
                extent = 1
                for c in cands:
                    extent *= _axis_size(mesh, c)
                if cands and extent > 1 and shape[off + i] % extent == 0:
                    spec[off + i] = cands if len(cands) > 1 else cands[0]
                    used.update(cands)
                continue
            ax = _gate(mesh, shape[off + i], want)
            if ax and ax not in used:
                spec[off + i] = ax
                used.add(ax)
        return PartitionSpec(*spec)

    # fallback: shard the two largest trailing dims over data, then model
    order = sorted(range(nd), key=lambda i: -shape[i])
    used = set()
    for i in order:
        if shape[i] < 2:
            continue
        for ax in ("data", "model"):
            if ax in used:
                continue
            if _gate(mesh, shape[i], ax):
                spec[i] = ax
                used.add(ax)
                break
        if len(used) == 2:
            break
    return PartitionSpec(*spec)


def param_shardings(params_shape: Any, mesh: Mesh,
                    mode: str = "train") -> Any:
    """Map a pytree of ShapeDtypeStruct/arrays to NamedShardings.

    mode='train': 2-D (FSDP over data × TP over model) — minimum state
    memory; the per-layer weight all-gather amortises over the batch.
    mode='serve': TP-only (no data/FSDP dim) — decode batches are too
    small to amortise weight gathers (measured: 88 per-layer f32 weight
    AGs dominate granite-34b decode; §Perf B3), so weights replicate
    across `data` and only split over `model`.
    """

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, PartitionSpec())
        spec = spec_for(path, shape, mesh)
        if mode == "serve":
            spec = PartitionSpec(*[
                None if s == "data" else
                (tuple(a for a in s if a != "data") or None)
                if isinstance(s, tuple) else s
                for s in spec])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def replicated(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()),
                        tree)


def batch_shardings(batch_shape: Any, mesh: Mesh,
                    axis: str = "data") -> Any:
    """Shard dim0 (global batch) of every batch leaf over data (+pod)."""
    axes = [a for a in ("pod", axis) if a in mesh.axis_names]

    def assign(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, PartitionSpec())
        extent = int(np.prod([_axis_size(mesh, a) for a in axes]))
        first = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)
        if shape[0] % max(extent, 1) == 0 and extent > 1:
            return NamedSharding(mesh,
                                 PartitionSpec(first,
                                               *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, PartitionSpec())

    return jax.tree.map(assign, batch_shape)
