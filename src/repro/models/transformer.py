"""Decoder-only transformer LM (dense + MoE variants).

Layer stacking: parameters carry a leading ``layers`` dim and the
forward pass is a single ``lax.scan`` over it — compile time and HLO
size are O(1) in depth (essential for the 88/94-layer dry-runs).
MoE interleaving (llama4's alternate dense/MoE) is expressed by
scanning over *groups* of ``moe_every`` layers so the stacked params
stay homogeneous within each scan.

Remat: each scan step is wrapped in jax.checkpoint with a
dots-saveable policy so the backward pass recomputes cheap elementwise
work but keeps matmul outputs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import _qkv, attention_decode, attention_fwd, init_attention
from .common import ModelConfig, split_keys
from .kernels_glue import flash_attention
from .layers import embed_tokens, init_embedding, rms_norm, unembed
from .mlp import init_mlp, mlp_fwd
from .moe import init_moe, moe_fwd
from .remat import _remat_policy
from .sharding import get_rules, sp_residual


# ----------------------------------------------------------------------
def _group_structure(cfg: ModelConfig) -> tuple[int, list[str]]:
    """(n_groups, sublayer kinds per group).  kinds: 'dense' | 'moe'."""
    if not cfg.is_moe or cfg.moe_every == 0:
        return cfg.n_layers, ["dense"]
    g = cfg.moe_every
    assert cfg.n_layers % g == 0, (cfg.n_layers, g)
    kinds = ["dense"] * (g - 1) + ["moe"]
    return cfg.n_layers // g, kinds


def _init_group(key, cfg: ModelConfig):
    _, kinds = _group_structure(cfg)
    ks = split_keys(key, len(kinds))
    subs = []
    for kk, kind in zip(ks, kinds):
        k1, k2 = split_keys(kk, 2)
        sub = {
            "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "attn": init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
        }
        if kind == "moe":
            sub["moe"] = init_moe(k2, cfg)
        else:
            sub["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff,
                                  cfg.param_dtype)
        subs.append(sub)
    return tuple(subs)


def init_lm(key, cfg: ModelConfig) -> dict:
    n_groups, _ = _group_structure(cfg)
    k_emb, k_layers, k_out = split_keys(key, 3)
    layer_keys = jax.random.split(k_layers, n_groups)
    layers = jax.vmap(lambda k: _init_group(k, cfg))(layer_keys)
    params = {
        "embed": init_embedding(k_emb, cfg),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(k_out, cfg)
    return params


# ----------------------------------------------------------------------
def _ffn(sub: dict, x: jnp.ndarray, cfg: ModelConfig, kind: str
         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = rms_norm(x, sub["ln2"].astype(cfg.dtype), cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_fwd(sub["moe"], h, cfg)
    else:
        y, aux = mlp_fwd(sub["mlp"], h, cfg.dtype), jnp.zeros((),
                                                              jnp.float32)
    return x + y, aux


def lm_forward(params: dict, cfg: ModelConfig, *,
               tokens: jnp.ndarray | None = None,
               embeds: jnp.ndarray | None = None
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (logits (B, S, vocab) fp32, aux_loss scalar)."""
    if embeds is None:
        x = embed_tokens(params["embed"], tokens, cfg.dtype)
    else:
        x = embeds.astype(cfg.dtype)
    b, s, d = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    _, kinds = _group_structure(cfg)

    def body(x, group):
        aux = jnp.zeros((), jnp.float32)
        for sub, kind in zip(group, kinds):
            h = rms_norm(x, sub["ln1"].astype(cfg.dtype), cfg.norm_eps)
            x = sp_residual(
                x + attention_fwd(sub["attn"], h, cfg,
                                  positions=positions))
            x, a = _ffn(sub, x, cfg, kind)
            x = sp_residual(x)
            aux = aux + a
        return x, aux

    step = body
    if cfg.remat:
        step = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, auxs = jax.lax.scan(step, x, params["layers"])
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    return unembed(table, x), jnp.sum(auxs)


# ----------------------------------------------------------------------
# Serving: prefill + single-token decode with stacked KV caches.
def lm_prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
               max_len: int | None = None) -> tuple[jnp.ndarray, dict]:
    """Run the prompt, return (last-position logits, cache pytree).

    The cache holds exactly the prompt K/V (padded to ``max_len`` slots
    when given) with layout (L, B, Hkv, S, hd), sharded batch->data.
    """
    x = embed_tokens(params["embed"], tokens, cfg.dtype)
    return _prefill_from_embeds(params, cfg, x, max_len)


def lm_prefill_embeds(params: dict, cfg: ModelConfig, embeds: jnp.ndarray,
                      max_len: int | None = None
                      ) -> tuple[jnp.ndarray, dict]:
    """Prefill from precomputed embeddings (VLM patch+token prompts)."""
    return _prefill_from_embeds(params, cfg, embeds.astype(cfg.dtype),
                                max_len)


def _prefill_from_embeds(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                         max_len: int | None = None
                         ) -> tuple[jnp.ndarray, dict]:
    r = get_rules()
    b, s, _ = x.shape
    max_len = max_len or s
    pad = max_len - s
    positions = jnp.arange(s, dtype=jnp.int32)
    _, kinds = _group_structure(cfg)

    def body(x, group):
        ks, vs = [], []
        for sub, kind in zip(group, kinds):
            h = rms_norm(x, sub["ln1"].astype(cfg.dtype), cfg.norm_eps)
            q, k, v = _qkv(sub["attn"], h, cfg, positions)
            qh, kh, vh = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
            o = flash_attention(qh, kh, vh, causal=True,
                                use_pallas=cfg.use_flash)
            o = o.transpose(0, 2, 1, 3)
            y = jnp.einsum("bshk,hkd->bsd", o,
                           sub["attn"]["wo"].astype(cfg.dtype))
            x = sp_residual(x + y)
            x, _ = _ffn(sub, x, cfg, kind)
            x = sp_residual(x)
            ks.append(jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0))))
            vs.append(jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0))))
        return x, (jnp.stack(ks), jnp.stack(vs))

    step = body
    if cfg.remat:
        step = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, (k_all, v_all) = jax.lax.scan(step, x, params["layers"])
    k_all = k_all.reshape((-1,) + k_all.shape[2:])
    v_all = v_all.reshape((-1,) + v_all.shape[2:])
    k_all = r.constrain(k_all, "layers", "batch", "kv_heads", "kv_seq", None)
    v_all = r.constrain(v_all, "layers", "batch", "kv_heads", "kv_seq", None)
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x[:, -1:, :])
    return logits, {"k": k_all, "v": v_all,
                    "length": jnp.asarray(s, jnp.int32)}


def lm_decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                   cache: dict) -> tuple[jnp.ndarray, dict]:
    """token (B, 1) int32 -> (logits (B, 1, vocab), updated cache)."""
    r = get_rules()
    x = embed_tokens(params["embed"], token, cfg.dtype)
    length = cache["length"]
    n_groups, kinds = _group_structure(cfg)
    g = len(kinds)
    ck = cache["k"].reshape((n_groups, g) + cache["k"].shape[1:])
    cv = cache["v"].reshape((n_groups, g) + cache["v"].shape[1:])
    ck = r.constrain(ck, None, None, "batch", "kv_heads", "kv_seq", None)
    cv = r.constrain(cv, None, None, "batch", "kv_heads", "kv_seq", None)

    def body(x, inp):
        group, k_g, v_g = inp
        new_ks, new_vs = [], []
        for i, kind in enumerate(kinds):
            sub = group[i]
            h = rms_norm(x, sub["ln1"].astype(cfg.dtype), cfg.norm_eps)
            y, nk, nv = attention_decode(sub["attn"], h, k_g[i], v_g[i],
                                         length, cfg)
            x = x + y
            x, _ = _ffn(sub, x, cfg, kind)
            new_ks.append(nk)
            new_vs.append(nv)
        return x, (jnp.stack(new_ks), jnp.stack(new_vs))

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], ck, cv))
    nk = nk.reshape(cache["k"].shape)
    nv = nv.reshape(cache["v"].shape)
    nk = r.constrain(nk, "layers", "batch", "kv_heads", "kv_seq", None)
    nv = r.constrain(nv, "layers", "batch", "kv_heads", "kv_seq", None)
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x)
    return logits, {"k": nk, "v": nv, "length": length + 1}
