"""End-to-end tomography: phantom → simulated scan → Savu chain → FBP
reconstruction ≈ phantom.  This is the paper's core workload."""
import numpy as np
import pytest

from repro.core import ChunkedFileTransport, InMemoryTransport, PluginRunner
from repro.tomo import (ParallelGeometry, forward_project, phantom_stack,
                        shepp_logan, simulate_raw_scan, standard_chain)


def _run(chain, transport=None):
    runner = PluginRunner(chain, transport or InMemoryTransport())
    out = runner.run()
    recon = np.asarray(runner.transport.read(out["recon"]))
    truth = next(d.metadata["truth"] for d in runner.lineage
                 if d.metadata.get("truth") is not None)
    return recon, truth, runner


def _quality(recon, truth):
    sl = slice(8, -8)
    t, x = truth[:, sl, sl], recon[:, sl, sl]
    corr = np.corrcoef(t.ravel(), x.ravel())[0, 1]
    return corr


def test_full_chain_reconstructs_phantom():
    recon, truth, _ = _run(standard_chain(n_det=64, n_angles=96, n_rows=2))
    assert recon.shape == truth.shape
    assert _quality(recon, truth) > 0.85


def test_chain_on_chunked_file_transport():
    recon, truth, runner = _run(
        standard_chain(n_det=64, n_angles=96, n_rows=2),
        ChunkedFileTransport())
    assert _quality(recon, truth) > 0.85
    stats = runner.transport.total_stats()
    assert stats.chunk_reads > 0 and stats.chunk_writes > 0


def test_chain_with_paganin():
    recon, truth, _ = _run(standard_chain(n_det=64, n_angles=96, n_rows=1,
                                          paganin=True, ring=False))
    # Paganin low-passes; correlation threshold relaxed
    assert _quality(recon, truth) > 0.7


def test_chain_survives_noise():
    recon, truth, _ = _run(standard_chain(n_det=64, n_angles=96, n_rows=1,
                                          noise=4.0))
    assert _quality(recon, truth) > 0.75


def test_ref_vs_pallas_chain_agree():
    r1, t1, _ = _run(standard_chain(n_det=64, n_angles=64, n_rows=1,
                                    use_pallas=True))
    r2, t2, _ = _run(standard_chain(n_det=64, n_angles=64, n_rows=1,
                                    use_pallas=False))
    np.testing.assert_allclose(r1, r2, rtol=1e-3, atol=1e-4)


def test_forward_projector_sanity():
    """Radon of a centred disc: projection mass ≈ π r² at every angle."""
    n = 64
    ys, xs = np.mgrid[-1:1:n * 1j, -1:1:n * 1j]
    disc = ((xs ** 2 + ys ** 2) <= 0.5 ** 2).astype(np.float32)
    geom = ParallelGeometry(8, n, 1)
    proj = forward_project(disc[None], geom)      # (angles, 1, det)
    sums = proj.sum(axis=-1)[:, 0]
    # mass conservation across angles
    assert sums.std() / sums.mean() < 0.02
    expected = np.pi * (0.5 * n / 2) ** 2
    assert abs(sums.mean() - expected) / expected < 0.05


def test_simulated_scan_fields():
    geom = ParallelGeometry(16, 32, 2)
    scan = simulate_raw_scan(phantom_stack(32, 2), geom)
    assert scan["data"].shape == (16, 2, 32)
    assert scan["data"].dtype == np.uint16
    assert scan["flat"].mean() > scan["dark"].mean()


def test_phantom_rows_differ():
    v = phantom_stack(32, 3)
    assert not np.allclose(v[0], v[2])
