"""whisper-small [audio] — enc-dec backbone, conv frontend STUB
(arXiv:2212.04356).  12+12L d_model=768 12H d_ff=3072 vocab=51865.

input_specs() provides precomputed mel-frame embeddings (B, 1500, d)
per the assignment; the conv stem is not modelled.  No rope
(sinusoidal absolute positions).
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "whisper-small"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab=51865, rope_fraction=0.0,
    max_frames=1500, frontend="mel", dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=269, rope_fraction=0.0, max_frames=16,
    frontend="mel", dtype=jnp.float32, remat=False)
