import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's OWN workload at production scale: a
full-field scan of (3000 angles × 2048 rows × 2048 det) — the paper's
"typical single scan ≈ 96 GB" scaled to power-of-two dims (25 GB u16
raw, 50 GB fp32 working set) — through the fused
correction → ring-removal → sinogram-filter chain, compiled on the
256-chip production mesh with pattern-driven shardings.

This is the chain Savu runs through parallel HDF5; here the pattern
transition PROJECTION → SINOGRAM lowers to an in-HBM all-to-all and the
whole chain is ONE XLA program (plugin fusion, beyond-paper).

    PYTHONPATH=src python -m repro.launch.dryrun_tomo
"""
import json

import numpy as np

import jax
import jax.numpy as jnp

from ..core.dataset import DataSet
from ..core.patterns import PROJECTION, SINOGRAM
from ..core.plugin import PluginData
from ..core.transport import ShardedTransport
from ..roofline.analysis import analyse
from ..tomo.geometry import ParallelGeometry
from ..tomo.plugins import DarkFlatCorrection, RingRemoval, SinogramFilter
from .mesh import make_production_mesh

N_ANGLES, N_ROWS, N_DET = 3072, 2048, 2048   # paper's ~3k angles,
#   rounded to divide the 16-way data axis


def _dataset(name: str) -> DataSet:
    ds = DataSet(name, (N_ANGLES, N_ROWS, N_DET), np.float32,
                 ("rotation_angle", "detector_y", "detector_x"))
    ds.add_pattern(PROJECTION, core=("detector_y", "detector_x"),
                   slice_=("rotation_angle",))
    ds.add_pattern(SINOGRAM, core=("rotation_angle", "detector_x"),
                   slice_=("detector_y",))
    return ds


def lower_chain(mesh, use_pallas: bool = False) -> dict:
    tr = ShardedTransport(mesh)
    geom = ParallelGeometry(N_ANGLES, N_DET, N_ROWS)
    dark = np.full((N_ROWS, N_DET), 96.0, np.float32)
    flat = np.full((N_ROWS, N_DET), 40000.0, np.float32)

    raw = _dataset("tomo")
    raw.metadata.update({"dark": dark, "flat": flat, "mu": 0.02,
                         "geometry": geom})

    plugins = [
        DarkFlatCorrection(in_datasets=["tomo"], out_datasets=["tomo"],
                           use_pallas=use_pallas),
        RingRemoval(in_datasets=["tomo"], out_datasets=["tomo"]),
        SinogramFilter(in_datasets=["tomo"], out_datasets=["tomo"],
                       use_pallas=use_pallas),
    ]
    cur = raw
    for p in plugins:
        p.in_data = [PluginData(cur)]
        p.out_data = []
        (out,) = p.setup([cur])
        out.name = p.out_dataset_names[0]
        p.out_data = [PluginData(out)]
        p.out_data[0].pattern_name = (p.out_pattern_name
                                      or p.in_data[0].pattern_name)
        p.out_data[0].n_frames = p.in_data[0].n_frames
        if p.out_data[0].pattern_name not in out.patterns:
            out.patterns.update(cur.patterns)
        cur = out

    # XLA's SPMD partitioner REPLICATES fft ops regardless of batch-dim
    # sharding (measured: 198 GiB/dev for a 52 GB dataset).  These
    # plugins' frame math is shard-local (the transform axes are core
    # dims, never sharded), so each runs under shard_map — manual SPMD,
    # per-shard local compute, zero replication; the pattern transition
    # between plugins stays a with_sharding_constraint (all-to-all).
    from jax.experimental.shard_map import shard_map

    def local_fn(p_):
        pat_in = p_.in_data[0].pattern
        pat_out = p_.out_data[0].pattern

        def f(a):
            frames = pat_in.to_frames(a)
            nf = frames.shape[0]
            res = jax.vmap(
                lambda fr: p_.process_frames([fr[None]]))(frames)
            res = res.reshape((nf,) + res.shape[2:])
            return pat_out.from_frames(res, a.shape).astype(jnp.float32)
        return f

    wrapped, mid_sh = [], []
    for p_ in plugins:
        in_sh_p = tr._sharding(p_.in_data[0].pattern, "data")
        out_sh_p = tr._sharding(p_.out_data[0].pattern, "data")
        mid_sh.append(out_sh_p)
        wrapped.append(shard_map(local_fn(p_), mesh=mesh,
                                 in_specs=(in_sh_p.spec,),
                                 out_specs=in_sh_p.spec,
                                 check_rep=False))

    def chain(x):
        cur = x
        for w, sh in zip(wrapped, mid_sh):
            cur = w(cur)
            cur = jax.lax.with_sharding_constraint(cur, sh)
        return cur

    in_sh = tr._sharding(raw.get_pattern(PROJECTION), "data")
    out_sh = tr._sharding(cur.get_pattern(SINOGRAM), "data")
    spec = jax.ShapeDtypeStruct(raw.shape, jnp.float32, sharding=in_sh)
    with mesh:
        compiled = jax.jit(chain, in_shardings=(in_sh,),
                           out_shardings=out_sh).lower(spec).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    roof = analyse(cost, hlo, n_devices=mesh.size)
    return {
        "tag": f"tomo-fullfield-chain__{N_ANGLES}x{N_ROWS}x{N_DET}",
        "mesh": list(mesh.devices.shape),
        "dataset_gb": N_ANGLES * N_ROWS * N_DET * 4 / 1e9,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_estimate": mem.argument_size_in_bytes +
            mem.output_size_in_bytes + mem.temp_size_in_bytes -
            mem.alias_size_in_bytes,
        },
        "roofline": roof.to_json(),
    }


def main() -> None:
    mesh = make_production_mesh()
    rec = lower_chain(mesh)
    os.makedirs("experiments/dryrun", exist_ok=True)
    with open("experiments/dryrun/tomo_chain_pod.json", "w") as fh:
        json.dump(rec, fh, indent=1)
    ro = rec["roofline"]
    print(f"{rec['tag']}: {rec['dataset_gb']:.0f} GB fp32 working set, "
          f"peak/dev={rec['memory']['peak_estimate'] / 2**30:.2f} GiB")
    print(f"  compute={ro['compute_s'] * 1e3:.1f}ms "
          f"memory={ro['memory_s'] * 1e3:.1f}ms "
          f"collective={ro['collective_s'] * 1e3:.1f}ms "
          f"-> {ro['bottleneck']}")
    print("  (the PROJECTION->SINOGRAM pattern transition is the "
          "collective term: Savu paid it as a parallel-HDF5 round trip)")


if __name__ == "__main__":
    main()
