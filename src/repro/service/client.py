"""PipelineClient — stdlib HTTP client for the pipeline service.

The submit side of cross-process serving: build a process list locally
(or load a spec JSON), ``submit`` it, ``wait`` on the polling loop,
``result`` the reconstruction back as numpy.  Wraps every endpoint of
:mod:`repro.service.server`; errors carry the server's validation
message (:class:`ServiceError.status` / ``.message``).

    >>> client = PipelineClient("http://127.0.0.1:8973")
    >>> job_id = client.submit(standard_chain(n_det=48), priority=2)
    >>> client.wait(job_id, timeout=120)["status"]
    'done'
    >>> recon = client.result(job_id)        # np.ndarray
"""
from __future__ import annotations

import io
import json
import time
import urllib.error
import urllib.request
from typing import Any
from urllib.parse import quote

import numpy as np

from ..core.process_list import ProcessList
from .wire import to_spec

_TERMINAL = ("done", "failed", "cancelled")


class ServiceError(RuntimeError):
    """An HTTP endpoint answered with an error status.

    Attributes:
        status: the HTTP status code (400 validation, 404 unknown,
            409 conflict, 429 admission rejection, ...).
        message: the server's ``error`` body field.
        detail: the full parsed JSON error body when the server sent
            one (e.g. the 503 readiness reply's ``firing`` list),
            else None.
    """

    def __init__(self, status: int, message: str,
                 detail: dict[str, Any] | None = None):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status
        self.message = message
        self.detail = detail


class PipelineClient:
    def __init__(self, base_url: str, timeout: float = 30.0,
                 token: str | None = None):
        """Args:
            base_url: e.g. ``http://127.0.0.1:8973`` (no trailing slash
                needed).
            timeout: per-request socket timeout in seconds.
            token: shared secret for a token-armed server — sent as
                ``Authorization: Bearer <token>`` on every request
                (mutating verbs are 401 without it).
        """
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        self.token = token
        # per-worker secrets minted by POST /workers, keyed by worker_id
        # (one client may drive several registered workers — tests do);
        # attached automatically to lease/progress/complete/uploads
        self._worker_secrets: dict[str, str] = {}

    # -- transport ------------------------------------------------------
    def _request(self, method: str, path: str,
                 body: dict | None = None, raw: bool = False,
                 raw_body: bytes | None = None,
                 headers: dict[str, str] | None = None,
                 with_headers: bool = False) -> Any:
        if raw_body is not None:
            data = raw_body
            hdrs = {"Content-Type": "application/octet-stream"}
        else:
            data = None if body is None else json.dumps(body).encode()
            hdrs = {"Content-Type": "application/json"} if data else {}
        if self.token is not None:
            hdrs["Authorization"] = f"Bearer {self.token}"
        hdrs.update(headers or {})
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method, headers=hdrs)
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                payload = resp.read()
                resp_headers = dict(resp.headers)
        except urllib.error.HTTPError as e:
            raw = e.read()
            parsed: dict[str, Any] | None = None
            try:
                parsed = json.loads(raw)
                message = parsed["error"]
            except (json.JSONDecodeError, KeyError, TypeError):
                message = raw.decode(errors="replace") or e.reason
                parsed = parsed if isinstance(parsed, dict) else None
            raise ServiceError(e.code, message, detail=parsed) from None
        out = payload if raw else json.loads(payload)
        return (out, resp_headers) if with_headers else out

    # -- endpoints ------------------------------------------------------
    def submit(self, process_list: ProcessList | dict | list, *,
               priority: int = 0, job_id: str | None = None,
               metadata: dict | None = None) -> str:
        """Submit a process list (``POST /jobs``).

        Args:
            process_list: a :class:`ProcessList` (serialised via
                :func:`~repro.service.wire.to_spec`) or an
                already-serialised spec document.
            priority: higher pops first (FIFO within a priority).
            job_id: explicit id — reuse the id of a killed job to
                resume it from its checkpoint.
            metadata: free-form JSON-able annotations.

        Returns: the job id.
        Raises:
            ServiceError: 400 invalid spec, 409 duplicate active id,
                429 admission control rejected (shed load and retry).
        """
        if isinstance(process_list, ProcessList):
            process_list = to_spec(process_list)
        envelope: dict[str, Any] = {"process_list": process_list,
                                    "priority": priority}
        if job_id is not None:
            envelope["job_id"] = job_id
        if metadata:
            envelope["metadata"] = metadata
        return self._request("POST", "/jobs", envelope)["job_id"]

    def status(self, job_id: str) -> dict[str, Any]:
        """One job's ``Job.snapshot()`` (``GET /jobs/{id}``): state,
        ``running(plugin i/N)`` progress, ``resumed_from``, timings.
        Raises ServiceError(404) for an unknown/pruned job."""
        return self._request("GET", f"/jobs/{quote(job_id, safe='')}")

    def jobs(self) -> list[dict[str, Any]]:
        """Every job's snapshot, submission-ordered (``GET /jobs``)."""
        return self._request("GET", "/jobs")["jobs"]

    def stats(self) -> dict[str, Any]:
        """Scheduler + compile-cache counters (``GET /stats``)."""
        return self._request("GET", "/stats")

    def trace(self, job_id: str, text: bool = False,
              otlp: bool = False) -> dict[str, Any] | str:
        """A job's cross-process span timeline
        (``GET /jobs/{id}/trace``): ``{"job_id", "trace_id",
        "spans": [...]}`` — or, with ``text=True``, the ASCII gantt
        rendering (``?format=text``), or, with ``otlp=True``, the
        OTLP/JSON export document (``?format=otlp``).  Raises
        ServiceError(404) for an unknown/pruned job.  See
        ``docs/observability.md``."""
        path = f"/jobs/{quote(job_id, safe='')}/trace"
        if text:
            return self._request("GET", path + "?format=text",
                                 raw=True).decode()
        if otlp:
            return self._request("GET", path + "?format=otlp")
        return self._request("GET", path)

    def metrics(self) -> str:
        """The Prometheus text exposition (``GET /metrics``) — the same
        numbers as ``stats()["metrics"]``, scrape-ready."""
        return self._request("GET", "/metrics", raw=True).decode()

    def plugins(self) -> dict[str, Any]:
        """The wire-format plugin registry (``GET /plugins``)."""
        return self._request("GET", "/plugins")

    def health(self, ready: bool = False) -> dict[str, Any]:
        """Liveness probe (``GET /healthz``).  With ``ready=True`` asks
        the degrade-aware readiness question (``?ready=1``): while a
        critical SLO rule fires the server answers 503 — returned here
        as its machine-readable detail (``{"ok": False, "ready":
        False, "firing": [...], ...}``) rather than raised, so callers
        branch on ``out["ready"]``."""
        if not ready:
            return self._request("GET", "/healthz")
        try:
            return self._request("GET", "/healthz?ready=1")
        except ServiceError as e:
            if e.status == 503 and e.detail is not None:
                return e.detail
            raise

    def slo(self) -> dict[str, Any]:
        """The SLO engine snapshot (``GET /slo``): every rule's
        definition, current reading and lifecycle state, plus the
        ``firing`` / ``critical_firing`` summaries.  The scrape
        evaluates first, so states are never stale."""
        return self._request("GET", "/slo")

    def events(self, since: int = 0,
               limit: int | None = None) -> dict[str, Any]:
        """A structured event-log page (``GET /events``): records with
        ``seq > since`` oldest-first, the new ``cursor`` to resume
        from, and how many records the bounded ring ``dropped`` before
        this cursor.  Poll with the returned cursor to tail."""
        q = f"?since={int(since)}"
        if limit is not None:
            q += f"&limit={int(limit)}"
        return self._request("GET", "/events" + q)

    def cluster(self) -> dict[str, Any]:
        """The per-worker scoreboard (``GET /cluster``; broker mode —
        409 otherwise): heartbeat staleness, active leases with
        time-to-expiry, last error, warm-pool prefetch count."""
        return self._request("GET", "/cluster")

    def cancel(self, job_id: str) -> dict[str, Any]:
        """Cancel a queued job (``DELETE /jobs/{id}``).

        Returns: ``{"cancelled": True, ...}`` on success.
        Raises:
            ServiceError: 404 unknown job; 409 the job was already
                dispatched or terminal (body names its state).
        """
        return self._request("DELETE", f"/jobs/{quote(job_id, safe='')}")

    def result(self, job_id: str, dataset: str | None = None
               ) -> np.ndarray:
        """Fetch an output dataset (``GET /jobs/{id}/result``) as a
        numpy array (npy bytes on the wire, chunk-streamed server-side).

        Args:
            dataset: dataset name; default = the chain's saver output.

        Raises:
            ServiceError: 404 unknown job/dataset or evicted result,
                409 the job is not done yet.
        """
        q = f"?dataset={quote(dataset, safe='')}" if dataset else ""
        payload = self._request(
            "GET", f"/jobs/{quote(job_id, safe='')}/result{q}", raw=True)
        return np.load(io.BytesIO(payload))

    # -- streaming acquisition (docs/streaming.md) -----------------------
    def ingest(self, job_id: str, frames: np.ndarray,
               start: int) -> dict[str, Any]:
        """Feed one contiguous frame chunk to a streaming job
        (``POST /jobs/{id}/frames``; frames on axis 0, raw ``.npy`` on
        the wire).  ``start`` must equal the current watermark.

        Returns: ``{"start", "count", "watermark"}``.
        Raises:
            ServiceError: 404 unknown job; 409 not a streaming job,
                out-of-order/duplicate chunk, after EOF, or terminal.
        """
        buf = io.BytesIO()
        np.save(buf, np.ascontiguousarray(frames))
        return self._request(
            "POST", f"/jobs/{quote(job_id, safe='')}/frames",
            raw_body=buf.getvalue(),
            headers={"X-Start-Frame": str(int(start))})

    def eof(self, job_id: str) -> dict[str, Any]:
        """Declare end of acquisition (``POST /jobs/{id}/eof``).
        Raises ServiceError 409 on a second EOF or a non-streaming
        job."""
        return self._request(
            "POST", f"/jobs/{quote(job_id, safe='')}/eof", body={})

    def preview(self, job_id: str) -> tuple[np.ndarray, int]:
        """The partial reconstruction over the frames ingested so far
        (``GET /jobs/{id}/preview``) as ``(array, frames_covered)``.
        Raises ServiceError 409 while no preview can be produced yet."""
        payload, hdrs = self._request(
            "GET", f"/jobs/{quote(job_id, safe='')}/preview",
            raw=True, with_headers=True)
        return (np.load(io.BytesIO(payload)),
                int(hdrs.get("X-Watermark", 0)))

    def fetch_frames(self, job_id: str, start: int = 0,
                     max_frames: int | None = None
                     ) -> tuple[np.ndarray | None, int, bool, int]:
        """Pull buffered frames from ``start`` on
        (``GET /jobs/{id}/frames``) — how a broker-mode worker consumes
        the stream.  Returns ``(frames | None, start, eof, watermark)``;
        frames is None when nothing at-or-after ``start`` has arrived."""
        q = f"?start={int(start)}"
        if max_frames is not None:
            q += f"&max={int(max_frames)}"
        payload, hdrs = self._request(
            "GET", f"/jobs/{quote(job_id, safe='')}/frames{q}",
            raw=True, with_headers=True)
        eof = hdrs.get("X-EOF") == "1"
        watermark = int(hdrs.get("X-Watermark", 0))
        if not payload or hdrs.get("X-Count") == "0":
            return None, int(start), eof, watermark
        return (np.load(io.BytesIO(payload)),
                int(hdrs.get("X-Start", start)), eof, watermark)

    # -- parameter sweeps (docs/sweeps.md) -------------------------------
    def sweep(self, process_list: ProcessList | dict | list,
              sweep: dict | list, *, metric: str | None = None,
              priority: int = 0, sweep_id: str | None = None,
              metadata: dict | None = None) -> dict[str, Any]:
        """Submit a parameter sweep (``POST /sweeps``): the process list
        plus a grid block over ≤2 *sweepable* params, expanded
        server-side into gang-batched variant jobs.

        Args:
            process_list: a :class:`ProcessList` or spec document.
            sweep: one axis (``{"plugin": name | "plugin_index": i,
                "param": p, "values": [...]}``) or a list of ≤2.
            metric: optional per-variant score (``sharpness`` /
                ``entropy`` / ``std``) — surfaces ``best_variant``.
            priority: shared by every variant.
            sweep_id: explicit group id (variants are ``{id}/v{k}``).
            metadata: annotations copied onto every variant.

        Returns: the submission reply — ``sweep_id``, ``n_variants``,
        ``shape``, ``job_ids``.
        Raises:
            ServiceError: 400 invalid spec/sweep (non-sweepable param,
                >2 axes, unknown metric...), 409 duplicate active id,
                429 the whole group was rejected by admission control.
        """
        if isinstance(process_list, ProcessList):
            process_list = to_spec(process_list)
        envelope: dict[str, Any] = {"process_list": process_list,
                                    "sweep": sweep, "priority": priority}
        if metric is not None:
            envelope["metric"] = metric
        if sweep_id is not None:
            envelope["sweep_id"] = sweep_id
        if metadata:
            envelope["metadata"] = metadata
        return self._request("POST", "/sweeps", envelope)

    def sweep_status(self, sweep_id: str) -> dict[str, Any]:
        """One sweep group's snapshot (``GET /sweeps/{id}``): aggregate
        state, per-variant snapshots with their grid values, scores +
        ``best_variant`` once done (when a metric was requested)."""
        return self._request("GET",
                             f"/sweeps/{quote(sweep_id, safe='')}")

    def sweeps(self) -> list[dict[str, Any]]:
        """Every retained sweep group's summary (``GET /sweeps``)."""
        return self._request("GET", "/sweeps")["sweeps"]

    def sweep_result(self, sweep_id: str, dataset: str | None = None
                     ) -> np.ndarray:
        """Fetch the stacked result (``GET /sweeps/{id}/result``): shape
        ``(*grid_shape, *variant_shape)`` — the parameter axes lead.
        Raises ServiceError 404 (unknown) / 409 (not all done)."""
        q = f"?dataset={quote(dataset, safe='')}" if dataset else ""
        payload = self._request(
            "GET", f"/sweeps/{quote(sweep_id, safe='')}/result{q}",
            raw=True)
        return np.load(io.BytesIO(payload))

    def cancel_sweep(self, sweep_id: str) -> dict[str, Any]:
        """Cancel every live variant (``DELETE /sweeps/{id}``).  Returns
        the per-variant ``cancelled``/``skipped`` id lists."""
        return self._request("DELETE",
                             f"/sweeps/{quote(sweep_id, safe='')}")

    def wait_sweep(self, sweep_id: str, timeout: float | None = None,
                   poll: float = 0.1) -> dict[str, Any]:
        """Block until every variant is terminal.  Returns the final
        group snapshot (inspect ``snapshot["state"]`` — done / failed /
        cancelled / partial).  Raises TimeoutError at the deadline."""
        deadline = None if timeout is None else time.time() + timeout
        while True:
            snap = self.sweep_status(sweep_id)
            if snap["all_terminal"]:
                return snap
            if deadline is not None and time.time() >= deadline:
                raise TimeoutError(
                    f"sweep {sweep_id!r} still {snap['state']!r} "
                    f"({snap['counts']}) after {timeout}s")
            time.sleep(poll)

    # -- workflow DAGs (docs/workflows.md) -------------------------------
    def workflow(self, nodes: dict[str, Any], *,
                 workflow_id: str | None = None, priority: int = 0,
                 metadata: dict | None = None) -> dict[str, Any]:
        """Submit a DAG of process lists as ONE spec-v3 envelope
        (``POST /workflows``): each node is a process list, ``after``
        lists upstream node names, and an ``upstream_loader`` entry with
        ``{"data": {"from_job": "<node>", "dataset": "<name>"}}`` feeds
        a node an upstream output (the reference also implies the edge).

        Args:
            nodes: ``{name: ProcessList}`` or ``{name:
                {"process_list": ProcessList | spec,
                 "after": [upstream names], "priority": int}}``.
            workflow_id: explicit group id (node jobs are
                ``{id}/{node}``).
            priority: default for nodes that set none.
            metadata: annotations copied onto every node job.

        Returns: the submission reply — ``workflow_id``, ``state``,
        ``n_nodes``, ``nodes`` (topological order), ``job_ids``.
        Raises:
            ServiceError: 400 invalid envelope (cycle, dangling
                reference, bad spec — NOTHING was enqueued), 409
                duplicate active id, 429 the whole DAG was rejected by
                admission control.
        """
        wf: dict[str, Any] = {}
        for name, node in nodes.items():
            if isinstance(node, ProcessList):
                node = {"process_list": node}
            node = dict(node)
            if isinstance(node.get("process_list"), ProcessList):
                node["process_list"] = to_spec(node["process_list"])
            wf[name] = node
        envelope: dict[str, Any] = {"version": 3, "workflow": wf,
                                    "priority": priority}
        if workflow_id is not None:
            envelope["workflow_id"] = workflow_id
        if metadata:
            envelope["metadata"] = metadata
        return self._request("POST", "/workflows", envelope)

    def workflow_status(self, workflow_id: str) -> dict[str, Any]:
        """One workflow's snapshot (``GET /workflows/{id}``): aggregate
        state, per-state counts, the DAG edges, and per-node job
        snapshots (``waiting_on``, ``cancel_reason``...) keyed by node
        name."""
        return self._request(
            "GET", f"/workflows/{quote(workflow_id, safe='')}")

    def workflows(self) -> list[dict[str, Any]]:
        """Every retained workflow's summary (``GET /workflows``)."""
        return self._request("GET", "/workflows")["workflows"]

    def workflow_trace(self, workflow_id: str) -> dict[str, Any]:
        """The workflow-level linked trace
        (``GET /workflows/{id}/trace``): per-node span timelines keyed
        by node name, plus the DAG edges that connect them."""
        return self._request(
            "GET", f"/workflows/{quote(workflow_id, safe='')}/trace")

    def cancel_workflow(self, workflow_id: str) -> dict[str, Any]:
        """Cancel every live node (``DELETE /workflows/{id}``).  Queued
        nodes cancel immediately and their downstream cones cascade;
        returns the ``cancelled``/``skipped`` id lists."""
        return self._request(
            "DELETE", f"/workflows/{quote(workflow_id, safe='')}")

    def wait_workflow(self, workflow_id: str,
                      timeout: float | None = None,
                      poll: float = 0.1) -> dict[str, Any]:
        """Block until every node is terminal.  Returns the final group
        snapshot (inspect ``snapshot["state"]`` — done / failed /
        cancelled / partial).  Raises TimeoutError at the deadline."""
        deadline = None if timeout is None else time.time() + timeout
        while True:
            snap = self.workflow_status(workflow_id)
            if snap["all_terminal"]:
                return snap
            if deadline is not None and time.time() >= deadline:
                raise TimeoutError(
                    f"workflow {workflow_id!r} still {snap['state']!r} "
                    f"({snap['counts']}) after {timeout}s")
            time.sleep(poll)

    # -- worker-pull protocol (broker mode; docs/worker-protocol.md) ----
    def register_worker(self, *, worker_id: str | None = None,
                        plugins: list[str] | None = None,
                        mesh_shape: list[int] | None = None,
                        max_batch: int = 1,
                        shared_fs: bool = False,
                        sweeps: bool = True) -> dict[str, Any]:
        """Register a worker process (``POST /workers``) with its
        capabilities (``sweeps=False`` keeps the worker out of
        parameter-sweep fan-outs).  Returns ``{"worker_id",
        "worker_secret", "lease_ttl", "hot_executables"}`` (plus
        ``"results_dir"`` for shared-fs workers).  The minted
        ``worker_secret`` is remembered per worker_id and attached to
        every subsequent lease/progress/complete/upload automatically.
        409 if the server is not in broker mode."""
        reply = self._request("POST", "/workers", {
            "worker_id": worker_id, "plugins": plugins,
            "mesh_shape": mesh_shape, "max_batch": max_batch,
            "shared_fs": shared_fs, "sweeps": sweeps})
        if isinstance(reply.get("worker_secret"), str):
            self._worker_secrets[reply["worker_id"]] = \
                reply["worker_secret"]
        return reply

    def worker_secret(self, worker_id: str) -> str | None:
        """The per-worker secret minted at registration (None if this
        client never registered ``worker_id``)."""
        return self._worker_secrets.get(worker_id)

    def adopt_worker_secret(self, worker_id: str, secret: str) -> None:
        """Attach a secret minted elsewhere (e.g. by an in-process
        :class:`PipelineWorker`'s own client) so this client may act
        on that worker's behalf."""
        self._worker_secrets[worker_id] = secret

    def lease(self, worker_id: str, max_jobs: int = 1,
              timeout: float = 0.0,
              prefetched: int | None = None) -> list[dict[str, Any]]:
        """Lease capability-matching jobs (``POST /jobs/lease``).
        Returns the (possibly empty) job-descriptor list; ``timeout``
        long-polls server-side up to 30s.  ``prefetched`` reports how
        many warm-pool executables this worker holds — surfaced on the
        ``GET /cluster`` scoreboard."""
        body: dict[str, Any] = {
            "worker_id": worker_id, "max_jobs": max_jobs,
            "timeout": timeout,
            "worker_secret": self._worker_secrets.get(worker_id)}
        if prefetched is not None:
            body["prefetched"] = prefetched
        return self._request("POST", "/jobs/lease", body)["jobs"]

    def progress(self, job_id: str, worker_id: str,
                 **fields: Any) -> dict[str, Any]:
        """Heartbeat + progress for a leased job
        (``POST /jobs/{id}/progress``; fields: ``plugin_index``,
        ``n_plugins``, ``resumed_from``, ``checkpoint``).  The reply's
        ``verdict`` is ``ok`` / ``cancelled`` / ``lost``."""
        return self._request(
            "POST", f"/jobs/{quote(job_id, safe='')}/progress",
            {"worker_id": worker_id,
             "worker_secret": self._worker_secrets.get(worker_id),
             **fields})

    def complete(self, job_id: str, worker_id: str, state: str,
                 error: str | None = None,
                 results: dict[str, Any] | None = None,
                 **fields: Any) -> dict[str, Any]:
        """Report a leased job terminal (``POST /jobs/{id}/complete``).
        Raises ServiceError(409) if the lease was lost — the caller
        must discard its outcome."""
        body: dict[str, Any] = {
            "worker_id": worker_id,
            "worker_secret": self._worker_secrets.get(worker_id),
            "state": state, **fields}
        if error is not None:
            body["error"] = error
        if results is not None:
            body["results"] = results
        return self._request(
            "POST", f"/jobs/{quote(job_id, safe='')}/complete", body)

    def _worker_headers(self, worker_id: str) -> dict[str, str]:
        headers = {"X-Worker-Id": worker_id}
        secret = self._worker_secrets.get(worker_id)
        if secret is not None:
            headers["X-Worker-Secret"] = secret
        return headers

    def upload_result(self, job_id: str, worker_id: str, dataset: str,
                      payload: bytes) -> dict[str, Any]:
        """Upload one result dataset as raw ``.npy`` bytes
        (``PUT /jobs/{id}/result?dataset=``); only the lease holder may
        upload (409 otherwise; 403 on a bad worker secret)."""
        return self._request(
            "PUT",
            f"/jobs/{quote(job_id, safe='')}/result"
            f"?dataset={quote(dataset, safe='')}",
            raw_body=payload, headers=self._worker_headers(worker_id))

    # -- executable warm pool (docs/worker-protocol.md) -----------------
    def hot_executables(self) -> list[str]:
        """The broker spool's hottest executable signatures
        (``GET /executables``) — what a fresh worker prefetches."""
        return self._request("GET", "/executables")["hot"]

    def fetch_executable(self, sig: str) -> bytes:
        """One serialized executable's raw payload
        (``GET /executables/{sig}``).  Raises ServiceError(404) when
        the spool doesn't have it."""
        return self._request("GET", f"/executables/{quote(sig, safe='')}",
                             raw=True)

    def upload_executable(self, sig: str, worker_id: str,
                          payload: bytes) -> dict[str, Any]:
        """Hand one serialized executable to the broker spool
        (``PUT /executables/{sig}``); registered workers only (403 on a
        bad secret, 400 on an unframed payload)."""
        return self._request(
            "PUT", f"/executables/{quote(sig, safe='')}",
            raw_body=payload, headers=self._worker_headers(worker_id))

    def workers(self) -> dict[str, Any]:
        """Per-worker broker stats (``GET /workers``; broker mode)."""
        return self._request("GET", "/workers")

    def wait(self, job_id: str, timeout: float | None = None,
             poll: float = 0.1) -> dict[str, Any]:
        """Block until ``job_id`` reaches a terminal state (the
        client-side poll loop over :meth:`status`).

        Args:
            timeout: seconds before giving up (None = forever).
            poll: seconds between polls.

        Returns: the terminal snapshot (state done/failed/cancelled —
        inspect ``snapshot["state"]``; a failed job's message is in
        ``snapshot["error"]``).
        Raises:
            TimeoutError: still non-terminal at the deadline.
        """
        deadline = None if timeout is None else time.time() + timeout
        while True:
            snap = self.status(job_id)
            if snap["state"] in _TERMINAL:
                return snap
            if deadline is not None and time.time() >= deadline:
                raise TimeoutError(
                    f"job {job_id!r} still {snap['status']!r} after "
                    f"{timeout}s")
            time.sleep(poll)
