"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus the Fig-9 profile chart).
"""
from __future__ import annotations

import sys


def main() -> None:
    rows = []

    def report(name: str, us_per_call: float, derived: str = "") -> None:
        rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    from . import (bench_checkpoint, bench_chunking, bench_lm,
                   bench_profile, bench_recon, bench_scaling, bench_service)
    for mod in (bench_chunking, bench_profile, bench_recon, bench_scaling,
                bench_service, bench_checkpoint, bench_lm):
        try:
            mod.run(report)
        except Exception as e:  # keep the harness going
            print(f"{mod.__name__},-1,FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr, flush=True)
    print(f"# {len(rows)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
