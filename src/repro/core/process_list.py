"""Process lists + the pre-flight *plugin list check* (paper §III.E).

A process list is an ordered sequence of plugin entries (class + params +
in/out dataset names), starting with >=1 loader and ending with a saver.
``check()`` replays the chain symbolically — exactly the paper's
"plugin list check performed on the data, highlighting any
inconsistencies ... and will break the run before processing".
"""
from __future__ import annotations

import dataclasses
import importlib
import json
from typing import Any, Sequence, Type

from .plugin import BaseLoader, BasePlugin, BaseSaver


@dataclasses.dataclass
class PluginEntry:
    cls: Type[BasePlugin]
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    in_datasets: tuple[str, ...] = ()
    out_datasets: tuple[str, ...] = ()

    def instantiate(self) -> BasePlugin:
        return self.cls(in_datasets=list(self.in_datasets),
                        out_datasets=list(self.out_datasets), **self.params)

    def to_json(self) -> dict:
        return {"plugin": f"{self.cls.__module__}.{self.cls.__qualname__}",
                "params": {k: v for k, v in self.params.items()
                           if _is_jsonable(v)},
                "in_datasets": list(self.in_datasets),
                "out_datasets": list(self.out_datasets)}

    @staticmethod
    def from_json(d: dict) -> "PluginEntry":
        mod, _, qual = d["plugin"].rpartition(".")
        cls = getattr(importlib.import_module(mod), qual)
        return PluginEntry(cls, dict(d.get("params", {})),
                           tuple(d.get("in_datasets", ())),
                           tuple(d.get("out_datasets", ())))


def _is_jsonable(v) -> bool:
    try:
        json.dumps(v)
        return True
    except TypeError:
        return False


class ProcessListError(ValueError):
    pass


@dataclasses.dataclass
class ProcessList:
    entries: list[PluginEntry] = dataclasses.field(default_factory=list)

    # -- configurator-style construction -------------------------------
    def add(self, cls: Type[BasePlugin], *, params: dict | None = None,
            in_datasets: Sequence[str] = (), out_datasets: Sequence[str] = ()
            ) -> "ProcessList":
        self.entries.append(PluginEntry(cls, dict(params or {}),
                                        tuple(in_datasets),
                                        tuple(out_datasets)))
        return self

    # -- (de)serialisation ----------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump([e.to_json() for e in self.entries], fh, indent=2)

    @staticmethod
    def load(path: str) -> "ProcessList":
        with open(path) as fh:
            return ProcessList([PluginEntry.from_json(d)
                                for d in json.load(fh)])

    # -- the plugin list check -------------------------------------------
    def check(self) -> list[str]:
        """Symbolically replay the chain; raise ProcessListError on the
        first structural problem.  Returns the list of dataset names that
        survive to the saver."""
        if not self.entries:
            raise ProcessListError("empty process list")
        loaders = [e for e in self.entries if issubclass(e.cls, BaseLoader)]
        savers = [e for e in self.entries if issubclass(e.cls, BaseSaver)]
        if not loaders:
            raise ProcessListError("process list must start with a loader")
        if not savers:
            raise ProcessListError("process list must end with a saver")
        first_non_loader = next(i for i, e in enumerate(self.entries)
                                if not issubclass(e.cls, BaseLoader))
        if any(issubclass(e.cls, BaseLoader)
               for e in self.entries[first_non_loader:]):
            raise ProcessListError("all loaders must come first")
        if not issubclass(self.entries[-1].cls, BaseSaver):
            raise ProcessListError("the final plugin must be a saver")

        available: set[str] = set()
        for i, e in enumerate(self.entries):
            where = f"entry {i} ({e.cls.__name__})"
            if issubclass(e.cls, BaseLoader):
                dup = set(e.out_datasets) & available
                if dup:
                    raise ProcessListError(
                        f"{where}: dataset names {sorted(dup)} already exist")
                if not e.out_datasets:
                    raise ProcessListError(f"{where}: loader must name its "
                                           "out_datasets")
                available |= set(e.out_datasets)
            elif issubclass(e.cls, BaseSaver):
                missing = set(e.in_datasets) - available
                if missing:
                    raise ProcessListError(
                        f"{where}: saver input {sorted(missing)} not available"
                        f" (have {sorted(available)})")
            else:
                n_in = e.cls.n_in_datasets
                n_out = e.cls.n_out_datasets
                if len(e.in_datasets) != n_in:
                    raise ProcessListError(
                        f"{where}: needs {n_in} in_datasets, got "
                        f"{list(e.in_datasets)}")
                if len(e.out_datasets) != n_out:
                    raise ProcessListError(
                        f"{where}: needs {n_out} out_datasets, got "
                        f"{list(e.out_datasets)}")
                missing = set(e.in_datasets) - available
                if missing:
                    raise ProcessListError(
                        f"{where}: in_datasets {sorted(missing)} not "
                        f"available (have {sorted(available)})")
                # out_dataset with an existing name REPLACES it (paper
                # §III.B); a new name creates a new dataset.
                available |= set(e.out_datasets)
                # validate parameters exist (declared parameters dict or
                # explicit constructor arguments)
                import inspect
                sig = inspect.signature(e.cls.__init__)
                ctor = {n for n, p in sig.parameters.items()
                        if n not in ("self",) and
                        p.kind not in (inspect.Parameter.VAR_KEYWORD,
                                       inspect.Parameter.VAR_POSITIONAL)}
                valid = set(e.cls.parameters) | ctor
                unknown = set(e.params) - valid
                if unknown:
                    raise ProcessListError(
                        f"{where}: unknown params {sorted(unknown)} "
                        f"(valid: {sorted(valid)})")
        return sorted(available)

    def __iter__(self):
        return iter(self.entries)

    def __len__(self):
        return len(self.entries)
