"""Mamba-2 block (SSD) — used standalone and inside the Zamba2 hybrid.

Structure per block (Mamba-2 paper, arXiv:2405.21060):
  in_proj -> [z | x | B | C | dt] ; causal conv1d on [x|B|C] ; SiLU;
  SSD over heads (state N, head dim P); +D·x skip; RMSNorm; gate by
  SiLU(z); out_proj.

Group count G=1 (B/C shared across heads).  Decode keeps a (conv
window, SSD state) cache per layer.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, split_keys
from .layers import rms_norm
from .sharding import get_rules
from .ssd import chunked_linear_scan, linear_scan_step


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.ssm_heads or max(1, d_inner // 64)
    p = d_inner // n_heads
    n = cfg.ssm_state
    return d_inner, n_heads, p, n


def init_mamba_block(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n           # x, B, C all convolved (G=1)
    ks = split_keys(key, 6)
    dt_bias = jnp.log(jnp.expm1(
        jnp.linspace(1e-3, 0.1, h, dtype=jnp.float32)))  # softplus⁻¹ init
    return {
        "ln": jnp.ones((d,), cfg.param_dtype),
        "w_in": dense_init(ks[0], d,
                           (d, 2 * d_inner + 2 * n + h), cfg.param_dtype),
        "conv_w": dense_init(ks[1], cfg.conv_width,
                             (cfg.conv_width, conv_dim), cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.zeros((h,), jnp.float32) +
        jnp.log(jnp.linspace(1.0, 16.0, h)),
        "dt_bias": dt_bias,
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((d_inner,), cfg.param_dtype),
        "w_out": dense_init(ks[2], d_inner, (d_inner, d), cfg.param_dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv along seq.  x (B, S, C), w (W, C)."""
    width = w.shape[0]
    if prev is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = prev.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :]


class MambaCache(NamedTuple):
    conv: jnp.ndarray     # (B, W-1, conv_dim) rolling window
    ssd: jnp.ndarray      # (B, H, N, P) state


def mamba_fwd(params, x: jnp.ndarray, cfg: ModelConfig, *,
              chunk: int = 64) -> jnp.ndarray:
    """(B, S, d) -> (B, S, d), full-sequence (train / prefill)."""
    r = get_rules()
    b, s, d = x.shape
    d_inner, h, p, n = _dims(cfg)
    dt_ = cfg.dtype
    hx = rms_norm(x, params["ln"].astype(dt_), cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", hx, params["w_in"].astype(dt_))
    z, xs, bc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"].astype(dt_),
                            params["conv_b"].astype(dt_))
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(dt_)
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])   # (B,S,H)
    a = -jnp.exp(params["A_log"])[None, None, :]             # (H,) < 0
    log_decay = a * dt                                       # (B,S,H)

    xh = xs.reshape(b, s, h, p)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    kq_b = jnp.broadcast_to(bmat[:, :, None, :], (b, s, h, n))
    kq_c = jnp.broadcast_to(cmat[:, :, None, :], (b, s, h, n))
    xdt = r.constrain(xdt, "batch", None, "heads", None)

    y, _ = chunked_linear_scan(kq_c, kq_b, xdt, log_decay, chunk=chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(dt_)
    y = rms_norm(y, params["norm"].astype(dt_), cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"].astype(dt_))
    return r.constrain(out, "batch", "seq", "embed_act")


def init_mamba_cache(cfg: ModelConfig, batch: int) -> MambaCache:
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return MambaCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), cfg.dtype),
        ssd=jnp.zeros((batch, h, n, p), jnp.float32))


def mamba_step(params, x: jnp.ndarray, cache: MambaCache, cfg: ModelConfig
               ) -> tuple[jnp.ndarray, MambaCache]:
    """Single-token decode.  x (B, 1, d) -> (B, 1, d)."""
    b, _, d = x.shape
    d_inner, h, p, n = _dims(cfg)
    dt_ = cfg.dtype
    hx = rms_norm(x, params["ln"].astype(dt_), cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", hx, params["w_in"].astype(dt_))
    z, xs, bc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)        # (B, 1, conv_dim)
    window = jnp.concatenate([cache.conv, conv_in], axis=1)
    w = params["conv_w"].astype(dt_)
    conv_out = jnp.sum(window * w[None], axis=1, keepdims=True) + \
        params["conv_b"].astype(dt_)[None, None, :]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(dt_)
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         params["dt_bias"][None, :])       # (B, H)
    a = -jnp.exp(params["A_log"])[None, :]
    log_decay = a * dt
    xh = xs[:, 0].reshape(b, h, p)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    kb = jnp.broadcast_to(bmat[:, 0, None, :], (b, h, n))
    kc = jnp.broadcast_to(cmat[:, 0, None, :], (b, h, n))
    y, ssd_new = linear_scan_step(kc, kb, xdt, log_decay, cache.ssd)
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(dt_)
    y = rms_norm(y, params["norm"].astype(dt_), cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"].astype(dt_))
    new_cache = MambaCache(conv=window[:, 1:].astype(cfg.dtype),
                           ssd=ssd_new)
    return out, new_cache
