"""GQA attention: train/prefill forward + cached decode step.

TP: q heads shard over ``model``; kv heads shard over ``model`` only when
divisible (granite's kv=1 replicates — the MQA fallback).  The KV cache
shards (batch -> data, kv_heads -> model when divisible).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import attention as flash_attention
from .common import ModelConfig, dense_init, split_keys
from .layers import apply_rope, rope_freqs
from .sharding import get_rules


def init_attention(key, cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.hd
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], d, (d, cfg.n_heads, hd), cfg.param_dtype),
        "wk": dense_init(ks[1], d, (d, cfg.n_kv_heads, hd), cfg.param_dtype),
        "wv": dense_init(ks[2], d, (d, cfg.n_kv_heads, hd), cfg.param_dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, (cfg.n_heads, hd, d),
                         cfg.param_dtype),
    }


class KVCache(NamedTuple):
    k: jnp.ndarray      # (B, Hkv, S_max, hd)
    v: jnp.ndarray
    length: jnp.ndarray  # scalar int32 — tokens filled


def _head_axes(r, cfg: ModelConfig, n_heads: int, kind: str):
    """('batch', seq_axis, head_axis, None) with the context-parallel
    fallback when heads don't divide the TP extent (cfg flag)."""
    if cfg.seq_shard_fallback and r.mesh is not None:
        sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
        ext = sizes.get("model", 1)
        if ext > 1 and n_heads % ext != 0:
            return ("batch", "seq_sp", None, None)
    return ("batch", "seq", kind, None)


def _qkv(params, x, cfg: ModelConfig, positions):
    r = get_rules()
    dt = cfg.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    q = r.constrain(q, *_head_axes(r, cfg, cfg.n_heads, "heads"))
    k = r.constrain(k, *_head_axes(r, cfg, cfg.n_kv_heads, "kv_heads"))
    v = r.constrain(v, *_head_axes(r, cfg, cfg.n_kv_heads, "kv_heads"))
    if cfg.rope_fraction > 0:
        cos, sin = rope_freqs(cfg.hd, cfg.rope_fraction, cfg.rope_theta,
                              positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attention_fwd(params, x: jnp.ndarray, cfg: ModelConfig, *,
                  causal: bool = True,
                  positions: jnp.ndarray | None = None,
                  kv_override: tuple | None = None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill).  x: (B, S, d)."""
    r = get_rules()
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    if kv_override is None:
        q, k, v = _qkv(params, x, cfg, positions)
    else:                       # cross-attention: kv from encoder output
        dt = cfg.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        ctx = kv_override[0]
        k = jnp.einsum("bsd,dhk->bshk", ctx, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", ctx, params["wv"].astype(dt))
        causal = False
    # (B, H, S, hd) layout for the kernel
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention(qh, kh, vh, causal=causal,
                          use_pallas=cfg.use_flash)
    out = out.transpose(0, 2, 1, 3)            # (B, S, H, hd)
    out = r.constrain(out, *_head_axes(r, cfg, cfg.n_heads, "heads"))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.dtype))
    return r.constrain(y, "batch", "seq", "embed_act")


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               n_layers: int | None = None) -> KVCache:
    """Stacked-over-layers KV cache pytree (leading dim = layers)."""
    L = n_layers or cfg.n_layers
    shape = (L, batch, cfg.n_kv_heads, max_len, cfg.hd)
    r = get_rules()
    k = jnp.zeros(shape, cfg.dtype)
    v = jnp.zeros(shape, cfg.dtype)
    k = r.constrain(k, "layers", "batch", "kv_heads", "kv_seq", None)
    v = r.constrain(v, "layers", "batch", "kv_heads", "kv_seq", None)
    return KVCache(k, v, jnp.zeros((), jnp.int32))


def attention_decode(params, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, length: jnp.ndarray,
                     cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray,
                                                jnp.ndarray]:
    """One-token decode.  x: (B, 1, d); cache_k/v: (B, Hkv, S_max, hd).

    Returns (y, new_k, new_v).  Attention runs over the first ``length+1``
    cache slots via masking (static shapes — serving-friendly).
    """
    r = get_rules()
    b, one, d = x.shape
    s_max = cache_k.shape[2]
    # re-pin the cache sharding: scan slicing/reshapes drop constraints
    # and XLA would otherwise gather the full cache per step.
    cache_k = r.constrain(cache_k, "batch", "kv_heads", "kv_seq", None)
    cache_v = r.constrain(cache_v, "batch", "kv_heads", "kv_seq", None)
    positions = jnp.full((1,), length, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    # insert new kv at slot `length`
    kh = k.transpose(0, 2, 1, 3)               # (B, Hkv, 1, hd)
    vh = v.transpose(0, 2, 1, 3)
    new_k = jax.lax.dynamic_update_slice(
        cache_k, kh.astype(cache_k.dtype), (0, 0, length, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache_v, vh.astype(cache_v.dtype), (0, 0, length, 0))
    new_k = r.constrain(new_k, "batch", "kv_heads", "kv_seq", None)
    new_v = r.constrain(new_v, "batch", "kv_heads", "kv_seq", None)
    qh = q.transpose(0, 2, 1, 3)               # (B, Hq, 1, hd)
    group = cfg.n_heads // cfg.n_kv_heads
    qg = qh.reshape(b, cfg.n_kv_heads, group, cfg.hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hd, jnp.float32))
    # NB: never .astype(f32) the cache — XLA hoists the convert out of
    # the layer loop and materialises the whole cache in fp32.  bf16
    # inputs + preferred_element_type gives fp32 accumulation instead.
    logits = jnp.einsum("bhgk,bhsk->bhgs", qg.astype(new_k.dtype), new_k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s_max)[None, None, None, :] <= length
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsk->bhgk", probs.astype(new_v.dtype), new_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, cfg.n_heads, 1, cfg.hd).transpose(0, 2, 1, 3)
    out = out.astype(cfg.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.dtype))
    return r.constrain(y, "batch", None, "embed_act"), new_k, new_v
