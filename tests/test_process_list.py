"""Process-list construction + the pre-flight plugin-list check."""
import numpy as np
import pytest

from repro.core import (BaseLoader, BaseSaver, DataSet, LambdaFilter,
                        ProcessList, ProcessListError)


class L(BaseLoader):
    name = "loader"

    def load(self):
        d = DataSet(self.out_dataset_names[0], (4, 4), np.float32,
                    ("a", "b"), backing=np.zeros((4, 4), np.float32))
        d.add_pattern("P", core=("b",), slice_=("a",))
        return [d]


class S(BaseSaver):
    name = "saver"

    def save(self, ds):
        pass


def _ok_list():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(S, in_datasets=("tomo",))
    return pl


def test_valid_list_passes():
    assert "tomo" in _ok_list().check()


def test_empty_list_rejected():
    with pytest.raises(ProcessListError):
        ProcessList().check()


def test_missing_loader_rejected():
    pl = ProcessList()
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("x",), out_datasets=("x",))
    pl.add(S, in_datasets=("x",))
    with pytest.raises(ProcessListError, match="loader"):
        pl.check()


def test_missing_saver_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    with pytest.raises(ProcessListError, match="saver"):
        pl.check()


def test_unknown_input_dataset_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("nope",), out_datasets=("x",))
    pl.add(S, in_datasets=("x",))
    with pytest.raises(ProcessListError, match="nope"):
        pl.check()


def test_wrong_dataset_counts_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("tomo", "tomo2"), out_datasets=("x",))
    pl.add(S, in_datasets=("x",))
    with pytest.raises(ProcessListError, match="in_datasets"):
        pl.check()


def test_unknown_param_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b, "bogus_param": 3},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(S, in_datasets=("tomo",))
    with pytest.raises(ProcessListError, match="bogus_param"):
        pl.check()


def test_loader_after_processing_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("a",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("a",), out_datasets=("a",))
    pl.add(L, out_datasets=("b",))
    pl.add(S, in_datasets=("a",))
    with pytest.raises(ProcessListError, match="loaders"):
        pl.check()


def test_json_roundtrip(tmp_path):
    pl = _ok_list()
    path = str(tmp_path / "chain.json")
    pl.save(path)
    pl2 = ProcessList.load(path)
    assert len(pl2) == len(pl)
    assert [e.cls for e in pl2] == [e.cls for e in pl]
    # function params are not serialisable and are dropped — the check
    # re-validates structure
    assert pl2.entries[1].in_datasets == ("tomo",)


# ------------------------------------------------------ run_process_list
class DescribeLoader(BaseLoader):
    """Loader that only DESCRIBES its dataset (no backing) — the inline
    case run_process_list's ``data`` argument exists for."""
    name = "describe_loader"
    parameters = {"shape": None}

    def load(self):
        d = DataSet(self.out_dataset_names[0], self.params["shape"],
                    np.float32, ("theta", "y", "x"))
        d.add_pattern("PROJECTION", core=("y", "x"), slice_=("theta",))
        return [d]


class MetaSaver(BaseSaver):
    name = "meta_saver"

    def save(self, ds):
        ds.metadata["saved"] = True


def test_run_process_list_prepopulates_loader_datasets():
    from repro.core import run_process_list
    rng = np.random.default_rng(0)
    a = rng.normal(size=(3, 4, 4)).astype(np.float32)
    pl = ProcessList()
    pl.add(DescribeLoader, params={"shape": list(a.shape)},
           out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b * 2.0,
                                 "pattern": "PROJECTION"},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(MetaSaver, in_datasets=("tomo",))
    out = run_process_list(pl, {"tomo": a, "not_a_dataset": a})
    np.testing.assert_allclose(np.asarray(out["tomo"].materialise()),
                               a * 2.0, rtol=1e-6)


def test_run_process_list_ignores_plugin_produced_names():
    """``data`` only pre-populates LOADER-created datasets; a name that a
    plugin produces must come from the chain, not the dict."""
    from repro.core import run_process_list
    rng = np.random.default_rng(0)
    a = rng.normal(size=(3, 4, 4)).astype(np.float32)
    pl = ProcessList()
    pl.add(DescribeLoader, params={"shape": list(a.shape)},
           out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b + 1.0,
                                 "pattern": "PROJECTION"},
           in_datasets=("tomo",), out_datasets=("filtered",))
    pl.add(MetaSaver, in_datasets=("filtered",))
    out = run_process_list(pl, {"tomo": a,
                                "filtered": np.zeros_like(a)})
    np.testing.assert_allclose(np.asarray(out["filtered"].materialise()),
                               a + 1.0, rtol=1e-6)
