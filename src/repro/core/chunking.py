"""The Savu chunking optimiser (paper §IV.A, Table 1 + Eq (1)–(7)).

Given the first two access patterns of a dataset — *now* (how the plugin
that writes it slices) and *next* (how the following plugin reads it) —
choose per-dimension chunk values c_i that

  * minimise the number of chunks touched per frame access, while
  * keeping one chunk's byte size <= the cache budget M
    (HDF5 raw-chunk cache, default 1 MB, in the paper; VMEM tile budget
    in the TPU adaptation).

Dimension typing per pattern (paper Table 1):
  'core'  — a core dimension (delivered whole),
  'slice' — the *first* slice dimension (fastest-changing),
  'other' — any other slice dimension.

The published table is used as follows (c0 = start value, [lo, hi] =
bounds, dims sorted for adjustment order):

  (core , core ) : c0 = dim              bounds [1, dim]
  (core , slice) : c0 = min(f, dim)      bounds [1, min(f_p, dim)]
  (core , other) : c0 = 1                bounds [1, dim]
  (slice, slice) : c0 = min(f, dim)      bounds [1, min(f_p, dim)]
  (slice, other) : c0 = 1                bounds [1, dim]
  (other, other) : c0 = 1                fixed

(symmetric in now/next).  f = frames per plugin call, f_p = average
frames handled per process.  Adjustable dims D_a = core dims ∪ first
slice dims (Eq 1's D_c ∪ D_s).  When growing, core dims are grown first
(order (D_c, D_s)); when shrinking, slice dims are shrunk first
((D_s, D_c)) — exactly Eq (1)'s two branches.  Growth steps are +a for
core dims and +a·f for slice dims; shrink steps are half for core dims
and −a·f for slice dims (Table 1's α columns), with a the largest /
smallest integer keeping the product within M (Eqs (2)–(7), implemented
as an integral line search).

The same optimiser doubles as the Pallas BlockSpec tile chooser
(:func:`optimise_block_shape`): M becomes a VMEM budget and the minor
dims are rounded to hardware tile multiples (8×128 fp32 lanes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .patterns import Pattern

DEFAULT_CACHE_BYTES = 1_000_000  # HDF5 raw data chunk cache (paper: 1MB)


@dataclasses.dataclass(frozen=True)
class DimPlan:
    dim: int
    size: int
    type_now: str
    type_next: str
    c0: int
    lo: int
    hi: int
    adjustable: bool
    kind: str  # 'core' | 'slice' | 'fixed' — adjustment family


def _dim_types(pattern: Pattern | None, ndim: int) -> list[str]:
    if pattern is None:
        return ["other"] * ndim
    return [pattern.dim_type(d) for d in range(ndim)]


def plan_dims(shape: Sequence[int], now: Pattern, next_: Pattern | None,
              frames: int, frames_per_proc: int) -> list[DimPlan]:
    ndim = len(shape)
    tn = _dim_types(now, ndim)
    tx = _dim_types(next_, ndim)
    plans = []
    for d in range(ndim):
        size = int(shape[d])
        pair = frozenset((tn[d], tx[d]))
        f = max(1, min(frames, size))
        fp = max(f, min(frames_per_proc, size))
        if pair == frozenset(("core",)):                     # core/core
            c0, lo, hi, adj, kind = size, 1, size, True, "core"
        elif pair == frozenset(("core", "slice")):
            c0, lo, hi, adj, kind = f, 1, fp, True, "slice"
        elif pair == frozenset(("core", "other")):
            c0, lo, hi, adj, kind = 1, 1, size, True, "core"
        elif pair == frozenset(("slice",)):                  # slice/slice
            c0, lo, hi, adj, kind = f, 1, fp, True, "slice"
        elif pair == frozenset(("slice", "other")):
            c0, lo, hi, adj, kind = 1, 1, size, True, "core"
        else:                                                # other/other
            c0, lo, hi, adj, kind = 1, 1, 1, False, "fixed"
        plans.append(DimPlan(d, size, tn[d], tx[d], min(c0, size), lo,
                             min(hi, size), adj, kind))
    return plans


def _product_bytes(c: list[int], itemsize: int) -> int:
    return int(np.prod(c, dtype=np.int64)) * itemsize


def optimise_chunks(shape: Sequence[int], now: Pattern,
                    next_: Pattern | None = None, *,
                    itemsize: int = 4, frames: int = 1,
                    frames_per_proc: int | None = None,
                    cache_bytes: int = DEFAULT_CACHE_BYTES) -> tuple[int, ...]:
    """Return the optimised per-dimension chunk tuple (paper Eq (1))."""
    if frames_per_proc is None:
        frames_per_proc = max(frames * 8, frames)
    plans = plan_dims(shape, now, next_, frames, frames_per_proc)
    c = [p.c0 for p in plans]

    # Shrink phase (Eq (1) lower branch): order (D_s, D_c) — slice dims
    # first, then core dims — until one chunk fits in M.
    shrink_order = ([p for p in plans if p.adjustable and p.kind == "slice"] +
                    [p for p in plans if p.adjustable and p.kind == "core"])
    f = max(1, frames)
    guard = 0
    while _product_bytes(c, itemsize) > cache_bytes and guard < 10_000:
        guard += 1
        progressed = False
        for p in shrink_order:
            if _product_bytes(c, itemsize) <= cache_bytes:
                break
            cur = c[p.dim]
            if cur <= p.lo:
                continue
            if p.kind == "core":
                new = max(p.lo, cur // 2)            # α^d = c/2
            else:
                new = max(p.lo, cur - f)             # α^d = c − a·f (a=1)
            if new < cur:
                c[p.dim] = new
                progressed = True
        if not progressed:
            # force: shrink any adjustable dim to lo
            for p in shrink_order:
                c[p.dim] = p.lo
            break

    # Grow phase (Eq (1) upper branch): order (D_c, D_s); pick the largest
    # integral step `a` that keeps the chunk within both the dim bound and
    # M (Eqs (2)–(4) as an argmax line search).
    grow_order = ([p for p in plans if p.adjustable and p.kind == "core"] +
                  [p for p in plans if p.adjustable and p.kind == "slice"])
    for p in grow_order:
        rest = _product_bytes(c, itemsize) // max(1, c[p.dim])
        if rest == 0:
            continue
        limit = min(p.hi, cache_bytes // rest if rest else p.hi)
        step = 1 if p.kind == "core" else f
        if limit <= c[p.dim]:
            continue
        # largest a ∈ N0 with c + a·step <= limit
        a = (limit - c[p.dim]) // step
        c[p.dim] = c[p.dim] + a * step

    return tuple(int(v) for v in c)


def chunks_touched(shape: Sequence[int], chunks: Sequence[int],
                   index: tuple[slice, ...]) -> int:
    """Number of chunks a slab access touches (cost model for benches)."""
    n = 1
    for dim, (size, ch) in enumerate(zip(shape, chunks)):
        sl = index[dim]
        start = sl.start or 0
        stop = size if sl.stop is None else min(sl.stop, size)
        first = start // ch
        last = (stop - 1) // ch
        n *= (last - first + 1)
    return n


def naive_chunks(shape: Sequence[int], itemsize: int,
                 cache_bytes: int = DEFAULT_CACHE_BYTES) -> tuple[int, ...]:
    """The 'row-major greedy' baseline HDF5 guess (h5py-style): fill from
    the fastest-varying dim backwards until M is hit — pattern-oblivious."""
    c = [1] * len(shape)
    budget = max(1, cache_bytes // itemsize)
    for d in reversed(range(len(shape))):
        take = min(shape[d], budget)
        c[d] = max(1, take)
        budget = max(1, budget // max(1, shape[d]))
        if budget == 1:
            break
    return tuple(c)


# ----------------------------------------------------------------------
# TPU adaptation: the same optimiser chooses Pallas BlockSpec tiles.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024   # conservative slice of 16MB VMEM
_LANE = 128
_SUBLANE = {1: 32, 2: 16, 4: 8, 8: 8}


def _round_to(v: int, m: int, cap: int) -> int:
    if v >= cap:
        return cap
    return max(m, (v // m) * m) if v >= m else v


def optimise_block_shape(shape: Sequence[int], now: Pattern,
                         next_: Pattern | None = None, *,
                         itemsize: int = 4, frames: int = 1,
                         vmem_bytes: int = VMEM_BUDGET_BYTES
                         ) -> tuple[int, ...]:
    """Pick a hardware-aligned VMEM tile using the paper's optimiser.

    The minor-most dim is rounded to the 128-lane register width and the
    second-minor to the dtype sublane count, so that the MXU/VPU see
    aligned tiles; the product is kept within ``vmem_bytes``.
    """
    c = list(optimise_chunks(shape, now, next_, itemsize=itemsize,
                             frames=frames, cache_bytes=vmem_bytes))
    nd = len(shape)
    if nd >= 1:
        c[-1] = _round_to(max(c[-1], min(_LANE, shape[-1])), _LANE, shape[-1])
    if nd >= 2:
        sub = _SUBLANE.get(itemsize, 8)
        c[-2] = _round_to(max(c[-2], min(sub, shape[-2])), sub, shape[-2])
    # re-shrink leading dims if alignment blew the budget
    for d in range(nd - 2 if nd >= 2 else 0):
        while _product_bytes(c, itemsize) > vmem_bytes and c[d] > 1:
            c[d] = max(1, c[d] // 2)
    return tuple(int(v) for v in c)
