from .registry import (ARCH_IDS, SHAPES, SUBQUADRATIC, CellSpec, all_cells,
                       cell_supported, get_config, input_specs, smoke_batch)

__all__ = ["ARCH_IDS", "SHAPES", "SUBQUADRATIC", "CellSpec", "all_cells",
           "cell_supported", "get_config", "input_specs", "smoke_batch"]
