"""Per-architecture smoke tests (reduced configs) + decode/forward
consistency — one reduced-config forward/train step per assigned arch,
asserting output shapes and no NaNs (assignment requirement)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_batch
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.training import init_training, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    batch = smoke_batch(cfg, batch=2, seq=16)
    params, opt = init_training(model, jax.random.key(0))

    logits, aux = model.forward(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    ts = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=1)))
    params2, opt2, metrics = ts(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    batch = smoke_batch(cfg, batch=2, seq=8)
    params = model.init(jax.random.key(1))
    logits, cache = model.prefill(params, batch, max_len=12)
    assert logits.shape[1] == 1 and logits.shape[-1] == cfg.vocab
    tok = np.argmax(np.asarray(logits), -1).astype(np.int32)
    lg, cache = model.decode_step(params, tok, cache)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training-time logits —
    the strongest cache-correctness check."""
    from repro.models.common import ModelConfig
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=3, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      dtype=jnp.float32, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (2, 10)).astype(np.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})

    # prefill on the first 4 tokens, then teacher-forced decode
    _, cache = model.prefill(params, {"tokens": toks[:, :4]}, max_len=10)
    for t in range(4, 10):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_zamba():
    cfg = get_config("zamba2-1.2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, 8)
    # decode the whole sequence token by token from an empty cache
    for t in range(7):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=5e-4, atol=5e-4)


def test_decode_matches_forward_xlstm():
    cfg = get_config("xlstm-1.3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, 8)
    for t in range(7):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=5e-4, atol=5e-4)


def test_moe_routes_to_multiple_experts():
    from repro.models.common import ModelConfig
    from repro.models.moe import init_moe, moe_fwd
    cfg = ModelConfig(arch_id="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=32,
                      n_experts=4, top_k=2, moe_d_ff=32, moe_every=1,
                      dtype=jnp.float32, remat=False)
    p = init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 16)),
                    jnp.float32)
    out, aux = moe_fwd(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0
    # capacity drop: zero tokens lost with generous capacity
    assert np.all(np.isfinite(np.asarray(out)))


def test_moe_capacity_drop_is_graceful():
    from repro.models.common import ModelConfig
    from repro.models.moe import init_moe, moe_fwd
    import dataclasses
    cfg = ModelConfig(arch_id="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=32,
                      n_experts=4, top_k=1, moe_d_ff=32, moe_every=1,
                      capacity_factor=0.1, dtype=jnp.float32, remat=False)
    p = init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 16)),
                    jnp.float32)
    out, _ = moe_fwd(p, x, cfg)       # most tokens dropped -> zeros, not NaN
    assert np.all(np.isfinite(np.asarray(out)))


def test_param_counts_match_published():
    c = get_config("qwen3-moe-235b-a22b")
    assert abs(c.param_count() / 1e9 - 235) < 10
    assert abs(c.active_param_count() / 1e9 - 22) < 3
    c = get_config("llama4-maverick-400b-a17b")
    assert abs(c.param_count() / 1e9 - 400) < 25
    c = get_config("phi4-mini-3.8b")
    assert abs(c.param_count() / 1e9 - 3.8) < 0.5
    c = get_config("xlstm-1.3b")
    assert abs(c.param_count() / 1e9 - 1.3) < 0.4


def test_rope_partial_fraction():
    from repro.models.layers import apply_rope, rope_freqs
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 4, 2, 8)),
                    jnp.float32)
    cos, sin = rope_freqs(8, 0.5, 10_000.0, jnp.arange(4))
    y = apply_rope(x, cos, sin)
    # the un-rotated second half passes through untouched
    np.testing.assert_array_equal(np.asarray(y[..., 4:]),
                                  np.asarray(x[..., 4:]))
    assert not np.allclose(np.asarray(y[..., :4]), np.asarray(x[..., :4]))
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-6)
