"""End-to-end behaviour tests for the paper's system: the full Savu
chain driven exactly as a user would (process list in, NeXus-style
manifest + reconstructed volume out), across transports, plus the
train→checkpoint→restore→serve lifecycle of the LM substrate."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (ChunkedFileTransport, InMemoryTransport,
                        PluginRunner)
from repro.distributed import CheckpointManager
from repro.models import ModelConfig, build_model
from repro.optim import AdamWConfig
from repro.training import greedy_generate, init_training, make_train_step
from repro.tomo import standard_chain


def test_user_workflow_tomo(tmp_path):
    """Process list → runner → manifest + profile + recon, serial mode."""
    chain = standard_chain(n_det=64, n_angles=96, n_rows=2)
    chain.save(str(tmp_path / "chain.json"))           # configurator file
    runner = PluginRunner(chain, InMemoryTransport(),
                          output_dir=str(tmp_path))
    out = runner.run()
    assert "recon" in out
    man = json.load(open(tmp_path / "savu_manifest.nxs.json"))
    assert any(d["name"] == "recon" for d in man["datasets"])
    assert runner.profiler.totals()          # every plugin profiled


def test_user_workflow_out_of_core(tmp_path):
    """Chunked-file mode: every intermediate is a file on disk and the
    chain reaches the same answer (the paper's RAM-free claim)."""
    tr = ChunkedFileTransport(str(tmp_path / "scratch"))
    runner = PluginRunner(standard_chain(n_det=64, n_angles=64, n_rows=1),
                          tr)
    out = runner.run()
    files = os.listdir(tmp_path / "scratch")
    assert len(files) >= 4                   # one per intermediate dataset
    recon = tr.read(out["recon"])
    assert np.all(np.isfinite(recon))


def test_lifecycle_train_checkpoint_restore_serve(tmp_path):
    """Train a small LM, checkpoint, restore, serve — the full loop."""
    cfg = ModelConfig(arch_id="life", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab=64, dtype=jnp.float32, remat=False)
    model = build_model(cfg)
    params, opt = init_training(model, jax.random.key(0))
    ts = jax.jit(make_train_step(
        model, AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=40)))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (4, 16)).astype(np.int32)
    batch = {"tokens": toks, "labels": toks}
    cm = CheckpointManager(str(tmp_path), keep=2)
    for step in range(6):
        params, opt, metrics = ts(params, opt, batch)
        if step % 3 == 2:
            cm.save(step, {"params": params, "opt": opt},
                    extra={"loss": float(metrics["loss"])}, blocking=True)
    restored, man = cm.restore({"params": params, "opt": opt})
    assert man["step"] == 5
    out = greedy_generate(model, restored["params"], {"tokens": toks},
                          max_new=4, max_len=24)
    assert out.shape == (4, 4)
    # restored params give the same next-step loss as the originals
    _, _, m1 = ts(params, opt, batch)
    _, _, m2 = ts(restored["params"], restored["opt"], batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
