"""PipelineScheduler — many process lists, shared workers, one cache.

Savu runs one pipeline per MPI job; a facility runs *hundreds* of them a
day.  The scheduler closes that gap:

* ``n_workers`` threads pull jobs off the :class:`JobQueue` and drive
  each job's :class:`PluginRunner` through its resumable plugin steps —
  with ≥2 workers one job's host-side I/O (ChunkedFileTransport chunk
  reads, checkpoint writes) overlaps another job's jit compute, which
  releases the GIL while XLA executes.
* every job's transport shares one process-level
  :class:`~repro.service.compile_cache.CompileCache`, so resubmitting an
  identical process list skips every ``jax.jit`` retrace (the paper's
  "same pipeline, many datasets" case).
* ``batch_identical=True`` gang-schedules queued jobs whose chain
  signatures match: each plugin step executes as ONE compiled call over
  all gang members' datasets (``ShardedTransport.run_plugin_batch``),
  with per-job calibration constants riding along as stacked arguments.
* an optional :class:`CheckpointStore` persists per-plugin completion +
  surviving datasets after every step; a killed job resubmitted with the
  same id restarts at the last finished plugin (Savu's MPI
  checkpointing).
"""
from __future__ import annotations

import dataclasses
import hmac
import os
import re
import secrets
import shutil
import tempfile
import threading
import time
import traceback
from typing import Any, Callable

import numpy as np

from ..core.framework import PluginRunner
from ..core.plugin import _is_jsonable
from ..core.profiler import Profiler
from ..core.transport import InMemoryTransport, Transport
from ..obs.metrics import MetricsRegistry
from ..obs.trace import use_trace
from .checkpoint import CheckpointStore
from .job import Job, JobState
from .queue import JobQueue
from .wire import WireError, chain_plugin_names, to_spec


class UpstreamGone(RuntimeError):
    """A workflow job's upstream result reference cannot be resolved —
    the upstream job (or its stored result) was evicted between the
    dependency becoming ready and this job dispatching.  The job is
    cancelled with ``cancel_reason="upstream_evicted"``, mirroring the
    queue's own eviction cascade (docs/workflows.md)."""


def _upstream_ref(params: dict[str, Any]) -> tuple[str, str | None] | None:
    """The ``(from_job, dataset)`` upstream-result reference of an
    ``upstream_loader`` entry, or None when the entry needs no
    resolution (no ref, or the data/path is already materialised).
    Accepts both wire forms: split ``from_job``/``dataset`` params and
    the ``"data": {"from_job": ..., "dataset": ...}`` object."""
    data = params.get("data")
    if isinstance(data, dict) and data.get("from_job"):
        return str(data["from_job"]), data.get("dataset")
    if params.get("data") is not None or params.get("path"):
        return None
    fj = params.get("from_job")
    if fj:
        return str(fj), params.get("dataset")
    return None


def _observe_terminal(metrics: MetricsRegistry | None, job: Job,
                      events=None) -> None:
    """Fold one terminal job into the registry: outcome counter,
    end-to-end latency, and per-plugin process wall from its trace.
    Every terminal path funnels through here exactly once, so this is
    also where the structured ``job.complete`` event is emitted."""
    if job.stream is not None:
        # every terminal path funnels through here — the retained frame
        # chunks (kept for lease-expiry refetch) are no longer needed
        job.stream.drop_buffers()
    if events is not None:
        events.emit("job.complete", trace_id=job.trace_id,
                    job_id=job.job_id, worker_id=job.worker_id or "",
                    state=job.state.value, attempt=job.attempt,
                    **({"error": job.error} if job.error else {}))
    if metrics is None:
        return
    if job.state is JobState.DONE:
        metrics.counter("jobs.completed").inc()
    elif job.state is JobState.FAILED:
        metrics.counter("jobs.failed").inc()
    elif job.state is JobState.CANCELLED:
        metrics.counter("jobs.cancelled").inc()
    if job.finished_at is not None:
        metrics.histogram("job.latency.e2e").observe(
            job.finished_at - job.submitted_at)


def _observe_plugin_spans(metrics: MetricsRegistry | None,
                          spans) -> None:
    """Feed ``process``-phase plugin spans into the plugin-wall
    histograms (the aggregate plus one per plugin name).  Callers pass
    only spans seen for the FIRST time (a fresh run, or the newly-merged
    slice of a heartbeat) so nothing double-counts."""
    if metrics is None:
        return
    for s in spans:
        if not s.name.startswith("plugin.") or s.end is None:
            continue
        if s.attrs.get("phase") != "process":
            continue
        metrics.histogram("plugin.wall").observe(s.wall)
        plugin = s.attrs.get("plugin") or s.name
        metrics.histogram(f"plugin.wall.{plugin}").observe(s.wall)
        if s.attrs.get("flops"):
            metrics.gauge(f"plugin.flops.{plugin}").set(s.attrs["flops"])


class PipelineScheduler:
    """Drives jobs popped from a :class:`JobQueue` over shared worker
    threads — reproduces the paper's §I premise (one framework, many
    simultaneous datasets) as a long-lived multi-tenant service."""

    def __init__(self, queue: JobQueue, *,
                 transport_factory: Callable[[Job], Transport] | None = None,
                 n_workers: int = 2,
                 checkpoints: CheckpointStore | None = None,
                 batch_identical: bool = False,
                 batch_max: int = 4,
                 fuse: bool = False,
                 compile_cache=None,
                 metrics: MetricsRegistry | None = None,
                 events=None):
        """Args:
            queue: the admission queue workers pull from.
            transport_factory: Job -> Transport for each dispatch
                (default: a fresh ``InMemoryTransport`` per job).
            n_workers: worker threads (≥2 overlaps one job's host I/O
                with another's jit compute; see module docstring).
            checkpoints: save after every plugin step + restore
                resubmitted job ids (None disables).
            batch_identical: gang queued jobs with matching chain
                signatures into one compiled call per step.
            batch_max: gang size bound.
            fuse: compile consecutive linear plugins as one jit.
            compile_cache: held only for ``stats()`` reporting — wire
                the SAME object into the transports the factory builds.
            metrics: telemetry registry (``repro.obs``) to record job
                outcomes/latencies into; None disables.
            events: structured :class:`~repro.obs.log.EventLog` for
                state-transition records; None disables.
        """
        self.queue = queue
        self.transport_factory = (transport_factory
                                  or (lambda job: InMemoryTransport()))
        self.n_workers = max(1, n_workers)
        self.checkpoints = checkpoints
        self.batch_identical = batch_identical
        self.batch_max = max(2, batch_max)
        self.fuse = fuse
        self.compile_cache = compile_cache   # held for stats reporting
        self.metrics = metrics
        self.events = events
        # terminal transitions the QUEUE performs (queue-side cancels,
        # workflow dependency cascades) are observed here — the
        # scheduler observes its own in _finish, so every terminal job
        # is counted exactly once (docs/workflows.md)
        queue.add_terminal_hook(
            lambda job: _observe_terminal(self.metrics, job,
                                          self.events))
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self.jobs_done = 0
        self.jobs_failed = 0
        self.gangs_run = 0
        self._started_at: float | None = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "PipelineScheduler":
        """Start the worker threads (idempotent).  Returns self."""
        if self._threads:
            return self
        self._started_at = time.time()
        for i in range(self.n_workers):
            # workers poll the event they were STARTED with, so a
            # shutdown always reaches this generation even after _stop
            # is re-armed for the next start()
            t = threading.Thread(target=self._worker, args=(self._stop,),
                                 name=f"pipeline-w{i}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def drain(self, timeout: float | None = None) -> bool:
        """Wait for every submitted job to reach a terminal state.
        Returns False on timeout (seconds; None = wait forever)."""
        return self.queue.wait_all(timeout)

    def shutdown(self, wait: bool = True) -> None:
        """Stop the workers.  In-flight jobs finish their current run;
        queued jobs stay queued for the next ``start()``.  With
        ``wait=True`` blocks until the worker threads exit."""
        self._stop.set()
        if wait:
            for t in self._threads:
                t.join(timeout=30)
        self._threads = []
        self._stop = threading.Event()

    def stats(self) -> dict[str, Any]:
        """Aggregate counters (``GET /stats``): ``jobs_done``,
        ``jobs_failed``, ``gangs_run``, ``pending``, scheduler ``wall``
        since start, and the shared cache's ``compile_cache`` hit/miss
        counts when one was wired in."""
        out: dict[str, Any] = {
            "jobs_done": self.jobs_done, "jobs_failed": self.jobs_failed,
            "gangs_run": self.gangs_run, "pending": self.queue.pending(),
        }
        if self._started_at is not None:
            out["wall"] = time.time() - self._started_at
        if self.compile_cache is not None:
            out["compile_cache"] = self.compile_cache.stats()
        out["queue"] = self.queue.queue_info()
        return out

    # -- worker loop ----------------------------------------------------
    def _worker(self, stop: threading.Event) -> None:
        while not stop.is_set():
            if self.batch_identical:
                jobs = self.queue.get_batch(self.batch_max, timeout=0.1)
            else:
                job = self.queue.get(timeout=0.1)
                jobs = [job] if job is not None else []
            if not jobs:
                continue
            if len(jobs) == 1:
                self._run_job(jobs[0])
            else:
                self._run_gang(jobs)

    # -- solo execution -------------------------------------------------
    def _fail(self, job: Job, exc: Exception) -> None:
        job.error = f"{type(exc).__name__}: {exc}"
        job.metadata["traceback"] = traceback.format_exc()
        job.state = JobState.FAILED

    def _dispatched(self, job: Job) -> None:
        """Telemetry at dispatch: the queue.wait span (from submission,
        or from the last requeue), the queue-latency histogram, and the
        ``job.lease`` event (in-process mode the "worker" is the
        scheduler thread that claimed the job)."""
        now = job.started_at or time.time()
        waited_from = job.requeued_at or job.submitted_at
        job.trace.record("queue.wait", waited_from, now,
                         attrs={"priority": job.priority})
        if self.metrics is not None:
            self.metrics.histogram("job.latency.queue").observe(
                now - waited_from)
        if self.events is not None:
            self.events.emit("job.lease", trace_id=job.trace_id,
                             job_id=job.job_id,
                             worker_id=threading.current_thread().name,
                             priority=job.priority)

    def _drive(self, job: Job, runner: PluginRunner) -> None:
        """Step a PREPARED runner to completion (status + checkpoints)."""
        job.plugin_index = runner.current_step
        job.state = JobState.RUNNING
        while runner.step():
            job.plugin_index = runner.current_step
            if self.checkpoints is not None:
                with job.trace.span("checkpoint.save"):
                    self.checkpoints.save(job.job_id, runner)
        runner.finalise()
        job.state = JobState.DONE
        if self.checkpoints is not None:
            self.checkpoints.clear(job.job_id)

    # -- workflow upstream inputs (docs/workflows.md) -------------------
    def _upstream_array(self, from_job: str,
                        dataset: str | None) -> np.ndarray:
        """Resolve one upstream-result reference against the queue: the
        upstream job's live runner datasets (in-process runs) or its
        remote ``.npy`` (mixed deployments).  Raises UpstreamGone when
        the upstream — or its result — is no longer reachable."""
        try:
            up = self.queue.job(from_job)
        except KeyError:
            raise UpstreamGone(
                f"upstream {from_job!r} was evicted before its result "
                f"was consumed") from None
        if up.state is not JobState.DONE:
            raise UpstreamGone(
                f"upstream {from_job!r} is {up.state.value}, not done")
        if up.remote_results:
            name = dataset or next(
                (k for k in up.remote_results if not k.startswith("__")),
                None)
            path = up.remote_results.get(name) if name else None
            if path is None or not os.path.exists(path):
                raise UpstreamGone(
                    f"upstream {from_job!r} has no stored result "
                    f"{name or dataset!r}")
            return np.load(path)
        runner = up.runner
        if runner is None:
            raise UpstreamGone(
                f"upstream {from_job!r} result was evicted "
                f"(max_history)")
        name = dataset or (runner.result_names() or [None])[0]
        if name is None or name not in runner.datasets:
            raise UpstreamGone(
                f"upstream {from_job!r} has no dataset {name!r} "
                f"(available: {sorted(runner.datasets)})")
        return np.ascontiguousarray(
            np.asarray(runner.transport.read(runner.datasets[name])))

    def _resolve_upstream(self, job: Job) -> None:
        """Materialise every upstream-result reference in the job's
        chain before the runner is built: the referenced array rides in
        as the entry's ``data`` param (``upstream_loader``).  The
        resolved value is a data param — excluded from the chain
        signature — so downstream nodes still gang with other ready
        jobs."""
        for e in job.process_list.entries:
            ref = _upstream_ref(e.params)
            if ref is None:
                continue
            with job.trace.span("upstream.fetch", from_job=ref[0]):
                e.params["data"] = self._upstream_array(*ref)

    def _cancel_evicted(self, job: Job, exc: UpstreamGone) -> None:
        job.error = str(exc)
        job.state = JobState.CANCELLED
        job.cancel_reason = "upstream_evicted"

    def _run_job(self, job: Job) -> None:
        job.started_at = time.time()
        job.state = JobState.CHECKING
        self._dispatched(job)
        try:
            with use_trace(job.trace):
                self._resolve_upstream(job)
                runner = PluginRunner(job.process_list,
                                      self.transport_factory(job),
                                      profiler=Profiler(trace=job.trace),
                                      fuse=self.fuse)
                job.runner = runner
                runner.prepare()
                if self.checkpoints is not None:
                    with job.trace.span("checkpoint.restore"):
                        job.resumed_from = self.checkpoints.restore(
                            job.job_id, runner)
                job.n_plugins = runner.n_steps
                if job.streaming:
                    self._drive_stream(job, runner)
                else:
                    self._drive(job, runner)
        except UpstreamGone as e:
            self._cancel_evicted(job, e)
        except Exception as e:
            self._fail(job, e)
        finally:
            self._finish([job])

    def _drive_stream(self, job: Job, runner: PluginRunner) -> None:
        """Arrival-driven execution (docs/streaming.md): feed frames
        from the job's server-side buffer as they land, pump the runner
        over each new slab, checkpoint after progress, finish once every
        group has completed.  ``stream.exec_lock`` serialises runner
        access against on-demand previews."""
        st = job.stream
        # idempotent — a checkpoint restore may already have enabled it
        # (and restored the ingested prefix + watermark)
        runner.enable_streaming()
        state = runner.stream_state()
        total, fed = state["total"], state["ingested"]
        job.frames_consumed = fed
        job.plugin_index = runner.current_step
        job.state = JobState.RUNNING
        while runner.current_step < runner.n_steps:
            with st.lock:
                chunk, _ = st.fetch(fed)
                eof = st.eof
                arrived = (st.arrival_time(fed) if chunk is not None
                           else None)
            if chunk is None:
                if eof and fed < total:
                    raise RuntimeError(
                        f"stream ended at frame {fed} but the loader "
                        f"declares {total} frames")
                with st.cond:       # starved: wait for ingest/EOF
                    if st.watermark <= fed and not st.eof:
                        st.cond.wait(timeout=0.25)
                continue
            with st.exec_lock:
                fed = runner.feed(chunk, fed)
                if eof and fed == total:
                    runner.mark_eof()
                t0 = time.time()
                runner.pump()
            if self.metrics is not None:
                self.metrics.histogram("stream.window_latency_s") \
                    .observe(time.time() - t0)
                if arrived is not None:
                    self.metrics.histogram("stream.ingest_lag_s") \
                        .observe(max(0.0, time.time() - arrived))
            job.frames_consumed = fed
            job.plugin_index = runner.current_step
            if self.checkpoints is not None:
                with job.trace.span("checkpoint.save"):
                    self.checkpoints.save(job.job_id, runner)
        runner.finalise()
        job.state = JobState.DONE
        if self.checkpoints is not None:
            self.checkpoints.clear(job.job_id)

    # -- gang execution -------------------------------------------------
    def _run_gang(self, jobs: list[Job]) -> None:
        """Identical chains from several jobs step in lockstep; each
        single-plugin step becomes one batched compiled call.  Faults
        are isolated where possible: a job whose prepare fails is marked
        failed alone, and a batch-signature mismatch (chain signatures
        equal but runtime shapes differ, e.g. inline-scan loaders) falls
        back to per-job execution rather than failing the gang.  A job
        holding a checkpoint is restored here too (``resumed_from`` set
        like the solo path) and then driven solo — a gang would force it
        back into lockstep from step 0."""
        transport = self.transport_factory(jobs[0])
        runners: list[PluginRunner] = []
        live: list[Job] = []
        resumed: list[Job] = []
        for job in jobs:
            job.started_at = time.time()
            job.state = JobState.CHECKING
            self._dispatched(job)
            try:
                with use_trace(job.trace):
                    self._resolve_upstream(job)
                r = PluginRunner(job.process_list, transport,
                                 profiler=Profiler(trace=job.trace),
                                 fuse=self.fuse)
                job.runner = r
                r.prepare()
                if self.checkpoints is not None:
                    with job.trace.span("checkpoint.restore"):
                        job.resumed_from = self.checkpoints.restore(
                            job.job_id, r)
                job.n_plugins = r.n_steps
                if job.resumed_from:
                    resumed.append(job)
                else:
                    runners.append(r)
                    live.append(job)
            except UpstreamGone as e:
                self._cancel_evicted(job, e)
                self._finish([job])
            except Exception as e:
                self._fail(job, e)
                self._finish([job])
        for job in resumed:
            try:
                self._drive(job, job.runner)
            except Exception as e:
                self._fail(job, e)
            finally:
                self._finish([job])
        jobs = live
        if not jobs:
            return
        if len(jobs) == 1:
            job = jobs[0]
            try:
                self._drive(job, job.runner)
            except Exception as e:
                self._fail(job, e)
            finally:
                self._finish([job])
            return
        try:
            for job in jobs:
                job.state = JobState.RUNNING
            can_batch = hasattr(transport, "run_plugin_batch")
            for _ in range(runners[0].n_steps):
                groups = [r.begin_step() for r in runners]
                t0 = time.time()
                if can_batch and len(groups[0]) == 1:
                    try:
                        transport.run_plugin_batch([g[0] for g in groups])
                    except ValueError:       # signature mismatch: solo
                        for g in groups:
                            transport.run_plugin(g[0])
                else:
                    for g in groups:
                        if len(g) > 1:
                            transport.run_fused(g)
                        else:
                            transport.run_plugin(g[0])
                t1 = time.time()
                for job, r, g in zip(jobs, runners, groups):
                    # the batched call is one compiled program over the
                    # whole gang — each member's trace gets the shared
                    # wall, tagged with the gang size
                    r.profiler.record(g[0].name, "process", t0, t1,
                                      gang=len(jobs))
                    r.complete_step()
                    job.plugin_index = r.current_step
                    if self.checkpoints is not None:
                        with job.trace.span("checkpoint.save"):
                            self.checkpoints.save(job.job_id, r)
            for job, r in zip(jobs, runners):
                r.finalise()
                job.state = JobState.DONE
                if self.checkpoints is not None:
                    self.checkpoints.clear(job.job_id)
            with self._lock:
                self.gangs_run += 1
        except Exception as e:
            err = f"{type(e).__name__}: {e}"
            tb = traceback.format_exc()
            for job in jobs:
                if not job.state.terminal():
                    job.error = err
                    job.metadata["traceback"] = tb
                    job.state = JobState.FAILED
        finally:
            self._finish(jobs)

    def _finish(self, jobs: list[Job]) -> None:
        now = time.time()
        with self._lock:
            for job in jobs:
                job.finished_at = job.finished_at or now
                if job.state is JobState.DONE:
                    self.jobs_done += 1
                elif job.state is JobState.FAILED:
                    self.jobs_failed += 1
        for job in jobs:
            # in-process runs record every span exactly once, and
            # _finish sees each job exactly once — safe to fold the
            # whole trace into the plugin-wall histograms here
            _observe_terminal(self.metrics, job, self.events)
            _observe_plugin_spans(self.metrics, job.trace.spans())
        for job in jobs:
            # per-job so the queue can propagate DONE/FAILED/CANCELLED
            # into each job's downstream cone (docs/workflows.md)
            self.queue.notify_terminal(job)


# ======================================================================
# Worker-pull scheduling: the broker side of multi-host deployment.
# ======================================================================
class LeaseLost(RuntimeError):
    """The caller no longer holds the job's lease (it expired and the
    job was requeued, possibly onto another worker) — any late result
    must be discarded (HTTP 409)."""


class WorkerAuthError(RuntimeError):
    """The caller presented a missing or mismatched per-worker secret —
    a registered worker's identity may not be assumed by other sessions
    even inside token auth (HTTP 403)."""


# Clock seams.  Lease/heartbeat EXPIRY arithmetic must use the monotonic
# clock: an NTP step of the wall clock would otherwise mass-expire every
# lease (step forward) or immortalise them (step backward).  Wall time
# is kept only for display fields and trace spans.  Module-level
# indirection so tests can fake either clock independently
# (``scheduler._mono = lambda: ...``).
def _wall() -> float:
    return time.time()


def _mono() -> float:
    return time.monotonic()


#: names that may become path components (worker ids, result datasets):
#: no separators, no leading dot — "../../x" or "/etc/x" never reaches
#: os.path.join
_SAFE_NAME = re.compile(r"^[\w\-][\w.\- ]*$")


@dataclasses.dataclass
class WorkerInfo:
    """One registered worker process and its advertised capabilities."""

    worker_id: str
    #: wire plugin names the worker can execute; None = unrestricted
    plugins: frozenset[str] | None = None
    #: device-mesh shape the worker runs (capacity filter)
    mesh_shape: tuple[int, ...] = (1,)
    #: largest gang the worker accepts in one lease
    max_batch: int = 1
    #: worker sees the broker's results_dir (writes results directly)
    shared_fs: bool = False
    #: worker accepts parameter-sweep variant jobs (False keeps e.g.
    #: lightweight interactive workers out of wide sweep fan-outs)
    sweeps: bool = True
    #: per-worker credential minted at registration; every subsequent
    #: lease/progress/complete/result/executable call must present it
    #: (rotated on re-registration).  Never serialised in snapshots.
    secret: str = ""
    registered_at: float = dataclasses.field(default_factory=time.time)
    last_seen: float = dataclasses.field(default_factory=time.time)
    leases_granted: int = 0
    jobs_done: int = 0
    jobs_failed: int = 0
    #: job ids currently leased to this worker
    active: set[str] = dataclasses.field(default_factory=set)
    #: the error string of the worker's most recent failed job (the
    #: cluster scoreboard's "what went wrong last" column)
    last_error: str | None = None
    #: executables the worker reported prefetching from the warm pool
    #: (piggybacked on lease requests)
    prefetched: int = 0

    def snapshot(self) -> dict[str, Any]:
        return {"worker_id": self.worker_id,
                "plugins": (sorted(self.plugins)
                            if self.plugins is not None else None),
                "mesh_shape": list(self.mesh_shape),
                "max_batch": self.max_batch, "shared_fs": self.shared_fs,
                "sweeps": self.sweeps,
                "registered_at": self.registered_at,
                "last_seen": self.last_seen,
                "leases_granted": self.leases_granted,
                "jobs_done": self.jobs_done,
                "jobs_failed": self.jobs_failed,
                "active": sorted(self.active),
                "last_error": self.last_error,
                "prefetched": self.prefetched}


@dataclasses.dataclass
class _Lease:
    worker_id: str
    #: MONOTONIC-clock deadline (``_mono() + ttl``) — expiry arithmetic
    #: must survive wall-clock steps; never compare against time.time()
    expires_at: float
    #: when the lease was granted, wall clock — start of the job's
    #: ``lease`` span (display/trace only, never expiry arithmetic)
    granted_at: float = 0.0


class WorkerBroker:
    """Feeds :class:`JobQueue` jobs to detached worker *processes* —
    the multi-host half of the paper's claim that the same process list
    runs "in serial on a PC, or in parallel across a cluster": one
    queue, N ``PipelineWorker`` processes pulling from it over HTTP.

    Protocol (wire messages in ``docs/worker-protocol.md``):

    * a worker registers (:meth:`register`) with its capabilities —
      plugins available, mesh shape, max gang size, shared-fs flag;
    * it leases jobs (:meth:`lease`): the queue pop is filtered by
      those capabilities (``JobQueue.get`` with a predicate — see its
      starvation guarantee), the job is serialised back to its wire
      spec, and a lease with a TTL is recorded;
    * while running it heartbeats (:meth:`progress`) after every plugin
      step, renewing the lease and streaming ``plugin_index`` /
      ``resumed_from`` / checkpoint location back; the reply carries a
      verdict — ``ok``, ``cancelled`` (a cancel arrived mid-lease) or
      ``lost`` (the lease expired and the job was requeued);
    * it hands results over (:meth:`store_result` upload spool, or a
      shared-fs path in :meth:`complete`) and reports terminal state.

    A worker that dies silently stops heartbeating; the sweep loop
    expires its leases and requeues the jobs, which resume from their
    last checkpoint on the next capable worker (``resumed_from`` set by
    the PR 2 checkpoint path — the worker restores, the broker records).
    """

    def __init__(self, queue: JobQueue, *, lease_ttl: float = 15.0,
                 sweep_interval: float | None = None,
                 results_dir: str | None = None,
                 metrics: MetricsRegistry | None = None,
                 events=None,
                 executables_dir: str | None = None,
                 executables_max_bytes: int = 512 << 20):
        """Args:
            queue: the admission queue leases are fed from.
            lease_ttl: seconds a lease survives without a heartbeat.
            sweep_interval: expiry-sweep cadence (default ``ttl / 4``,
                capped at 1s).
            results_dir: spool for worker results (uploads land here;
                shared-fs workers write into it).  Default: a fresh
                temp directory.
            metrics: telemetry registry (``repro.obs``) to record job
                outcomes/latencies into; None disables.
            events: structured :class:`~repro.obs.log.EventLog` for
                state-transition records (lease/park/expire/requeue/
                complete); None disables.
            executables_dir: spool for serialized executables workers
                upload (``PUT /executables/{sig}``) and fresh workers
                prefetch (warm pool).  Default: a fresh temp directory.
            executables_max_bytes: LRU retention bound on that spool.
        """
        self.queue = queue
        self.metrics = metrics
        self.events = events
        # exactly-once outcome attribution: terminal transitions the
        # QUEUE performs (queue-side cancels, workflow dependency
        # cascades) fire this hook; the broker observes its own
        # transitions inline (docs/workflows.md)
        queue.add_terminal_hook(
            lambda job: _observe_terminal(self.metrics, job,
                                          self.events))
        self.lease_ttl = lease_ttl
        self.sweep_interval = (sweep_interval if sweep_interval is not None
                               else min(1.0, lease_ttl / 4))
        self.results_dir = results_dir or tempfile.mkdtemp(
            prefix="pipeline-results-")
        os.makedirs(self.results_dir, exist_ok=True)
        # result-spool GC: when max_history evicts a job, its uploaded
        # .npy spool goes with it — otherwise the spool grows for the
        # broker's lifetime (ROADMAP follow-up)
        queue.add_evict_hook(self._gc_spool)
        from .compile_cache import ExecutableStore
        self.executables = ExecutableStore(
            executables_dir or tempfile.mkdtemp(prefix="pipeline-exe-"),
            max_bytes=executables_max_bytes)
        self.executables_uploaded = 0
        self.executables_served = 0
        self._workers: dict[str, WorkerInfo] = {}
        self._leases: dict[str, _Lease] = {}
        self._required: dict[str, set[str]] = {}   # job_id -> plugin names
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._sweeper: threading.Thread | None = None
        self._wseq = 0
        self.jobs_done = 0
        self.jobs_failed = 0
        self.jobs_requeued = 0
        self.leases_expired = 0
        self._started_at: float | None = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "WorkerBroker":
        """Start the lease-expiry sweep thread (idempotent)."""
        if self._sweeper is not None:
            return self
        self._started_at = time.time()
        self._stop = threading.Event()
        self._sweeper = threading.Thread(
            target=self._sweep_loop, args=(self._stop,),
            name="broker-sweep", daemon=True)
        self._sweeper.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        """Stop the sweep thread.  Leases survive (workers keep running
        their current jobs); nothing expires until the next start()."""
        self._stop.set()
        if self._sweeper is not None and wait:
            self._sweeper.join(timeout=10)
        self._sweeper = None

    # -- registration ---------------------------------------------------
    def register(self, info: dict[str, Any]) -> dict[str, Any]:
        """Admit (or refresh) a worker from its registration message::

            {"worker_id": null, "plugins": [...] | null,
             "mesh_shape": [1], "max_batch": 1, "shared_fs": false}

        Returns the reply envelope: the (possibly generated)
        ``worker_id``, a freshly minted ``worker_secret`` that every
        subsequent lease/progress/complete/result/executable call must
        present (re-registration rotates it — the old secret dies),
        the broker's ``lease_ttl``, the spool's hottest
        ``hot_executables`` signatures (the warm-pool prefetch list,
        docs/worker-protocol.md), and — for shared-fs workers — the
        ``results_dir`` to write results into.
        Raises WireError on a malformed message.
        """
        if not isinstance(info, dict):
            raise WireError("registration body must be an object")
        plugins = info.get("plugins")
        if plugins is not None and (
                not isinstance(plugins, (list, tuple))
                or not all(isinstance(p, str) for p in plugins)):
            raise WireError(f"plugins must be a list of wire names or "
                            f"null, got {plugins!r}")
        mesh_shape = info.get("mesh_shape") or [1]
        if not isinstance(mesh_shape, (list, tuple)) or \
                not all(isinstance(d, int) and d > 0 for d in mesh_shape):
            raise WireError(f"mesh_shape must be a list of positive ints, "
                            f"got {mesh_shape!r}")
        max_batch = info.get("max_batch", 1)
        if not isinstance(max_batch, int) or max_batch < 1:
            raise WireError(f"max_batch must be a positive int, got "
                            f"{max_batch!r}")
        worker_id = info.get("worker_id")
        if worker_id is not None and (
                not isinstance(worker_id, str)
                or not _SAFE_NAME.match(worker_id)):
            raise WireError(f"worker_id must be a filename-safe string "
                            f"(no path separators), got {worker_id!r}")
        with self._lock:
            if worker_id is None:
                self._wseq += 1
                worker_id = f"worker-{self._wseq:03d}"
            w = self._workers.get(worker_id)
            if w is None:
                w = WorkerInfo(worker_id)
                self._workers[worker_id] = w
            w.plugins = (frozenset(plugins) if plugins is not None
                         else None)
            w.mesh_shape = tuple(mesh_shape)
            w.max_batch = max_batch
            w.shared_fs = bool(info.get("shared_fs", False))
            w.sweeps = bool(info.get("sweeps", True))
            w.last_seen = _wall()
            # (re-)registration mints a fresh secret: a restarting
            # worker reclaims its id without needing the old credential,
            # and the old credential stops working at the same moment
            w.secret = secrets.token_hex(16)
            reply = {"worker_id": worker_id, "lease_ttl": self.lease_ttl,
                     "worker_secret": w.secret,
                     "hot_executables": self.executables.hot()}
            if w.shared_fs:
                reply["results_dir"] = self.results_dir
            return reply

    def _check_secret_locked(self, worker_id: str,
                             secret: str | None) -> WorkerInfo:
        """The registered worker for ``worker_id`` after verifying its
        per-worker secret.  Raises KeyError (→ 404) for an unknown
        worker, WorkerAuthError (→ 403) for a missing/mismatched
        secret."""
        w = self._workers[worker_id]
        if not (isinstance(secret, str)
                and hmac.compare_digest(w.secret, secret)):
            raise WorkerAuthError(
                f"bad or missing worker_secret for {worker_id!r}")
        return w

    # -- capability matching --------------------------------------------
    def _required_plugins(self, job: Job) -> set[str]:
        need = self._required.get(job.job_id)
        if need is None:
            need = chain_plugin_names(job.process_list)
            self._required[job.job_id] = need
        return need

    def _capable(self, w: WorkerInfo, job: Job) -> bool:
        """Can ``w`` run ``job``?  Plugins: the chain's wire names must
        all be advertised (None = unrestricted).  Sweeps: a parameter-
        sweep variant (``metadata["sweep"]``) only goes to workers that
        accept sweep workloads.  Mesh: a job that asks for devices
        (``metadata["mesh_shape"]``) needs a worker whose mesh has at
        least that many."""
        if w.plugins is not None and \
                not self._required_plugins(job) <= w.plugins:
            return False
        if not w.sweeps and job.metadata.get("sweep"):
            return False
        req = job.metadata.get("mesh_shape")
        if req:
            need = 1
            for d in req:
                need *= int(d)
            have = 1
            for d in w.mesh_shape:
                have *= int(d)
            if have < need:
                return False
        return True

    # -- lease ----------------------------------------------------------
    def lease(self, worker_id: str, max_jobs: int = 1,
              timeout: float = 0.0,
              secret: str | None = None,
              prefetched: int | None = None) -> list[dict[str, Any]]:
        """Pop up to ``max_jobs`` (capped by the worker's ``max_batch``)
        capability-matching jobs and lease them to ``worker_id``.

        Returns one descriptor per job: the wire spec to execute plus
        identity/lease bookkeeping::

            {"job_id": ..., "process_list": {spec v1}, "priority": 0,
             "attempt": 1, "metadata": {...}, "lease_ttl": 15.0}

        Raises KeyError for an unregistered worker, WorkerAuthError for
        a missing/mismatched per-worker secret.  A job whose chain
        cannot be wire-serialised (in-process submission with opaque
        params) is failed loudly rather than silently starving.

        ``prefetched`` piggybacks the worker's warm-pool prefetch count
        (how many hot executables it pulled at registration) for the
        ``GET /cluster`` scoreboard.
        """
        self._expire_locked_sweep()
        with self._lock:
            w = self._check_secret_locked(worker_id, secret)
            w.last_seen = _wall()
            if isinstance(prefetched, int) and prefetched >= 0:
                w.prefetched = prefetched
            n = max(1, min(max_jobs, w.max_batch))
            pred = lambda job: self._capable(w, job)   # noqa: E731
        if n == 1:
            job = self.queue.get(timeout=timeout, predicate=pred)
            jobs = [job] if job is not None else []
        else:
            jobs = self.queue.get_batch(n, timeout=timeout, predicate=pred)
        out = []
        now = _wall()                    # display / span timestamps
        now_m = _mono()                  # lease-deadline arithmetic
        with self._lock:
            shared_fs = w.shared_fs
        for job in jobs:
            try:
                spec = to_spec(job.process_list)
                self._resolve_upstream_spec(job, spec, shared_fs)
            except UpstreamGone as e:
                job.error = str(e)
                job.state = JobState.CANCELLED
                job.cancel_reason = "upstream_evicted"
                job.finished_at = time.time()
                with self._lock:
                    self._required.pop(job.job_id, None)
                _observe_terminal(self.metrics, job, self.events)
                self.queue.notify_terminal(job)
                continue
            except WireError as e:
                job.error = f"WireError: {e}"
                job.state = JobState.FAILED
                job.finished_at = time.time()
                with self._lock:
                    self.jobs_failed += 1
                    self._required.pop(job.job_id, None)
                _observe_terminal(self.metrics, job, self.events)
                self.queue.notify_terminal(job)
                continue
            with self._lock:
                job.worker_id = worker_id
                job.attempt += 1
                job.started_at = job.started_at or now
                self._leases[job.job_id] = _Lease(
                    worker_id, now_m + self.lease_ttl, granted_at=now)
                w.leases_granted += 1
                w.active.add(job.job_id)
            # the broker records the queue-side spans; the worker adds
            # the execution spans via heartbeats (one merged timeline)
            waited_from = job.requeued_at or job.submitted_at
            job.trace.record("queue.wait", waited_from, now,
                             attrs={"priority": job.priority,
                                    "attempt": job.attempt})
            if self.metrics is not None:
                self.metrics.histogram("job.latency.queue").observe(
                    now - waited_from)
            if self.events is not None:
                self.events.emit("job.lease", trace_id=job.trace_id,
                                 job_id=job.job_id, worker_id=worker_id,
                                 attempt=job.attempt,
                                 priority=job.priority)
            out.append({
                "job_id": job.job_id, "process_list": spec,
                "priority": job.priority, "attempt": job.attempt,
                "trace_id": job.trace_id,
                "metadata": {k: v for k, v in job.metadata.items()
                             if _is_jsonable(v)},
                "lease_ttl": self.lease_ttl})
        return out

    # -- workflow upstream inputs (docs/workflows.md) -------------------
    def _resolve_upstream_spec(self, job: Job, spec: dict[str, Any],
                               shared_fs: bool) -> None:
        """Rewrite upstream-result references in the SERIALISED spec at
        lease time.  Shared-fs workers get the broker-side ``.npy``
        path spliced in (zero-copy hand-off); remote workers keep the
        ref and fetch it over ``GET /jobs/{id}/result``.  Only the
        descriptor's spec dict is touched — never ``job.process_list``
        — so a lease expiry + re-lease to a differently-capable worker
        re-resolves from scratch.  Raises UpstreamGone when the
        upstream result is no longer reachable."""
        for ent in spec.get("plugins", ()):
            params = ent.get("params")
            if not isinstance(params, dict):
                continue
            ref = _upstream_ref(params)
            if ref is None:
                continue
            from_job, dataset = ref
            try:
                up = self.queue.job(from_job)
            except KeyError:
                raise UpstreamGone(
                    f"upstream {from_job!r} was evicted before its "
                    f"result was consumed") from None
            if up.state is not JobState.DONE:
                raise UpstreamGone(
                    f"upstream {from_job!r} is {up.state.value}, "
                    f"not done")
            name = dataset or next(
                (k for k in up.remote_results if not k.startswith("__")),
                None)
            path = up.remote_results.get(name) if name else None
            if path is None or not os.path.exists(path):
                raise UpstreamGone(
                    f"upstream {from_job!r} has no stored result "
                    f"{name or dataset!r}")
            params = dict(params)
            if shared_fs:
                params.pop("data", None)
                params["path"] = path
                params["from_job"] = None
            else:
                # normalise to the split form the worker resolves over
                # HTTP (GET /jobs/{from_job}/result?dataset=...)
                params.pop("data", None)
                params["from_job"] = from_job
                params["dataset"] = name
            ent["params"] = params

    # -- heartbeat / progress -------------------------------------------
    def progress(self, job_id: str, worker_id: str,
                 body: dict[str, Any] | None = None) -> dict[str, Any]:
        """Heartbeat + per-plugin progress from the leased worker.

        Renews the lease and folds ``plugin_index`` / ``n_plugins`` /
        ``resumed_from`` / ``checkpoint`` (a location string) into the
        job's snapshot.  The verdict in the reply is the control
        channel back to the worker:

        * ``"ok"`` — keep going (lease renewed);
        * ``"cancelled"`` — a cancel arrived while the worker held the
          lease; the job is now terminal, stop and discard;
        * ``"lost"`` — the lease expired (or another worker owns the
          job after a requeue); stop, the job is no longer yours.
          Exactly one owner survives an expiry race: the requeue
          happens under the broker lock, and a stale owner can never
          match the new lease's ``worker_id``.

        Raises KeyError for an unknown job, WorkerAuthError when a
        REGISTERED worker's secret is missing/mismatched (an
        unregistered worker_id falls through to the lease checks and is
        answered ``lost`` as before — there is no credential to verify).
        """
        body = body or {}
        job = self.queue.job(job_id)
        now = time.time()                # span timestamps (epoch)
        now_m = _mono()                  # lease-expiry arithmetic
        # fold piggybacked spans into the job's trace FIRST, whatever
        # the verdict — a worker about to be told "lost" still carries
        # real history from its attempt (span-id dedup makes redelivery
        # idempotent), and the killed-worker spans the resume timeline
        # needs arrive exactly this way
        new_spans = job.trace.merge(body.get("spans") or [])
        _observe_plugin_spans(self.metrics, new_spans)
        with self._lock:
            if worker_id in self._workers:
                self._check_secret_locked(worker_id,
                                          body.get("worker_secret"))
            lease = self._leases.get(job_id)
            if lease is None or lease.worker_id != worker_id:
                return {"verdict": "lost"}
            w = self._workers.get(worker_id)
            if w is not None:
                w.last_seen = now
            if now_m > lease.expires_at:
                # expired but not yet swept: reject the heartbeat and
                # requeue NOW so the job lands on a live worker (the
                # requeue may CANCEL a cancel-flagged job — terminal —
                # so fall through to notify_terminal below)
                self._end_lease_locked(job, lease, "lost", now)
                self._drop_lease_locked(job_id, worker_id)
                self._requeue_locked(job)
                verdict = {"verdict": "lost"}
            elif job.cancel_requested or job.state is JobState.CANCELLED:
                self._end_lease_locked(job, lease, "cancelled", now)
                self._drop_lease_locked(job_id, worker_id)
                if not job.state.terminal():
                    job.state = JobState.CANCELLED
                    job.cancel_reason = job.cancel_reason or "user"
                    job.finished_at = now
                    _observe_terminal(self.metrics, job, self.events)
                verdict = {"verdict": "cancelled"}
            else:
                lease.expires_at = now_m + self.lease_ttl
                if isinstance(body.get("plugin_index"), int):
                    # a bare renewal (no fields) keeps the lease alive
                    # without claiming execution started — batch-leased
                    # jobs waiting their turn stay "checking"
                    job.state = JobState.RUNNING
                    job.plugin_index = body["plugin_index"]
                if isinstance(body.get("n_plugins"), int):
                    job.n_plugins = body["n_plugins"]
                if isinstance(body.get("resumed_from"), int):
                    job.resumed_from = max(job.resumed_from,
                                           body["resumed_from"])
                if isinstance(body.get("checkpoint"), str):
                    job.metadata["checkpoint"] = body["checkpoint"]
                if isinstance(body.get("ingest_watermark"), int) and \
                        job.stream is not None:
                    self._fold_ingest_locked(job,
                                             body["ingest_watermark"], now)
                if isinstance(body.get("preview_watermark"), int):
                    job.preview_watermark = max(job.preview_watermark,
                                                body["preview_watermark"])
                if self.metrics is not None and isinstance(
                        body.get("window_latency"), (int, float)) and \
                        not isinstance(body.get("window_latency"), bool):
                    # worker-side pump wall for the freshest streamed
                    # window — transient on the heartbeat (shipped once,
                    # never re-posted), closing the ROADMAP gap of
                    # stream.window_latency_s being scheduler-mode only
                    self.metrics.histogram("stream.window_latency_s") \
                        .observe(max(0.0, float(body["window_latency"])))
                if body.get("park") and job.streaming:
                    # starved streaming worker: hand the job back to the
                    # queue (a checkpoint was just reported) so the
                    # worker slot frees up instead of burning the lease
                    # polling.  stream_ready() keeps it unleasable until
                    # frames or EOF arrive.
                    self._end_lease_locked(job, lease, "parked", now)
                    self._drop_lease_locked(job_id, worker_id)
                    if self.metrics is not None:
                        self.metrics.counter("jobs.parked").inc()
                    if self.events is not None:
                        self.events.emit(
                            "job.park", trace_id=job.trace_id,
                            job_id=job_id, worker_id=worker_id,
                            frames_consumed=job.frames_consumed)
                    self.queue.requeue(job)
                    return {"verdict": "parked"}
                return {"verdict": "ok", "lease_ttl": self.lease_ttl}
        self.queue.notify_terminal(job)
        return verdict

    def _fold_ingest_locked(self, job: Job, watermark: int,
                            now: float) -> None:
        """Heartbeat carried the worker's consumption watermark: advance
        ``frames_consumed`` (monotone) and derive the ingest-lag sample
        (newest consumed frame's arrival -> this heartbeat)."""
        prev = job.frames_consumed
        job.frames_consumed = max(prev, watermark)
        if self.metrics is not None and watermark > prev:
            with job.stream.lock:
                arrived = job.stream.arrival_time(watermark - 1)
            if arrived is not None:
                self.metrics.histogram("stream.ingest_lag_s").observe(
                    max(0.0, now - arrived))

    # -- results --------------------------------------------------------
    def _spool_dir(self, job_id: str) -> str:
        return os.path.join(self.results_dir,
                            job_id.replace(os.sep, "_").replace("..", "_"))

    def _job_spool(self, job_id: str) -> str:
        d = self._spool_dir(job_id)
        os.makedirs(d, exist_ok=True)
        return d

    def _gc_spool(self, job: Job) -> None:
        """``JobQueue`` evict hook: delete the evicted job's result
        spool (uploaded AND shared-fs files live under
        ``results_dir/<job_id>``).  The job is already removed — its
        result was going to 404 anyway; now the bytes go too."""
        shutil.rmtree(self._spool_dir(job.job_id), ignore_errors=True)
        job.remote_results.clear()

    def store_result(self, job_id: str, worker_id: str, dataset: str,
                     payload: bytes, secret: str | None = None) -> str:
        """Spool one uploaded result dataset (raw ``.npy`` bytes) for
        ``GET /jobs/{id}/result`` to stream later.  Only the current
        lease holder may upload — a worker that lost its lease gets
        :class:`LeaseLost` and must discard its copy; a registered
        worker with a bad secret gets :class:`WorkerAuthError`."""
        if not _SAFE_NAME.match(dataset):
            # the name becomes a path component under results_dir —
            # refuse separators/dot-leading names, never traverse out
            raise WireError(f"dataset must be a filename-safe name, "
                            f"got {dataset!r}")
        with self._lock:
            if worker_id in self._workers:
                self._check_secret_locked(worker_id, secret)
            lease = self._leases.get(job_id)
            if lease is None or lease.worker_id != worker_id:
                raise LeaseLost(f"worker {worker_id!r} no longer holds "
                                f"the lease on job {job_id!r}")
        path = os.path.join(self._job_spool(job_id), f"{dataset}.npy")
        tmp = f"{path}.{worker_id}.tmp"
        with open(tmp, "wb") as fh:
            fh.write(payload)
        os.replace(tmp, path)
        job = self.queue.job(job_id)
        with self._lock:
            job.remote_results[dataset] = path
        return path

    # -- executable warm pool (docs/worker-protocol.md) -----------------
    def put_executable(self, worker_id: str, secret: str | None,
                       sig: str, payload: bytes) -> dict[str, Any]:
        """Accept one serialized executable a worker just compiled
        (``PUT /executables/{sig}``).  Only registered workers with a
        valid secret may upload (KeyError → 404, WorkerAuthError →
        403); only framed payloads enter the spool (WireError → 400).
        """
        with self._lock:
            self._check_secret_locked(worker_id, secret)
        if not self.executables.put_bytes(sig, payload):
            if self.metrics is not None:
                self.metrics.counter("executables.rejected").inc()
            raise WireError(f"rejected executable payload for {sig!r} "
                            f"(bad signature or framing)")
        with self._lock:
            self.executables_uploaded += 1
        if self.metrics is not None:
            self.metrics.counter("executables.uploaded").inc()
        return {"sig": sig, "stored": True}

    def get_executable(self, sig: str) -> bytes:
        """The raw payload for one signature (``GET /executables/
        {sig}``).  Raises KeyError when absent.  Each fetch counts a
        use, which is exactly the heat signal :meth:`register`'s
        ``hot_executables`` list ranks by."""
        payload = self.executables.get_bytes(sig)
        if payload is None:
            raise KeyError(sig)
        with self._lock:
            self.executables_served += 1
        if self.metrics is not None:
            self.metrics.counter("executables.served").inc()
        return payload

    def hot_executables(self, n: int = 8) -> list[str]:
        """The spool's hottest signatures (``GET /executables``)."""
        return self.executables.hot(n)

    def complete(self, job_id: str, worker_id: str,
                 body: dict[str, Any]) -> dict[str, Any]:
        """Terminal report from the lease holder::

            {"state": "done" | "failed", "error": null,
             "results": {"recon": {"path": "/shared/.../recon.npy"}}}

        ``results`` paths are the shared-fs hand-off (the worker wrote
        the ``.npy`` under ``results_dir`` where the broker can read
        it — paths outside ``results_dir`` are refused);
        uploaded datasets were already spooled via
        :meth:`store_result`.  Raises :class:`LeaseLost` if the lease
        is gone — the job was requeued, this worker's outcome is void.
        """
        job = self.queue.job(job_id)
        state = body.get("state")
        if state not in ("done", "failed"):
            raise WireError(f'complete state must be "done" or "failed", '
                            f'got {state!r}')
        # keep the worker's final span flush even if the lease check
        # below raises LeaseLost — a late completion is void as an
        # OUTCOME, but its spans are real history on the timeline
        new_spans = job.trace.merge(body.get("spans") or [])
        _observe_plugin_spans(self.metrics, new_spans)
        results = body.get("results") or {}
        if not isinstance(results, dict):
            raise WireError("results must be an object")
        # validate BEFORE touching any state: a shared-fs hand-off may
        # only name paths inside results_dir — the broker must never be
        # talked into streaming an arbitrary server file to clients
        root = os.path.realpath(self.results_dir)
        accepted: dict[str, str] = {}
        for name, ent in results.items():
            path = ent.get("path") if isinstance(ent, dict) else None
            if not path:
                continue
            real = os.path.realpath(path)
            if not real.startswith(root + os.sep):
                raise WireError(f"result path for {name!r} is outside "
                                f"the broker results_dir")
            if os.path.exists(real):
                accepted[name] = real
        now = time.time()
        with self._lock:
            if worker_id in self._workers:
                self._check_secret_locked(worker_id,
                                          body.get("worker_secret"))
            lease = self._leases.get(job_id)
            if lease is None or lease.worker_id != worker_id or \
                    _mono() > lease.expires_at:
                raise LeaseLost(f"worker {worker_id!r} no longer holds "
                                f"the lease on job {job_id!r}")
            self._end_lease_locked(job, lease, state, now)
            self._drop_lease_locked(job_id, worker_id)
            w = self._workers.get(worker_id)
            job.remote_results.update(accepted)
            if isinstance(body.get("plugin_index"), int):
                job.plugin_index = body["plugin_index"]
            if isinstance(body.get("n_plugins"), int):
                job.n_plugins = body["n_plugins"]
            if state == "done":
                job.state = JobState.DONE
                self.jobs_done += 1
                if w is not None:
                    w.jobs_done += 1
            else:
                job.error = str(body.get("error") or "worker failure")
                job.state = JobState.FAILED
                self.jobs_failed += 1
                if w is not None:
                    w.jobs_failed += 1
                    w.last_error = job.error
            job.finished_at = now
            self._required.pop(job_id, None)
        _observe_terminal(self.metrics, job, self.events)
        self.queue.notify_terminal(job)
        return {"job_id": job_id, "state": job.state.value}

    # -- cancellation ---------------------------------------------------
    def request_cancel(self, job_id: str) -> bool:
        """Cancel a LEASED job cooperatively: flag it so the worker's
        next heartbeat is answered ``cancelled``.  Returns True if the
        job is currently leased (cancel pending), False otherwise."""
        with self._lock:
            lease = self._leases.get(job_id)
            if lease is None:
                return False
            try:
                job = self.queue.job(job_id)
            except KeyError:
                return False
            if job.state.terminal():
                return False
            job.cancel_requested = True
            return True

    # -- expiry ---------------------------------------------------------
    def _end_lease_locked(self, job: Job, lease: _Lease, outcome: str,
                          now: float) -> None:
        """Record the closing ``lease`` span: one per attempt, covering
        grant → end, tagged with the holding worker and how it ended
        (``done``/``failed``/``cancelled``/``lost``/``expired``)."""
        job.trace.record("lease", lease.granted_at or job.submitted_at,
                         now, worker_id=lease.worker_id,
                         attrs={"outcome": outcome,
                                "attempt": job.attempt})

    def _drop_lease_locked(self, job_id: str, worker_id: str) -> None:
        self._leases.pop(job_id, None)
        w = self._workers.get(worker_id)
        if w is not None:
            w.active.discard(job_id)

    def _requeue_locked(self, job: Job) -> None:
        self.leases_expired += 1
        if self.metrics is not None:
            self.metrics.counter("lease.expired").inc()
        if self.events is not None:
            # the single choke point for BOTH expiry paths (heartbeat-
            # detected and sweep-detected) — exactly one event per
            # expired lease
            self.events.emit("lease.expire", trace_id=job.trace_id,
                             job_id=job.job_id,
                             worker_id=job.worker_id or "",
                             attempt=job.attempt)
        if job.cancel_requested and not job.state.terminal():
            job.state = JobState.CANCELLED
            job.cancel_reason = job.cancel_reason or "user"
            job.finished_at = time.time()
            _observe_terminal(self.metrics, job, self.events)
            return
        if self.queue.requeue(job):
            self.jobs_requeued += 1
            if self.metrics is not None:
                self.metrics.counter("jobs.requeued").inc()
            if self.events is not None:
                self.events.emit("job.requeue", trace_id=job.trace_id,
                                 job_id=job.job_id,
                                 worker_id=job.worker_id or "",
                                 attempt=job.attempt)

    def _expire_locked_sweep(self) -> None:
        """Requeue every job whose lease expired (dead worker), and
        prune the required-plugins cache of jobs that went terminal via
        any path (cancel, failure, eviction) — the cache must not grow
        for the broker's lifetime."""
        now = time.time()                # span timestamps
        now_m = _mono()                  # expiry arithmetic
        touched: list[Job] = []
        with self._lock:
            expired = [(jid, ls) for jid, ls in self._leases.items()
                       if now_m > ls.expires_at]
            for jid, ls in expired:
                self._drop_lease_locked(jid, ls.worker_id)
                try:
                    job = self.queue.job(jid)
                except KeyError:
                    continue
                self._end_lease_locked(job, ls, "expired", now)
                if not job.state.terminal():
                    self._requeue_locked(job)
                touched.append(job)
            for jid in list(self._required):
                try:
                    if self.queue.job(jid).state.terminal():
                        del self._required[jid]
                except KeyError:
                    del self._required[jid]
        for job in touched:
            # per-job: a cancel-flagged expiry went CANCELLED and must
            # cascade into its downstream cone; plain requeues are
            # non-terminal and only wake capacity waiters
            self.queue.notify_terminal(job)

    def _sweep_loop(self, stop: threading.Event) -> None:
        while not stop.wait(self.sweep_interval):
            self._expire_locked_sweep()

    # -- stats ----------------------------------------------------------
    def n_active_leases(self) -> int:
        """Currently-held lease count (the ``leases.active`` gauge)."""
        with self._lock:
            return len(self._leases)

    def n_workers(self) -> int:
        """Registered worker count (``workers.registered`` gauge)."""
        with self._lock:
            return len(self._workers)

    def cluster(self) -> dict[str, Any]:
        """The ``GET /cluster`` worker scoreboard: one row per
        registered worker — capabilities, heartbeat staleness, active
        leases with time-to-expiry, last failure, and the warm-pool
        prefetch count — plus broker-level lease totals.  This is the
        operator's "which worker is sick?" view; ``/slo`` answers
        "is the service sick?"."""
        now = _wall()
        now_m = _mono()
        with self._lock:
            workers = []
            for wid, w in sorted(self._workers.items()):
                snap = w.snapshot()
                snap["heartbeat_staleness_s"] = round(
                    max(0.0, now - w.last_seen), 3)
                snap["leases"] = [
                    {"job_id": jid,
                     "expires_in_s": round(ls.expires_at - now_m, 3)}
                    for jid, ls in sorted(self._leases.items())
                    if ls.worker_id == wid]
                workers.append(snap)
            return {"workers": workers,
                    "active_leases": len(self._leases),
                    "leases_expired": self.leases_expired,
                    "jobs_requeued": self.jobs_requeued,
                    "lease_ttl": self.lease_ttl,
                    "now": now}

    def stats(self) -> dict[str, Any]:
        """Broker counters + per-worker stats (``GET /stats`` in broker
        mode): ``jobs_done``/``jobs_failed``/``jobs_requeued``/
        ``leases_expired``, active lease count, queue-age info under
        ``queue``, and one entry per registered worker under
        ``workers``."""
        with self._lock:
            out: dict[str, Any] = {
                "mode": "broker",
                "jobs_done": self.jobs_done,
                "jobs_failed": self.jobs_failed,
                "jobs_requeued": self.jobs_requeued,
                "leases_expired": self.leases_expired,
                "active_leases": len(self._leases),
                "executables": {
                    **self.executables.stats(),
                    "uploaded": self.executables_uploaded,
                    "served": self.executables_served},
                "workers": {wid: w.snapshot()
                            for wid, w in self._workers.items()},
            }
        out["pending"] = self.queue.pending()
        out["queue"] = self.queue.queue_info()
        if self._started_at is not None:
            out["wall"] = time.time() - self._started_at
        return out
