"""Streaming acquisition (docs/streaming.md): arrival-driven chunk
execution end-to-end.

Framework layer: a runner fed random-size frame slabs through
``enable_streaming``/``feed``/``pump`` must produce a reconstruction
BIT-IDENTICAL to the batch run of the same chain, with partial previews
available mid-stream and out-of-order feeds rejected.

Service layer (scheduler mode): the HTTP ingest contract — frames over
``POST /jobs/{id}/frames``, EOF, preview-before-EOF, 409 on
out-of-order/duplicate/after-EOF ingest, 401 without the bearer token
when the service is token-armed.

Broker mode: a streaming job survives a worker SIGKILL mid-stream (the
retained frame buffers + the checkpoint's ingest watermark let the next
owner refetch and continue), and a starved stream PARKS its lease
instead of burning it.

Plus the satellites that ride along: the TraceSpool ring (terminal-job
traces survive history eviction) and the PluginRunner.run() error path
closing the transport instead of leaking chunk-file handles.
"""
import os
import signal
import time

import numpy as np
import pytest

import slow_plugins  # noqa: F401 — registers slow_identity server-side
from repro.core import ChunkedFileTransport, PluginRunner
from repro.core.patterns import PROJECTION
from repro.core.plugin import BaseFilter
from repro.core.process_list import ProcessList
from repro.service import (PipelineClient, PipelineService, PipelineWorker,
                           ServiceError, from_spec)
from repro.service.worker import spawn_local_workers
from repro.tomo.plugins import HDF5LikeSaver, SyntheticTomoLoader

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def _spec(seed=0, n_det=16, n_angles=24, streaming=True, delay=0.0):
    """A small loader → window → barrier → saver chain; ``delay`` > 0
    inserts the slow (windowed) identity so a worker can be killed
    mid-pump deterministically."""
    plugins = [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": n_det, "n_angles": n_angles, "n_rows": 1,
                    "seed": seed},
         "out_datasets": ["tomo"]},
        {"plugin": "dark_flat_correction",
         "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["tomo"]},
    ]
    if delay:
        plugins.append({"plugin": "slow_identity",
                        "params": {"delay": delay},
                        "in_datasets": ["tomo"], "out_datasets": ["tomo"]})
    plugins += [
        {"plugin": "fbp_recon", "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["recon"]},
        {"plugin": "hdf5_saver", "in_datasets": ["recon"]},
    ]
    spec = {"version": 1, "plugins": plugins}
    if streaming:
        spec = {**spec, "version": 2, "streaming": True}
    return spec


def _reference(spec) -> np.ndarray:
    """The batch run of the same chain (the loader materialises its
    own frames)."""
    batch = {k: v for k, v in spec.items() if k != "streaming"}
    ref = PluginRunner(from_spec({**batch, "version": 1})).run()
    return np.asarray(ref["recon"].materialise())


def _frames(spec) -> np.ndarray:
    """What the chain's loader WOULD produce — the frame stack an
    acquisition source streams in."""
    e = from_spec(spec).entries[0]
    loader = e.cls(**e.params, in_datasets=list(e.in_datasets),
                   out_datasets=list(e.out_datasets))
    return np.asarray(loader.load()[0].materialise())


# ======================================================== framework layer
def test_pump_matches_batch_random_chunks():
    """Feed the stream in random-size slabs: the final reconstruction is
    bit-identical to the batch run, and a mid-stream preview covers a
    non-trivial prefix."""
    spec = _spec(seed=11)
    want = _reference(spec)
    frames = _frames(spec)
    runner = PluginRunner(from_spec(spec))
    runner.enable_streaming()
    rng = np.random.default_rng(0)
    fed, previewed = 0, None
    while fed < frames.shape[0]:
        k = int(rng.integers(1, 6))
        fed = runner.feed(frames[fed:fed + k], fed)
        runner.pump()
        if previewed is None and fed >= frames.shape[0] // 2:
            try:
                arr, cut = runner.preview()
                assert 0 < cut <= fed
                assert arr.shape == want.shape
                previewed = cut
            except ValueError:
                pass                     # windowed head not cleared yet
    runner.mark_eof()
    runner.pump()
    assert runner.current_step == runner.n_steps
    runner.finalise()
    got = np.asarray(runner.transport.read(runner.datasets["recon"]))
    np.testing.assert_array_equal(got, want)
    assert previewed is not None, "no preview ever became available"


def test_feed_rejects_out_of_order_and_overrun():
    spec = _spec(seed=1)
    frames = _frames(spec)
    runner = PluginRunner(from_spec(spec))
    runner.enable_streaming()
    assert runner.feed(frames[:4], 0) == 4
    with pytest.raises(ValueError, match="out of order"):
        runner.feed(frames[4:8], 6)      # gap
    with pytest.raises(ValueError, match="out of order"):
        runner.feed(frames[0:4], 0)      # duplicate
    with pytest.raises(ValueError):
        runner.mark_eof()                # premature: 4/24 frames
    runner.feed(frames[4:], 4)
    runner.mark_eof()
    with pytest.raises(ValueError, match="after eof"):
        runner.feed(frames[:1], 24)


def test_run_failure_closes_transport(tmp_path):
    """A mid-chain plugin failure must not leak open chunk-file
    handles: run() closes the transport on the error path too."""
    class _Boom(BaseFilter):
        name = "boom_filter"
        pattern_name = PROJECTION
        parameters = {}

        def process_frames(self, frames):
            raise RuntimeError("boom")

    class _SpyTransport(ChunkedFileTransport):
        closed = False

        def close(self):
            self.closed = True
            super().close()

    pl = (ProcessList()
          .add(SyntheticTomoLoader,
               params={"n_det": 16, "n_angles": 8, "n_rows": 1},
               out_datasets=["tomo"])
          .add(_Boom, in_datasets=["tomo"], out_datasets=["tomo"])
          .add(HDF5LikeSaver, in_datasets=["tomo"]))
    t = _SpyTransport(str(tmp_path / "chunks"))
    with pytest.raises(RuntimeError, match="boom"):
        PluginRunner(pl, t).run()
    assert t.closed


# ================================================== scheduler mode (HTTP)
@pytest.fixture
def sched():
    svc = PipelineService(n_workers=1)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    try:
        yield svc, client
    finally:
        svc.stop()


def test_http_streamed_job_bit_identical_with_preview(sched):
    """The headline contract: a job streamed over HTTP chunk-by-chunk
    finishes bit-identical to the batch run, and ``GET
    /jobs/{id}/preview`` serves a partial reconstruction BEFORE EOF."""
    svc, client = sched
    spec = _spec(seed=21)
    want = _reference(spec)
    frames = _frames(spec)
    jid = client.submit(spec)
    preview = None
    for lo in range(0, frames.shape[0], 7):
        out = client.ingest(jid, frames[lo:lo + 7], lo)
        assert out["watermark"] == min(lo + 7, frames.shape[0])
        if lo >= 14 and preview is None:
            deadline = time.time() + 60
            while preview is None and time.time() < deadline:
                try:
                    preview = client.preview(jid)
                except ServiceError as e:
                    assert e.status == 409, e
                    time.sleep(0.05)
    assert preview is not None, "no preview before EOF"
    arr, cut = preview
    assert arr.shape == want.shape and 0 < cut <= frames.shape[0]
    client.eof(jid)
    snap = client.wait(jid, timeout=120)
    assert snap["state"] == "done", snap
    assert snap["streaming"] is True
    assert snap["frames_consumed"] == frames.shape[0]
    np.testing.assert_array_equal(client.result(jid), want)


def test_http_ingest_contract_409s(sched):
    """Out-of-order, duplicate, after-EOF and non-streaming ingest are
    all protocol errors (409); unknown jobs are 404."""
    svc, client = sched
    spec = _spec(seed=3)
    frames = _frames(spec)
    jid = client.submit(spec)
    client.ingest(jid, frames[:6], 0)
    with pytest.raises(ServiceError) as ei:
        client.ingest(jid, frames[:6], 0)         # duplicate
    assert ei.value.status == 409
    with pytest.raises(ServiceError) as ei:
        client.ingest(jid, frames[8:12], 8)       # gap
    assert ei.value.status == 409
    with pytest.raises(ServiceError) as ei:
        client.ingest("nope", frames[:1], 0)      # unknown job
    assert ei.value.status == 404
    plain = client.submit(_spec(seed=4, streaming=False))
    with pytest.raises(ServiceError) as ei:
        client.ingest(plain, frames[:1], 0)       # not a streaming job
    assert ei.value.status == 409
    client.ingest(jid, frames[6:], 6)
    client.eof(jid)
    with pytest.raises(ServiceError) as ei:       # feed after EOF (or
        client.ingest(jid, frames[:1], frames.shape[0])  # after done)
    assert ei.value.status == 409
    assert client.wait(jid, timeout=120)["state"] == "done"
    # EOF on the COMPLETED stream is idempotent: the executor finishes
    # the moment the last declared frame lands, racing the producer's
    # EOF — that race must not surface as an error
    assert client.eof(jid)["eof"] is True
    # premature EOF fails the job; a second EOF is a 409 either way
    # (duplicate on a live stream, or ingest-closed once it failed)
    j2 = client.submit(_spec(seed=6))
    client.eof(j2)
    with pytest.raises(ServiceError) as ei:
        client.eof(j2)
    assert ei.value.status == 409
    assert client.wait(j2, timeout=120)["state"] == "failed"


def test_token_guards_mutating_endpoints(tmp_path):
    """With --token set, every mutating verb 401s without the bearer
    header; reads stay open; the right token passes."""
    svc = PipelineService(n_workers=1, token="s3cret")
    host, port = svc.serve(port=0)
    base = f"http://{host}:{port}"
    anon = PipelineClient(base, timeout=30.0)
    authed = PipelineClient(base, timeout=60.0, token="s3cret")
    try:
        spec = _spec(seed=5)
        frames = _frames(spec)
        with pytest.raises(ServiceError) as ei:
            anon.submit(spec)
        assert ei.value.status == 401
        jid = authed.submit(spec)
        with pytest.raises(ServiceError) as ei:
            anon.ingest(jid, frames[:4], 0)
        assert ei.value.status == 401
        with pytest.raises(ServiceError) as ei:
            anon.eof(jid)
        assert ei.value.status == 401
        with pytest.raises(ServiceError) as ei:
            PipelineClient(base, token="wrong").ingest(jid, frames[:4], 0)
        assert ei.value.status == 401
        assert anon.status(jid)["state"]          # reads stay open
        authed.ingest(jid, frames, 0)
        authed.eof(jid)
        snap = authed.wait(jid, timeout=120)
        assert snap["state"] == "done", snap
        np.testing.assert_array_equal(anon.result(jid), _reference(spec))
    finally:
        svc.stop()


# ======================================================== broker mode
def test_starved_stream_parks_lease(tmp_path):
    """A streaming job with no frames left to chew hands its lease back
    (verdict ``parked``) instead of camping on it; once frames land the
    job re-leases, restores the checkpoint's ingest watermark, and
    finishes bit-identical."""
    svc = PipelineService(workers_remote=True, lease_ttl=5.0,
                          sweep_interval=0.1)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    spec = _spec(seed=31)
    frames = _frames(spec)
    w = PipelineWorker(client.base_url, worker_id="sw", poll=0.01,
                       checkpoint_dir=str(tmp_path / "ck"),
                       preview_interval=0.0)
    try:
        jid = client.submit(spec)
        client.ingest(jid, frames[:6], 0)
        w.register()
        assert w.run_once() is True               # leases, feeds 6, parks
        snap = client.status(jid)
        assert snap["state"] == "queued", snap    # back in the queue...
        assert snap["frames_consumed"] == 6
        st = client.stats()
        assert st["leases_expired"] == 0          # ...without an expiry
        assert any(line.startswith("jobs_parked ")
                   and int(line.split()[1]) >= 1
                   for line in client.metrics().splitlines())
        client.ingest(jid, frames[6:], 6)
        client.eof(jid)
        assert w.run_once() is True               # resumes at frame 6
        snap = client.wait(jid, timeout=60)
        assert snap["state"] == "done", snap
        assert snap["frames_consumed"] == frames.shape[0]
        assert snap["attempt"] >= 2               # park ended lease #1
        np.testing.assert_array_equal(client.result(jid),
                                      _reference(spec))
    finally:
        svc.stop()


def test_broker_mode_records_window_latency(tmp_path):
    """Regression: ``stream.window_latency_s`` must be observed in
    broker mode too (it was scheduler-mode only) — the worker times each
    ``pump`` and ships the measurement transiently on its next progress
    post, where the broker folds it into the histogram.  Transient means
    bare lease renewals must not re-observe a stale value."""
    svc = PipelineService(workers_remote=True, lease_ttl=5.0,
                          sweep_interval=0.1)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    spec = _spec(seed=37)
    frames = _frames(spec)
    w = PipelineWorker(client.base_url, worker_id="lw", poll=0.01,
                       checkpoint_dir=str(tmp_path / "ck"),
                       preview_interval=0.0)
    try:
        jid = client.submit(spec)
        client.ingest(jid, frames, 0)
        client.eof(jid)
        w.register()
        deadline = time.time() + 120
        while client.status(jid)["state"] not in ("done", "failed"):
            w.run_once()
            assert time.time() < deadline, client.status(jid)
        assert client.status(jid)["state"] == "done"
        np.testing.assert_array_equal(client.result(jid),
                                      _reference(spec))
        counts = {line.split()[0]: float(line.split()[1])
                  for line in client.metrics().splitlines()
                  if line and not line.startswith("#")}
        assert counts.get("stream_window_latency_s_count", 0) >= 1, \
            "broker mode never observed stream.window_latency_s"
    finally:
        svc.stop()


def test_stream_worker_sigkill_resumes_from_watermark(tmp_path):
    """SIGKILL the worker mid-pump: the lease expires, the next owner
    restores the checkpoint's ingest watermark, refetches the retained
    frame buffers it never saw, and finishes bit-identical to batch."""
    ckpt = str(tmp_path / "ckpts")
    svc = PipelineService(workers_remote=True, lease_ttl=1.5,
                          sweep_interval=0.1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(
        url, 2, transport="inmemory", checkpoint_dir=ckpt,
        poll=0.05, heartbeat=0.3, imports=("slow_plugins",),
        worker_ids=["w0", "w1"], pythonpath_extra=(TESTS_DIR,))
    by_id = dict(zip(["w0", "w1"], workers))
    try:
        spec = _spec(seed=41, delay=0.2)          # 0.2 s per frame pump
        frames = _frames(spec)
        jid = client.submit(spec)
        client.ingest(jid, frames[:6], 0)
        # first slab chewed + checkpointed (watermark 6)
        deadline = time.time() + 120
        while True:
            snap = client.status(jid)
            if snap.get("frames_consumed", 0) >= 6:
                break
            assert snap["state"] not in ("done", "failed"), snap
            assert time.time() < deadline, f"slab never consumed: {snap}"
            time.sleep(0.05)
        # second slab: kill the owner mid-pump (6 frames x 0.2 s)
        client.ingest(jid, frames[6:12], 6)
        while True:
            snap = client.status(jid)
            if snap["state"] == "running" and snap["worker_id"]:
                break
            assert snap["state"] not in ("done", "failed"), snap
            assert time.time() < deadline, f"never re-leased: {snap}"
            time.sleep(0.05)
        victim = snap["worker_id"]
        time.sleep(0.4)                           # into the slow pump
        os.kill(by_id[victim].pid, signal.SIGKILL)
        client.ingest(jid, frames[12:], 12)
        client.eof(jid)
        snap = client.wait(jid, timeout=120)
        assert snap["state"] == "done", snap
        assert snap["frames_consumed"] == frames.shape[0]
        assert snap["attempt"] >= 2, snap
        np.testing.assert_array_equal(client.result(jid),
                                      _reference(spec))
        assert client.stats()["leases_expired"] >= 1
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


# ========================================================== trace spool
def test_trace_spool_ring(tmp_path):
    from repro.obs import TraceSpool
    from repro.obs.trace import Trace
    spool = TraceSpool(str(tmp_path / "spool"), max_traces=2)
    for i in range(3):
        tr = Trace(worker_id=f"w{i}")
        with tr.span("work"):
            pass
        spool.put(f"job-{i}", tr)
        time.sleep(0.02)                 # distinct mtimes for the ring
    assert len(spool) == 2
    assert spool.get("job-0") is None    # oldest evicted
    got = spool.get("job-2")
    assert got["job_id"] == "job-2"
    assert got["spans"] and got["spans"][0]["name"] == "work"


def test_trace_survives_history_eviction(tmp_path):
    """max_history evicts terminal jobs from the queue; their traces
    must still be served from the on-disk spool."""
    svc = PipelineService(n_workers=1, max_history=1,
                          trace_spool=str(tmp_path / "spool"))
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    try:
        j1 = client.submit(_spec(seed=1, streaming=False))
        client.wait(j1, timeout=120)
        j2 = client.submit(_spec(seed=2, streaming=False))
        client.wait(j2, timeout=120)
        # pruning runs at submit: the third submission evicts j1
        client.wait(client.submit(_spec(seed=3, streaming=False)),
                    timeout=120)
        with pytest.raises(ServiceError) as ei:
            client.status(j1)            # evicted from live history
        assert ei.value.status == 404
        tr = client.trace(j1)            # ...but the trace survived
        assert tr["job_id"] == j1 and tr["spans"]
    finally:
        svc.stop()
