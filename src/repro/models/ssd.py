"""Chunked linear-recurrence engine (Mamba-2 "SSD" form).

One engine serves both SSM families in the zoo:

  * Mamba2 / SSD:   h_t = exp(a_t)·h_{t-1} + B_t xᵀ_t ;  y_t = C_t h_t
  * mLSTM (xLSTM):  C_t = f_t·C_{t-1} + i_t·k_t vᵀ_t ;   h_t = C_t q_t
                     (q→C, k→B, i_t folded into v, log f_t → a_t)

with per-(step, head) scalar log-decay ``a_t``.  The sequence is split
into chunks of Q steps: the intra-chunk part is a masked quadratic
attention (MXU-friendly), the inter-chunk part is a tiny scan over
chunk states (B, H, N, P).  This is the standard quadratic↔recurrent
duality trade: O(S·Q) FLOPs instead of a length-S sequential scan.

All math in fp32 (long products of exponentials are precision-
sensitive); inputs are cast in, outputs cast back by callers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums.

    out[t, s] = Σ_{r=s+1..t} a_r  for t >= s, -inf above the diagonal.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def chunked_linear_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        log_a: jnp.ndarray, *, chunk: int = 64,
                        h0: jnp.ndarray | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compute y_t = q_t · h_t with h_t = exp(a_t) h_{t-1} + k_t vᵀ_t.

    q, k: (B, S, H, N); v: (B, S, H, P); log_a: (B, S, H).
    Returns (y (B, S, H, P), h_final (B, H, N, P)).
    S must be a multiple of ``chunk``.
    """
    b, s, h, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    chunk = max(1, chunk)
    c = s // chunk
    qc = q.reshape(b, c, chunk, h, n).astype(jnp.float32)
    kc = k.reshape(b, c, chunk, h, n).astype(jnp.float32)
    vc = v.reshape(b, c, chunk, h, p).astype(jnp.float32)
    ac = log_a.reshape(b, c, chunk, h).astype(jnp.float32)

    # --- intra-chunk (quadratic, masked by decay kernel) ---------------
    seg = segsum(ac.transpose(0, 1, 3, 2))           # (b, c, h, Q, Q)
    L = jnp.exp(seg)
    scores = jnp.einsum("bcthn,bcshn->bchts", qc, kc)
    y_diag = jnp.einsum("bchts,bchts,bcshp->bcthp",
                        scores, L, vc)

    # --- chunk summaries ------------------------------------------------
    a_cum = jnp.cumsum(ac, axis=2)                   # (b, c, Q, h)
    a_tot = a_cum[:, :, -1:, :]                      # (b, c, 1, h)
    decay_to_end = jnp.exp(a_tot - a_cum)            # (b, c, Q, h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp",
                        kc, decay_to_end, vc)        # per-chunk new state

    # --- inter-chunk recurrence over c (tiny scan) ----------------------
    a_chunk = jnp.exp(a_tot[:, :, 0, :])             # (b, c, h)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def step(hprev, inp):
        a_c, s_c = inp                               # (b, h), (b, h, n, p)
        hnew = hprev * a_c[..., None, None] + s_c
        return hnew, hprev                           # emit state *before*

    a_sw = jnp.moveaxis(a_chunk, 1, 0)               # (c, b, h)
    s_sw = jnp.moveaxis(states, 1, 0)                # (c, b, h, n, p)
    h_final, h_prevs = jax.lax.scan(step, h0.astype(jnp.float32),
                                    (a_sw, s_sw))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)            # (b, c, h, n, p)

    # --- inter-chunk contribution ---------------------------------------
    decay_from_start = jnp.exp(a_cum)                # (b, c, Q, h)
    y_off = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp",
                       qc, decay_from_start, h_prevs)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, h_final


def linear_scan_step(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     log_a: jnp.ndarray, h: jnp.ndarray
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step.  q/k (B, H, N), v (B, H, P), log_a (B, H),
    h (B, H, N, P) -> (y (B, H, P), h_new)."""
    hf = h.astype(jnp.float32)
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    h_new = hf * a + jnp.einsum("bhn,bhp->bhnp", k.astype(jnp.float32),
                                v.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), h_new)
    return y, h_new


def reference_scan(q, k, v, log_a, h0=None):
    """Naive sequential oracle for tests (fp32)."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    hst = (jnp.zeros((b, h, n, p), jnp.float32) if h0 is None
           else h0.astype(jnp.float32))
    ys = []
    for t in range(s):
        y, hst = linear_scan_step(q[:, t], k[:, t], v[:, t], log_a[:, t],
                                  hst)
        ys.append(y)
    return jnp.stack(ys, axis=1), hst
