"""Wire format (repro.service.wire): spec v1 round-trips, loud
rejection of unknown plugins/params, the param-introspection registry
served at GET /plugins — plus hypothesis property tests (arbitrary
valid specs round-trip and preserve the chain signature; arbitrary
invalid specs always raise with the valid alternatives listed)."""
import json

import pytest

from repro.core import LambdaFilter, ProcessList
from repro.core.process_list import ProcessListError
from repro.service import (WireError, chain_signature, from_spec,
                           register_plugin, registered_plugins,
                           registry_spec, to_spec)
from repro.service.wire import _valid_params
from repro.tomo import SyntheticTomoLoader, standard_chain

try:                       # same optional dep the other property tests
    from hypothesis import given, settings   # use via importorskip —
    from hypothesis import strategies as st  # but this module also has
    HAVE_HYPOTHESIS = True                   # plain tests to keep
except ImportError:                          # pragma: no cover
    HAVE_HYPOTHESIS = False


def test_round_trip_preserves_chain_signature():
    pl = standard_chain(n_det=24, n_angles=24, n_rows=1, paganin=True)
    spec = to_spec(pl)
    json.dumps(spec)                         # must be wire-able
    pl2 = from_spec(spec)
    assert chain_signature(pl) == chain_signature(pl2)
    assert pl2.check() == pl.check()


def test_round_trip_is_stable():
    spec = to_spec(standard_chain(n_det=16, n_angles=16))
    assert to_spec(from_spec(spec)) == spec


def test_from_spec_accepts_bare_plugin_list():
    spec = to_spec(standard_chain(n_det=16, n_angles=16))
    pl = from_spec(spec["plugins"])
    assert chain_signature(pl) == chain_signature(
        standard_chain(n_det=16, n_angles=16))


def test_unknown_plugin_rejected_loudly():
    with pytest.raises(WireError, match="unknown plugin 'warp_drive'"):
        from_spec({"plugins": [{"plugin": "warp_drive"}]})
    # the error names the registered alternatives
    with pytest.raises(WireError, match="synthetic_tomo_loader"):
        from_spec({"plugins": [{"plugin": "warp_drive"}]})


def test_unknown_param_rejected_loudly():
    spec = {"plugins": [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": 16, "warp": 9},
         "out_datasets": ["tomo"]}]}
    with pytest.raises(WireError, match=r"unknown params \['warp'\]"):
        from_spec(spec)


@pytest.mark.parametrize("spec", [
    42, "nope", {}, {"plugins": []}, {"plugins": [7]},
    {"plugins": [{"params": {}}]},
    {"version": 99, "plugins": [{"plugin": "fbp_recon"}]},
    {"plugins": [{"plugin": "fbp_recon", "params": ["not", "a", "dict"]}]},
    {"plugins": [{"plugin": "fbp_recon", "in_datasets": "tomo"}]},
])
def test_malformed_specs_rejected(spec):
    with pytest.raises(WireError):
        from_spec(spec)


def test_to_spec_rejects_unregistered_plugin():
    pl = ProcessList()
    pl.add(SyntheticTomoLoader, params={"n_det": 16, "n_angles": 16},
           out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("tomo",), out_datasets=("tomo",))
    with pytest.raises(WireError, match="not wire-registered"):
        to_spec(pl)


def test_register_plugin_conflict_rejected():
    class Impostor(SyntheticTomoLoader):
        name = "synthetic_tomo_loader"
    with pytest.raises(WireError, match="already registered"):
        register_plugin(Impostor)
    # re-registering the SAME class is a no-op
    register_plugin(SyntheticTomoLoader)
    assert registered_plugins()["synthetic_tomo_loader"] \
        is SyntheticTomoLoader


def test_structural_errors_still_caught_by_check():
    # wire-valid but structurally broken: no saver
    spec = {"plugins": [
        {"plugin": "synthetic_tomo_loader", "params": {"n_det": 16},
         "out_datasets": ["tomo"]}]}
    pl = from_spec(spec)                     # deserialises fine
    with pytest.raises(ProcessListError, match="saver"):
        pl.check()


# ------------------------------------------------- property tests
if HAVE_HYPOTHESIS:
    _REG = registered_plugins()              # snapshot for sampling
    _WIRE_NAMES = sorted(_REG)
    _DS_NAMES = ("a", "b", "c", "d")

    _json_values = st.recursive(
        st.none() | st.booleans() | st.integers(-2 ** 31, 2 ** 31)
        | st.floats(allow_nan=False, allow_infinity=False)
        | st.text(max_size=8),
        lambda kids: st.lists(kids, max_size=3)
        | st.dictionaries(st.text(max_size=4), kids, max_size=3),
        max_leaves=6)

    @st.composite
    def _valid_entries(draw):
        """One canonical spec entry: a registered plugin, a subset of
        its declared params with arbitrary JSON values, short dataset
        wiring lists; empty fields omitted (the form to_spec emits)."""
        name = draw(st.sampled_from(_WIRE_NAMES))
        entry = {"plugin": name}
        declared = sorted(_REG[name].parameters)
        if declared:
            params = draw(st.dictionaries(st.sampled_from(declared),
                                          _json_values, max_size=3))
            if params:
                entry["params"] = params
        for key in ("in_datasets", "out_datasets"):
            names = draw(st.lists(st.sampled_from(_DS_NAMES),
                                  max_size=2, unique=True))
            if names:
                entry[key] = names
        return entry

    @st.composite
    def _valid_specs(draw):
        return {"version": 1,
                "plugins": draw(st.lists(_valid_entries(),
                                         min_size=1, max_size=4))}

    @given(spec=_valid_specs())
    @settings(max_examples=60, deadline=None)
    def test_property_valid_spec_round_trips(spec):
        """to_spec(from_spec(s)) == s for every canonical valid spec,
        and the round trip preserves the chain signature."""
        pl = from_spec(spec)
        again = to_spec(pl)
        assert again == spec
        assert chain_signature(from_spec(again)) == chain_signature(pl)

    @given(name=st.text(min_size=1, max_size=12).filter(
        lambda s: s not in registered_plugins()))
    @settings(max_examples=40, deadline=None)
    def test_property_unknown_plugin_lists_alternatives(name):
        with pytest.raises(WireError) as ei:
            from_spec({"plugins": [{"plugin": name}]})
        msg = str(ei.value)
        assert "unknown plugin" in msg
        for known in _WIRE_NAMES:        # every alternative is named
            assert known in msg

    @given(data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_property_unknown_param_lists_valid(data):
        wire = data.draw(st.sampled_from(_WIRE_NAMES))
        valid = _valid_params(_REG[wire])
        bad = data.draw(st.text(min_size=1, max_size=10).filter(
            lambda s: s not in valid))
        with pytest.raises(WireError) as ei:
            from_spec({"plugins": [{"plugin": wire,
                                    "params": {bad: 1}}]})
        msg = str(ei.value)
        assert "unknown params" in msg and "valid:" in msg
        for p in sorted(valid):          # the alternatives are listed
            assert p in msg

    _malformed_specs = st.one_of(
        st.integers(), st.text(max_size=6), st.booleans(),
        st.just({}),
        st.just({"plugins": []}),
        st.just({"plugins": [7]}),
        st.just({"plugins": [{"params": {}}]}),
        st.builds(
            lambda v: {"version": v,
                       "plugins": [{"plugin": "fbp_recon"}]},
            st.one_of(st.integers().filter(lambda v: v != 1),
                      st.just("1"))),
        st.just({"plugins": [{"plugin": "fbp_recon",
                              "params": ["not", "a", "dict"]}]}),
        st.just({"plugins": [{"plugin": "fbp_recon",
                              "in_datasets": "tomo"}]}),
        st.just({"plugins": [{"plugin": "fbp_recon",
                              "out_datasets": [1, 2]}]}),
        st.just({"plugins": [{"plugin": "synthetic_tomo_loader",
                              "params": {"seed": {1, 2}}}]}),
    )

    @given(spec=_malformed_specs)
    @settings(max_examples=60, deadline=None)
    def test_property_malformed_specs_always_raise(spec):
        with pytest.raises(WireError):
            from_spec(spec)


def test_registry_spec_is_jsonable_introspection():
    reg = registry_spec()
    json.dumps(reg)
    loader = reg["synthetic_tomo_loader"]
    assert loader["params"]["seed"]["data_param"] is True
    assert loader["params"]["n_det"] == {"default": 64,
                                         "data_param": False,
                                         "sweepable": False}
    assert loader["n_in_datasets"] == 0
    recon = reg["fbp_recon"]
    assert recon["params"]["use_pallas"]["default"] is True
    assert recon["n_out_datasets"] == 1
    # tunable params surface as sweepable (the sweep admission check)
    assert reg["sinogram_filter"]["params"]["cutoff"]["sweepable"] is True
    assert reg["ring_removal"]["params"]["strength"]["sweepable"] is True
    assert reg["paganin_filter"]["params"]["tau"]["sweepable"] is True
