from .checkpoint import CheckpointManager
from .compression import compressed_psum, dequantise_int8, quantise_int8, quantise_tree
from .param_sharding import batch_shardings, param_shardings, replicated, spec_for
from .straggler import StragglerEvent, StragglerMonitor

__all__ = ["CheckpointManager", "compressed_psum", "quantise_int8",
           "dequantise_int8", "quantise_tree", "param_shardings",
           "batch_shardings", "replicated", "spec_for",
           "StragglerMonitor", "StragglerEvent"]
