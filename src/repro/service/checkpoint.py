"""Pipeline checkpoint/resume — Savu's MPI checkpointing, service-grade.

Savu checkpoints a run by keeping every intermediate HDF5 file plus a
NeXus file that links them; a killed job restarts at the last finished
plugin.  Here each job gets a directory under the store root holding

* ``checkpoint.nxs.json`` — the manifest: chain signature, completed
  plugin steps, and one entry per *surviving* dataset (name, shape,
  dtype, provenance, patterns, file link) — the same schema as the
  runner's ``savu_manifest.nxs.json``,
* one ``<dataset>.npy`` per surviving dataset (the HDF5 stand-in).

Writes are atomic (tmp + rename) so a kill mid-checkpoint leaves the
previous consistent state.  ``restore`` validates the chain signature —
a checkpoint from a different process list is ignored, not half-applied.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any

import numpy as np

from ..core.framework import PluginRunner
from .job import chain_signature


def _sig_str(sig: tuple) -> str:
    return json.dumps(sig, sort_keys=True)


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, job_id: str) -> str:
        return os.path.join(self.root, job_id)

    def _manifest_path(self, job_id: str) -> str:
        return os.path.join(self._dir(job_id), "checkpoint.nxs.json")

    # ------------------------------------------------------------------
    def save(self, job_id: str, runner: PluginRunner) -> None:
        """Persist the registry of surviving datasets + completion state
        after a finished plugin step."""
        d = self._dir(job_id)
        os.makedirs(d, exist_ok=True)
        entries = []
        for name, ds in runner.datasets.items():
            if not ds.is_populated:
                continue
            # a donated device buffer (ShardedTransport donate=True) is
            # dead the moment its consumer ran; such a dataset cannot be
            # read OR needed downstream — skip it rather than crash
            if getattr(ds.backing, "is_deleted", None) and \
                    ds.backing.is_deleted():
                continue
            arr = runner.transport.read(ds)
            path = os.path.join(d, f"{name}.npy")
            tmp = path + ".tmp"
            with open(tmp, "wb") as fh:
                np.save(fh, np.asarray(arr))
            os.replace(tmp, path)
            entries.append({
                "name": name, "shape": list(ds.shape),
                "dtype": str(np.dtype(ds.dtype)),
                "axis_labels": list(ds.axis_labels),
                "produced_by": ds.produced_by,
                "patterns": sorted(ds.patterns),
                "file": os.path.basename(path)})
        manifest = {
            "job_id": job_id,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "chain": _sig_str(chain_signature(runner.process_list)),
            "completed_steps": runner.current_step,
            "n_steps": runner.n_steps,
            "step_labels": runner.step_labels(),
            "datasets": entries,
        }
        tmp = self._manifest_path(job_id) + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(manifest, fh, indent=2)
        os.replace(tmp, self._manifest_path(job_id))

    # ------------------------------------------------------------------
    def load(self, job_id: str) -> dict[str, Any] | None:
        try:
            with open(self._manifest_path(job_id)) as fh:
                return json.load(fh)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def restore(self, job_id: str, runner: PluginRunner) -> int:
        """Fast-forward a PREPARED-or-fresh runner to the checkpointed
        step, reloading surviving dataset contents.  Returns the number
        of plugin steps skipped (0 = no usable checkpoint)."""
        man = self.load(job_id)
        if man is None:
            return 0
        runner.prepare()
        if man["chain"] != _sig_str(chain_signature(runner.process_list)):
            return 0                      # different pipeline: start over
        # the step basis must match too: the same chain re-run under a
        # different fuse setting has different groups, and skipping N of
        # THOSE would skip plugins that never ran
        if (man.get("n_steps") != runner.n_steps
                or man.get("step_labels") != runner.step_labels()):
            return 0
        step = int(man["completed_steps"])
        if not 0 < step <= runner.n_steps:
            return 0
        data = {}
        for ent in man["datasets"]:
            path = os.path.join(self._dir(job_id), ent["file"])
            try:
                data[ent["name"]] = np.load(path)
            except (FileNotFoundError, ValueError):
                return 0                  # torn checkpoint: start over
        runner.skip_to(step, data)
        return step

    def clear(self, job_id: str) -> None:
        shutil.rmtree(self._dir(job_id), ignore_errors=True)
