"""LM substrate step costs on reduced configs (CPU): train step,
prefill and decode step for a dense and the hybrid arch."""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs import get_config, smoke_batch
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.training import init_training, make_serve_step, make_train_step


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / reps


def run(report):
    for arch in ("granite-8b", "zamba2-1.2b"):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        batch = smoke_batch(cfg, batch=4, seq=64)
        params, opt = init_training(model, jax.random.key(0))
        ts = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=1)))
        t = _time(ts, params, opt, batch)
        toks = 4 * 64
        report(f"train_step_{arch}_smoke", t * 1e6,
               f"{toks / t:.0f} tok/s (reduced cfg, cpu)")

        _, cache = model.prefill(params, batch, max_len=96)
        step = jax.jit(make_serve_step(model))
        tok = np.zeros((4, 1), np.int32)
        t = _time(step, params, tok, cache)
        report(f"decode_step_{arch}_smoke", t * 1e6,
               f"{4 / t:.0f} tok/s decode (reduced cfg, cpu)")
