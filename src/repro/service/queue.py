"""JobQueue — priority admission queue for pipeline jobs.

Higher ``priority`` pops first; equal priorities are FIFO.  Admission
control bounds the number of non-terminal jobs in the system
(``max_pending``): past the bound, ``submit`` either raises
:class:`QueueFull` (caller sheds load) or, with ``block=True``, applies
backpressure by waiting for capacity.  ``get_batch`` pops the head job
plus queued jobs with the SAME chain signature so the scheduler can gang
them into one compiled call per plugin step.

Jobs may depend on jobs (``after=[job_id]``, fan-out/fan-in — the
workflow-DAG substrate, docs/workflows.md): a job with dependencies is
not poppable until every upstream reached DONE.  An upstream that
fails or is cancelled cascade-cancels its whole downstream cone with a
machine-readable ``cancel_reason``; evicting a DONE upstream whose
RESULTS a queued downstream still needs (``data_deps``) cancels that
downstream with ``upstream_evicted``.  The queue performs those
transitions itself, so it exposes ``add_terminal_hook`` — the service
attaches metrics attribution there and every terminal transition is
observed exactly once, whether the scheduler, the broker or the queue
made it.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable

from ..core.process_list import ProcessList
from .job import Job, JobState


class QueueFull(RuntimeError):
    """Admission control rejected the submission (queue at max_pending)."""


class JobQueue:
    """Priority admission queue — the service side of the paper's
    "simultaneous processing of multiple datasets" (§I): many users'
    process lists queued against one facility pipeline.  Thread-safe;
    shared between HTTP handler threads and scheduler workers."""

    def __init__(self, max_pending: int | None = None,
                 max_history: int | None = None):
        """Args:
            max_pending: bound on non-terminal jobs; ``submit`` past it
                raises :class:`QueueFull` (or blocks with ``block=True``).
                None = unbounded.
            max_history: bound on retained TERMINAL jobs: beyond it the
                oldest finished jobs are evicted (their runner —
                datasets, device buffers, transport — released with
                them).  None keeps everything, which is right for batch
                CLIs/tests that read results after drain but leaks in a
                long-lived service.
        """
        self.max_pending = max_pending
        self.max_history = max_history
        self._heap: list[tuple[int, int, Job]] = []
        self._jobs: dict[str, Job] = {}
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._capacity = threading.Condition(self._lock)
        self._seq = itertools.count()
        self._evict_hooks: list[Callable[[Job], None]] = []
        self._terminal_hooks: list[Callable[[Job], None]] = []
        #: upstream job id -> ids of jobs submitted with it in ``after``
        self._downstream: dict[str, set[str]] = {}
        #: structured event log (set by the service); the queue emits
        #: ``job.submit`` for every admitted job — the one transition
        #: only the queue sees, whatever path (submit / sweeps /
        #: workflows) admitted it (docs/observability.md)
        self.events = None

    def _emit_submitted(self, jobs: list[Job]) -> None:
        if self.events is None:
            return
        for job in jobs:
            self.events.emit("job.submit", trace_id=job.trace_id,
                             job_id=job.job_id, priority=job.priority,
                             **({"after": list(job.after)}
                                if job.after else {}))

    def add_terminal_hook(self, hook: Callable[[Job], None]) -> None:
        """Register a callback fired for each terminal transition the
        QUEUE ITSELF performs — queue-side cancels and dependency
        cascades (``upstream_failed``/``upstream_cancelled``/
        ``upstream_evicted``).  The scheduler and broker observe their
        own transitions; this hook closes the gap so e.g. the
        ``jobs.cancelled`` metric counts every cancellation exactly
        once.  Called outside the queue lock; exceptions are
        swallowed."""
        self._terminal_hooks.append(hook)

    def _fire_terminal_hooks(self, jobs: list[Job]) -> None:
        for job in jobs:
            for hook in self._terminal_hooks:
                try:
                    hook(job)
                except Exception:  # noqa: BLE001 — telemetry best-effort
                    pass

    def add_evict_hook(self, hook: Callable[[Job], None]) -> None:
        """Register a callback fired for each TERMINAL job evicted by
        ``max_history`` pruning — how the broker ties its result spool
        GC to job retention.  Called with the evicted Job *after* it is
        removed and *outside* the queue lock (hooks may do filesystem
        I/O); exceptions are swallowed."""
        self._evict_hooks.append(hook)

    def _fire_evict_hooks(self, evicted: list[Job]) -> None:
        for job in evicted:
            for hook in self._evict_hooks:
                try:
                    hook(job)
                except Exception:    # noqa: BLE001 — GC best-effort
                    pass

    # -- dependencies (workflow DAGs, docs/workflows.md) ----------------
    @staticmethod
    def _check_after(job_id: str, after, data_deps, known) -> tuple:
        """Validate + normalise one job's dependency declaration.
        ``data_deps`` are dependencies too (merged into ``after``);
        every upstream id must be in ``known`` and self-references are
        refused.  Returns ``(after, data_deps)`` as deduped tuples."""
        dd = tuple(dict.fromkeys(data_deps or ()))
        merged = tuple(dict.fromkeys(tuple(after or ()) + dd))
        for uid in merged:
            if uid == job_id:
                raise ValueError(
                    f"job {job_id!r} cannot depend on itself")
            if uid not in known:
                raise ValueError(
                    f"unknown upstream job {uid!r} in after=[...] "
                    f"(submitted earlier and evicted, or never "
                    f"submitted)")
        return merged, dd

    def _cancel_dep_locked(self, job: Job, reason: str,
                           err: str) -> list[Job]:
        """Cancel a QUEUED job for a dependency reason, then cascade
        through its own downstream cone.  Returns every job cancelled
        (for the terminal hooks, fired outside the lock)."""
        if job.state is not JobState.QUEUED:
            return []
        job.state = JobState.CANCELLED
        job.cancel_reason = reason
        job.error = err
        job.finished_at = time.time()
        return [job] + self._propagate_terminal_locked(job)

    def _propagate_terminal_locked(self, job: Job) -> list[Job]:
        """``job`` reached a terminal state: clear it from downstream
        ``waiting`` sets (DONE — fan-in edges resolve, newly ready jobs
        wake waiters) or cascade-cancel the downstream cone (FAILED/
        CANCELLED).  Returns the jobs the queue cancelled."""
        cancelled: list[Job] = []
        woke = False
        for did in sorted(self._downstream.get(job.job_id, ())):
            d = self._jobs.get(did)
            if d is None or d.state is not JobState.QUEUED:
                continue
            if job.state is JobState.DONE:
                d.waiting.discard(job.job_id)
                woke = woke or d.deps_ready()
            elif job.job_id in d.waiting:
                reason = ("upstream_cancelled"
                          if job.state is JobState.CANCELLED
                          else "upstream_failed")
                cancelled.extend(self._cancel_dep_locked(
                    d, reason,
                    f"upstream {job.job_id} {job.state.value}"))
        if woke or cancelled:
            self._not_empty.notify_all()
            self._capacity.notify_all()
        return cancelled

    def _wire_deps_locked(self, job: Job, after: tuple[str, ...],
                          data_deps: tuple[str, ...]) -> list[Job]:
        """Record ``job``'s upstream edges (ids pre-validated).  DONE
        upstreams are satisfied immediately; an upstream that already
        failed/was cancelled applies the cascade rule at admission —
        the job is admitted, then cancelled like any other downstream.
        Returns the jobs cancelled that way."""
        job.after = after
        job.data_deps = data_deps
        job.waiting = set()
        for uid in after:
            self._downstream.setdefault(uid, set()).add(job.job_id)
            up = self._jobs.get(uid)
            if up is None or not up.state.terminal():
                job.waiting.add(uid)
        for uid in after:
            up = self._jobs.get(uid)
            if up is not None and up.state.terminal() \
                    and up.state is not JobState.DONE:
                reason = ("upstream_cancelled"
                          if up.state is JobState.CANCELLED
                          else "upstream_failed")
                return self._cancel_dep_locked(
                    job, reason, f"upstream {uid} {up.state.value}")
        return []

    # -- admission ------------------------------------------------------
    def _pending_locked(self) -> int:
        return sum(1 for j in self._jobs.values() if not j.state.terminal())

    def _prune_locked(self) -> tuple[list[Job], list[Job]]:
        """Evict over-history terminal jobs; returns ``(evicted,
        dep_cancelled)`` so the caller can fire the evict + terminal
        hooks once the lock is released.  Evicting a DONE upstream
        whose results a queued downstream still needs (``data_deps``)
        cancels that downstream with ``upstream_evicted``."""
        if self.max_history is None:
            return [], []
        terminal = sorted((j for j in self._jobs.values()
                           if j.state.terminal()), key=lambda j: j.seq)
        evicted = terminal[:max(0, len(terminal) - self.max_history)]
        for j in evicted:
            j.runner = None
            del self._jobs[j.job_id]
        cancelled: list[Job] = []
        for j in evicted:
            for did in sorted(self._downstream.pop(j.job_id, ())):
                d = self._jobs.get(did)
                if d is None or d.state is not JobState.QUEUED:
                    continue
                if j.job_id in d.data_deps:
                    cancelled.extend(self._cancel_dep_locked(
                        d, "upstream_evicted",
                        f"upstream {j.job_id} result evicted from "
                        f"history"))
                else:
                    d.waiting.discard(j.job_id)
        return evicted, cancelled

    def submit(self, process_list: ProcessList, *, priority: int = 0,
               job_id: str | None = None, block: bool = False,
               timeout: float | None = None,
               metadata: dict[str, Any] | None = None,
               trace_id: str | None = None,
               after: list[str] | None = None,
               data_deps: list[str] | None = None) -> Job:
        """Admit one process list as a :class:`Job`.

        Args:
            process_list: the chain to run (checked at dispatch, not
                here — use ``ProcessList.check()`` first to fail fast).
            priority: higher pops first; FIFO within a priority.
            job_id: explicit id (resubmit a killed job's id to resume
                from its checkpoint); default ``job-{seq:04d}``.
            block: past ``max_pending``, wait for capacity instead of
                raising.
            timeout: cap on the ``block=True`` wait, in seconds.
            metadata: free-form annotations carried on the job.
            trace_id: explicit telemetry trace id (correlate with an
                external tracer); default a fresh one per job.
            after: upstream job ids this job must wait for; the job is
                only poppable once every one reached DONE, and an
                upstream failure/cancel cascades (docs/workflows.md).
            data_deps: the subset of upstreams whose RESULTS this job
                consumes (auto-added to ``after``); evicting one
                before this job runs cancels it (upstream_evicted).

        Returns: the QUEUED job (possibly already CANCELLED, if an
            upstream in ``after`` had already failed).
        Raises:
            QueueFull: admission rejected (or the blocking wait timed
                out).
            ValueError: ``job_id`` names a still-active job, or
                ``after`` names an unknown upstream / the job itself.
        """
        def check_id():
            # re-checked after every capacity wait: two blocked
            # submitters with the same explicit id must not both insert
            if (job_id in self._jobs
                    and not self._jobs[job_id].state.terminal()):
                raise ValueError(f"job id {job_id!r} already active")

        evicted: list[Job] = []
        dep_cancelled: list[Job] = []
        admitted: list[Job] = []
        try:
            with self._lock:
                evicted, dep_cancelled = self._prune_locked()
                seq = next(self._seq)
                job_id = job_id or f"job-{seq:04d}"
                check_id()
                aft, dd = self._check_after(job_id, after, data_deps,
                                            self._jobs)
                if self.max_pending is not None:
                    deadline = (None if timeout is None
                                else time.time() + timeout)
                    while self._pending_locked() >= self.max_pending:
                        if not block:
                            raise QueueFull(
                                f"{self._pending_locked()} jobs pending "
                                f"(max_pending={self.max_pending})")
                        remaining = (None if deadline is None
                                     else deadline - time.time())
                        if remaining is not None and remaining <= 0:
                            raise QueueFull(
                                f"timed out after {timeout}s waiting for "
                                f"queue capacity")
                        self._capacity.wait(remaining)
                        check_id()
                        # upstreams may have been evicted while blocked
                        aft, dd = self._check_after(job_id, aft, dd,
                                                    self._jobs)
                job = Job(job_id, process_list, priority=priority, seq=seq,
                          metadata=dict(metadata or {}),
                          trace_id=trace_id or "")
                self._jobs[job_id] = job
                heapq.heappush(self._heap, (-priority, seq, job))
                dep_cancelled.extend(self._wire_deps_locked(job, aft, dd))
                admitted.append(job)
                self._not_empty.notify()
                return job
        finally:
            # hooks (broker spool GC, metrics) do I/O — never under the
            # queue lock, and even when admission raises
            self._emit_submitted(admitted)
            self._fire_evict_hooks(evicted)
            self._fire_terminal_hooks(dep_cancelled)

    def submit_many(self, process_lists: list[ProcessList], *,
                    priority: int = 0,
                    job_ids: list[str] | None = None,
                    metadatas: list[dict[str, Any]] | None = None,
                    afters: list[list[str]] | None = None,
                    data_deps: list[list[str]] | None = None
                    ) -> list[Job]:
        """Admit a GROUP of process lists atomically — all admitted, or
        nothing is.  The jobs get consecutive ``seq`` numbers under one
        lock hold, so no other submission (or dispatch) interleaves: a
        gang-batching pop sees the whole group together.  This is the
        parameter-sweep admission path (``repro.service.sweep``) and
        the workflow-DAG admission path (``repro.service.workflow``):
        ``afters`` may reference ids WITHIN the group (in any order —
        acyclicity is the workflow layer's contract), so a whole DAG
        lands in one atomic call.

        Args:
            process_lists: the chains, in variant order.
            priority: shared by every member (a sweep is one workload).
            job_ids: explicit ids, same length (default ``job-{seq}``).
            metadatas: per-job annotations, same length.
            afters: per-job upstream id lists (see :meth:`submit`).
            data_deps: per-job result-consuming upstream id lists.

        Returns: the queued Jobs, in input order.
        Raises:
            QueueFull: the WHOLE group would exceed ``max_pending`` —
                nothing was admitted.
            ValueError: a job id is already active (or duplicated within
                the group), or an ``afters`` entry names an unknown
                upstream — nothing was admitted.
        """
        n = len(process_lists)
        if job_ids is not None and len(job_ids) != n:
            raise ValueError(f"{len(job_ids)} job_ids for {n} jobs")
        if metadatas is not None and len(metadatas) != n:
            raise ValueError(f"{len(metadatas)} metadatas for {n} jobs")
        if afters is not None and len(afters) != n:
            raise ValueError(f"{len(afters)} afters for {n} jobs")
        if data_deps is not None and len(data_deps) != n:
            raise ValueError(f"{len(data_deps)} data_deps for {n} jobs")
        evicted: list[Job] = []
        dep_cancelled: list[Job] = []
        admitted: list[Job] = []
        try:
            with self._lock:
                evicted, dep_cancelled = self._prune_locked()
                if self.max_pending is not None and \
                        self._pending_locked() + n > self.max_pending:
                    raise QueueFull(
                        f"group of {n} would exceed max_pending="
                        f"{self.max_pending} ({self._pending_locked()} "
                        f"already pending)")
                if job_ids is not None:
                    if len(set(job_ids)) != n:
                        raise ValueError(
                            "duplicate job ids within the group")
                    for jid in job_ids:
                        if jid in self._jobs and \
                                not self._jobs[jid].state.terminal():
                            raise ValueError(
                                f"job id {jid!r} already active")
                # dependency ids may point at existing jobs OR group
                # members; validate EVERYTHING before inserting anything
                # (all-or-nothing admission)
                deps: list[tuple] = []
                if afters is not None or data_deps is not None:
                    known = set(self._jobs) | set(job_ids or ())
                    for i in range(n):
                        jid = job_ids[i] if job_ids is not None else None
                        deps.append(self._check_after(
                            jid, (afters or [()] * n)[i],
                            (data_deps or [()] * n)[i], known))
                jobs = []
                for i, pl in enumerate(process_lists):
                    seq = next(self._seq)
                    jid = job_ids[i] if job_ids is not None \
                        else f"job-{seq:04d}"
                    job = Job(jid, pl, priority=priority, seq=seq,
                              metadata=dict((metadatas or [{}] * n)[i]))
                    self._jobs[jid] = job
                    heapq.heappush(self._heap, (-priority, seq, job))
                    jobs.append(job)
                # wire deps only once every member exists, so in-group
                # references resolve regardless of declaration order
                for job, (aft, dd) in zip(jobs, deps):
                    dep_cancelled.extend(
                        self._wire_deps_locked(job, aft, dd))
                admitted.extend(jobs)
                self._not_empty.notify_all()
                return jobs
        finally:
            self._emit_submitted(admitted)
            self._fire_evict_hooks(evicted)
            self._fire_terminal_hooks(dep_cancelled)

    # -- dispatch -------------------------------------------------------
    def _pop_locked(self, predicate: Callable[[Job], bool] | None = None
                    ) -> Job | None:
        # Eligibility-filtered pop: scan the FULL dispatch order
        # (-priority, seq) and take the first eligible queued job —
        # with its dependencies satisfied (:meth:`Job.deps_ready`: a
        # DAG downstream keeps its queue position until every upstream
        # is DONE), matching the capability ``predicate`` AND, for
        # streaming jobs,
        # with work available (:meth:`Job.stream_ready`: a frame-starved
        # streaming job keeps its queue position without burning a
        # dispatch slot or lease until frames/EOF arrive and ``kick()``
        # re-wakes the waiters).  Non-eligible QUEUED jobs are left
        # exactly where they are: an unmatchable high-priority head
        # never shadows a matchable lower-priority job (we keep scanning
        # past it), and because skipped entries are not popped/re-pushed
        # their position — and FIFO fairness — is preserved for the
        # worker that CAN run them.  Terminal tombstones (cancelled
        # while queued) are discarded as the scan passes them.
        taken = None
        dead: list[tuple] = []
        for entry in sorted(self._heap, key=lambda e: (e[0], e[1])):
            job = entry[2]
            if job.state is not JobState.QUEUED:
                dead.append(entry)
                continue
            if job.deps_ready() and job.stream_ready() \
                    and (predicate is None or predicate(job)):
                job.state = JobState.CHECKING
                taken = entry
                break
        if taken is not None:
            dead.append(taken)
        if dead:
            drop = {id(e) for e in dead}
            self._heap = [e for e in self._heap if id(e) not in drop]
            heapq.heapify(self._heap)
        return None if taken is None else taken[2]

    def kick(self) -> None:
        """Wake every blocked :meth:`get`/:meth:`get_batch` caller so it
        re-evaluates job eligibility — called by the ingest endpoints
        when frames or EOF arrive for a parked streaming job (its
        ``stream_ready()`` may just have flipped to True)."""
        with self._lock:
            self._not_empty.notify_all()

    def get(self, timeout: float | None = None,
            predicate: Callable[[Job], bool] | None = None) -> Job | None:
        """Pop the highest-priority queued job (None on timeout).

        Args:
            timeout: seconds to wait for a (matching) job; None = forever.
            predicate: capability filter — only jobs it accepts are
                eligible; non-matching jobs keep their queue position
                (see :meth:`_pop_locked` for the starvation guarantee).
        """
        deadline = None if timeout is None else time.time() + timeout
        with self._lock:
            while True:
                job = self._pop_locked(predicate)
                if job is not None:
                    return job
                remaining = (None if deadline is None
                             else deadline - time.time())
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(remaining)

    def get_batch(self, max_jobs: int, timeout: float | None = None,
                  match: Callable[[Job, Job], bool] | None = None,
                  predicate: Callable[[Job], bool] | None = None
                  ) -> list[Job]:
        """Pop the head job plus up to ``max_jobs - 1`` queued jobs with
        an identical chain signature (gang scheduling).  Candidates are
        scanned in dispatch order — sorted ``(-priority, seq)``, not raw
        heap-array order — so gang members join by priority then FIFO
        and a truncated gang takes the jobs whose turn it actually is.
        ``predicate`` restricts both the head and the gang members to
        jobs a capability-filtered worker can run (lease path).
        Streaming jobs never gang — their pace is set by frame arrival,
        not by the compiled step loop — so a streaming head pops solo
        and streaming members are skipped."""
        head = self.get(timeout, predicate)
        if head is None:
            return []
        if head.streaming:
            return [head]
        match = match or (lambda a, b: a.chain_sig == b.chain_sig)
        batch = [head]
        with self._lock:
            for entry in sorted(self._heap, key=lambda e: (e[0], e[1])):
                if len(batch) >= max_jobs:
                    break
                job = entry[2]
                if job.state is JobState.QUEUED and not job.streaming \
                        and job.deps_ready() and match(head, job) \
                        and (predicate is None or predicate(job)):
                    job.state = JobState.CHECKING
                    batch.append(job)
            if len(batch) > 1:
                taken = {id(j) for j in batch}
                self._heap = [e for e in self._heap
                              if id(e[2]) not in taken]
                heapq.heapify(self._heap)
        return batch

    def requeue(self, job: Job) -> bool:
        """Put a dispatched (leased) job back in the queue — the broker's
        lease-expiry path.  The job keeps its original ``seq``, so it
        re-enters at the FRONT of its priority class (it is the oldest
        submission there) and resumes promptly on the next capable
        worker.  Returns False (and does nothing) for terminal jobs."""
        with self._lock:
            if job.state.terminal() or job.state is JobState.QUEUED:
                return False
            job.state = JobState.QUEUED
            job.requeued_at = time.time()
            heapq.heappush(self._heap, (-job.priority, job.seq, job))
            self._not_empty.notify()
            return True

    # -- bookkeeping ----------------------------------------------------
    def job(self, job_id: str) -> Job:
        """Look up a job by id.  Raises KeyError if unknown (or already
        evicted by ``max_history``)."""
        with self._lock:
            return self._jobs[job_id]

    def cancel(self, job_id: str) -> bool:
        """Cancel a job that has not been dispatched yet.

        Returns:
            True — the job was QUEUED and is now CANCELLED (terminal;
            it will never execute, and blocked submitters are woken).
            False — unknown id, already dispatched (a worker owns it),
            or already terminal.  The refusal never mutates the job, so
            a cancel racing a dispatch resolves to exactly one winner.
        """
        cancelled: list[Job] = []
        try:
            with self._lock:
                job = self._jobs.get(job_id)
                if job is None or job.state is not JobState.QUEUED:
                    return False
                job.state = JobState.CANCELLED
                job.cancel_reason = job.cancel_reason or "user"
                job.finished_at = time.time()
                cancelled = [job] + self._propagate_terminal_locked(job)
                self._capacity.notify_all()
                return True
        finally:
            self._fire_terminal_hooks(cancelled)

    def notify_terminal(self, job: Job | None = None) -> None:
        """Scheduler/broker hook: a job reached a terminal state — wake
        blocked submitters (admission capacity freed) and, when the
        terminal ``job`` is passed, resolve the dependency graph:
        a DONE upstream releases its downstream fan-out edges, a
        failed/cancelled one cascade-cancels the downstream cone (the
        cascaded jobs fire the terminal hooks)."""
        cancelled: list[Job] = []
        with self._lock:
            # the guard matters: expiry paths notify with a job they
            # just REQUEUED — propagating a non-terminal job would
            # cascade-cancel a perfectly live downstream cone
            if job is not None and job.state.terminal():
                cancelled = self._propagate_terminal_locked(job)
            self._capacity.notify_all()
        self._fire_terminal_hooks(cancelled)

    def pending(self) -> int:
        """Number of non-terminal jobs (what admission control counts)."""
        with self._lock:
            return self._pending_locked()

    def queue_info(self) -> dict[str, Any]:
        """Starvation visibility (``GET /stats`` ``queue`` block): depth
        of still-QUEUED jobs, per-priority breakdown, and the oldest
        queued job's id + age since submission — the number that grows
        when the service is overloaded or a job is unmatchable."""
        now = time.time()
        with self._lock:
            queued = [j for j in self._jobs.values()
                      if j.state is JobState.QUEUED]
            by_priority: dict[str, int] = {}
            for j in queued:
                key = str(j.priority)
                by_priority[key] = by_priority.get(key, 0) + 1
            oldest = min(queued, key=lambda j: j.submitted_at,
                         default=None)
            return {
                "depth": len(queued),
                "by_priority": by_priority,
                "oldest_pending_job": (None if oldest is None
                                       else oldest.job_id),
                "oldest_pending_age": (None if oldest is None else
                                       round(now - oldest.submitted_at,
                                             6)),
            }

    def snapshot(self) -> list[dict[str, Any]]:
        """Every retained job's ``Job.snapshot()``, submission-ordered
        (``GET /jobs``)."""
        with self._lock:
            return [j.snapshot() for j in
                    sorted(self._jobs.values(), key=lambda j: j.seq)]

    def wait_all(self, timeout: float | None = None,
                 poll: float = 0.02) -> bool:
        """Block until every submitted job is terminal.  True on success,
        False on timeout."""
        deadline = None if timeout is None else time.time() + timeout
        while True:
            with self._lock:
                if all(j.state.terminal() for j in self._jobs.values()):
                    return True
            if deadline is not None and time.time() >= deadline:
                return False
            time.sleep(poll)
