"""8-bit Adam moments + HLO trip-count cost parser."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.adamw import _dq8, _q8
from repro.roofline.hlo_cost import analyse_hlo


@given(st.integers(1, 2000), st.floats(1e-6, 1e3))
@settings(max_examples=25, deadline=None)
def test_dynamic_int8_roundtrip_error(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray((rng.normal(size=(n,)) * scale).astype(np.float32))
    xr = _dq8(_q8(x), x.shape)
    # quadratic-map error: <= ~2/127 relative near blockmax, much finer
    # near zero; assert a loose global bound per block
    err = np.abs(np.asarray(xr - x))
    bmax = np.abs(np.asarray(jnp.pad(x, (0, (-n) % 256)).reshape(-1, 256)
                             )).max(1)
    eb = np.pad(err, (0, (-n) % 256)).reshape(-1, 256).max(1)
    assert np.all(eb <= bmax * 0.02 + 1e-12)


def test_dynamic_int8_preserves_small_values():
    """The failure mode that killed linear int8: tiny v entries next to a
    large blockmax must NOT quantise to zero."""
    x = jnp.asarray(np.array([1.0] + [1e-4] * 255, np.float32))
    xr = np.asarray(_dq8(_q8(x), x.shape))
    assert xr[1] > 0  # survives
    assert abs(xr[1] - 1e-4) / 1e-4 < 0.7


def test_int8_adam_matches_fp32_closely():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(512,)).astype(np.float32))}
    grads = {"w": jnp.asarray(rng.normal(size=(512,)).astype(np.float32))}
    cfg32 = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=100,
                        weight_decay=0.0)
    cfg8 = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=100,
                       weight_decay=0.0, moments_dtype="int8")
    p32, s32 = dict(params), init_opt_state(params)
    p8, s8 = dict(params), init_opt_state(params, "int8")
    for _ in range(5):
        p32, s32, _ = adamw_update(cfg32, p32, grads, s32)
        p8, s8, _ = adamw_update(cfg8, p8, grads, s8)
    # per-element drift compounds (quantised moments); what must hold is
    # that the accumulated UPDATE points the same way at similar scale.
    u32 = np.asarray(p32["w"]) - np.asarray(params["w"])
    u8 = np.asarray(p8["w"]) - np.asarray(params["w"])
    cos = (u32 @ u8) / (np.linalg.norm(u32) * np.linalg.norm(u8))
    assert cos > 0.98, cos
    assert abs(np.linalg.norm(u8) / np.linalg.norm(u32) - 1) < 0.1


def test_hlo_cost_counts_loop_trips():
    L, B, D = 5, 8, 32

    def f(x, ws):
        def body(x, w):
            return jnp.dot(x, w).astype(x.dtype), None
        return jax.lax.scan(body, x, ws)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    res = analyse_hlo(c.as_text())
    assert res["flops"] == pytest.approx(2.0 * L * B * D * D, rel=0.01)


def test_hlo_cost_nested_scans():
    L, M, B, D = 3, 4, 4, 16

    def f(x, ws):
        def outer(x, wrow):
            def inner(x, w):
                return jnp.dot(x, w).astype(x.dtype), None
            return jax.lax.scan(inner, x, wrow)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, M, D, D), jnp.float32)).compile()
    res = analyse_hlo(c.as_text())
    assert res["flops"] == pytest.approx(2.0 * L * M * B * D * D, rel=0.01)


def test_hlo_cost_bytes_positive():
    c = jax.jit(lambda x: x * 2.0).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    res = analyse_hlo(c.as_text())
    assert res["bytes"] >= 64 * 64 * 4
