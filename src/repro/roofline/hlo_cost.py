"""Trip-count-aware HLO cost model.

XLA's HloCostAnalysis counts a ``while`` body ONCE, which under-counts
scan-over-layers / microbatch-scan programs by the loop trip product.
This parser rebuilds per-computation costs from the optimized HLO text
and multiplies them through the call graph:

  * dot FLOPs       = 2 · |result| · |lhs contracting dims|
  * bytes           ≈ 2 · Σ |op results|   (write + one read)
  * collective bytes by type (all-reduce weighted 2×: RS+AG phases)

Trip counts come from the loop condition computations (ROOT compare
against an s32 constant — the lowering jax.lax.scan produces).  Edges
followed: while body/condition (×trip), fusion/call ``calls=``,
conditional branches (×1, max over branches).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|"
                     r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (name, mult)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            s = line.strip()
            m = _COMP_HDR.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Largest s32 constant in the loop condition ≈ trip count."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def analyse_hlo(text: str) -> dict:
    comps = _split_computations(text)
    entry_name = comps.pop("__entry_name__")
    comps.pop("__entry__")

    # computations reached via a `fusion` op execute inside one kernel:
    # their interior results never touch HBM — suppress their bytes
    # (flops and collectives still count).  Fusions whose ROOT is a
    # dynamic-update-slice are in-place accumulator updates: their
    # traffic is the update slice, not the full result the op type
    # names (a scan's cache update would otherwise count the whole
    # cache every iteration).
    fusion_bodies: set[str] = set()
    dus_update_bytes: dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            m = _DEF_RE.match(line)
            if m and m.group(3).rstrip("0123456789.") == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                if cm:
                    fusion_bodies.add(cm.group(1))
    for name in fusion_bodies:
        shapes: dict[str, str] = {}
        upd_bytes = None
        for line in comps.get(name, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            shapes[m.group(1)] = m.group(2)
            base = m.group(3).rstrip("0123456789.")
            if base == "dynamic-update-slice":
                om = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
                if om:
                    ops_ = [o.strip().lstrip("%")
                            for o in om.group(1).split(",")]
                    if len(ops_) >= 2 and ops_[1] in shapes:
                        b = _nbytes(shapes[ops_[1]])
                        upd_bytes = (upd_bytes or 0) + b
        if upd_bytes is not None:
            dus_update_bytes[name] = upd_bytes

    costs: dict[str, CompCost] = {}
    for name, lines in comps.items():
        cc = CompCost(coll={k: 0 for k in _COLLECTIVES})
        in_fusion = name in fusion_bodies
        shapes: dict[str, str] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            opname, type_str, op = m.group(1), m.group(2), m.group(3)
            shapes[opname] = type_str
            base = op.rstrip("0123456789.")
            nb = _nbytes(type_str)
            # bytes: skip fusion interiors, parameters/gte (no traffic of
            # their own) — count real result-producing top-level ops.
            if not in_fusion and base not in (
                    "parameter", "get-tuple-element", "tuple", "bitcast",
                    "constant"):
                if base == "dynamic-update-slice":
                    # in-place: traffic = the update slice, not the
                    # whole accumulator the result type names.
                    om = re.search(r"dynamic-update-slice\(([^)]*)\)",
                                   line)
                    upd_nb = nb
                    if om:
                        ops_ = [o.strip().lstrip("%")
                                for o in om.group(1).split(",")]
                        if len(ops_) >= 2 and ops_[1] in shapes:
                            upd_nb = _nbytes(shapes[ops_[1]])
                    cc.bytes += 2 * upd_nb
                elif base == "fusion":
                    cm = re.search(r"calls=%?([\w.\-]+)", line)
                    tgt = cm.group(1) if cm else ""
                    if tgt in dus_update_bytes:
                        cc.bytes += 2 * dus_update_bytes[tgt]
                    else:
                        cc.bytes += 2 * nb
                else:
                    cc.bytes += 2 * nb
            # collectives
            for coll in _COLLECTIVES:
                if base == coll or base == coll + "-start":
                    cc.coll[coll] += nb
                    break
            # dots
            if base == "dot":
                operands = re.search(r"dot\(([^)]*)\)", line)
                lcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                result_elems = 0
                for dt, dims in _shape_dims(type_str):
                    n = 1
                    for d in dims:
                        n *= d
                    result_elems += n
                contract = 1
                if operands and lcd:
                    lhs = operands.group(1).split(",")[0].strip()
                    lhs = lhs.lstrip("%")
                    lhs_shape = shapes.get(lhs)
                    if lhs_shape:
                        sd = _shape_dims(lhs_shape)
                        if sd:
                            dims = sd[0][1]
                            for ci in lcd.group(1).split(","):
                                if ci and int(ci) < len(dims):
                                    contract *= dims[int(ci)]
                cc.flops += 2.0 * result_elems * contract
            # call edges
            wm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                           line)
            if wm:
                trip = _trip_count(comps.get(wm.group(1), []))
                cc.calls.append((wm.group(2), trip))
                cc.calls.append((wm.group(1), trip))
            else:
                for cm in re.finditer(
                        r"(?:calls|to_apply|branch_computations)="
                        r"\{?%?([\w.\-]+(?:, ?%?[\w.\-]+)*)\}?", line):
                    for target in re.split(r",\s*", cm.group(1)):
                        cc.calls.append((target.lstrip("%"), 1))
        costs[name] = cc

    # propagate multipliers from entry (memoised; HLO call graphs are DAGs)
    total = CompCost(coll={k: 0 for k in _COLLECTIVES})
    seen_stack: set[str] = set()

    def accumulate(name: str, mult: float) -> None:
        cc = costs.get(name)
        if cc is None or name in seen_stack or mult <= 0:
            return
        seen_stack.add(name)
        total.flops += cc.flops * mult
        total.bytes += cc.bytes * mult
        for k in _COLLECTIVES:
            total.coll[k] += cc.coll[k] * mult
        for child, trip in cc.calls:
            accumulate(child, mult * trip)
        seen_stack.discard(name)

    if entry_name:
        accumulate(entry_name, 1.0)

    weighted_coll = (total.coll["all-gather"] + 2 * total.coll["all-reduce"]
                     + total.coll["reduce-scatter"]
                     + total.coll["all-to-all"]
                     + total.coll["collective-permute"])
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "collective_bytes": weighted_coll,
        "coll_detail": dict(total.coll),
    }
