"""Architecture registry + assigned input shapes + input_specs().

The 10 assigned architectures (× 4 shapes = 40 nominal cells).  Cells
mandated skipped (DESIGN.md §Arch-applicability):
  * long_500k for the 8 pure-full-attention archs (needs sub-quadratic
    attention) — runs only for xlstm-1.3b and zamba2-1.2b.
All remaining 32 cells lower + compile on both production meshes.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig

_MODULES = {
    "granite-34b": "granite_34b",
    "granite-8b": "granite_8b",
    "phi4-mini-3.8b": "phi4_mini",
    "chatglm3-6b": "chatglm3_6b",
    "xlstm-1.3b": "xlstm_1p3b",
    "whisper-small": "whisper_small",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen3-moe-235b-a22b": "qwen3_moe",
    "llava-next-34b": "llava_next_34b",
    "zamba2-1.2b": "zamba2_1p2b",
}

ARCH_IDS = list(_MODULES)

# assigned LM shapes: name -> (seq_len, global_batch, kind)
SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

SUBQUADRATIC = {"xlstm-1.3b", "zamba2-1.2b"}


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.SMOKE if smoke else mod.FULL


def cell_supported(arch_id: str, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch × shape) cell."""
    if shape == "long_500k" and arch_id not in SUBQUADRATIC:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{arch_id} is full-attention (skip per assignment)")
    return True, ""


def all_cells(include_skipped: bool = False
              ) -> list[tuple[str, str, bool, str]]:
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            ok, why = cell_supported(a, s)
            if ok or include_skipped:
                out.append((a, s, ok, why))
    return out


# ----------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch_id: str
    shape_name: str
    kind: str                   # train | prefill | decode
    batch: dict[str, Any]       # ShapeDtypeStructs for the step inputs
    seq_len: int
    global_batch: int
    notes: str = ""


def input_specs(arch_id: str, shape_name: str, *,
                cfg: ModelConfig | None = None) -> CellSpec:
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, no device allocation."""
    cfg = cfg or get_config(arch_id)
    seq, gb, kind = SHAPES[shape_name]
    fam = cfg.family
    i32 = jnp.int32

    if kind in ("train", "prefill"):
        if fam == "encdec":
            t = cfg.max_frames or 1500
            batch = {
                "frames": _sds((gb, t, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((gb, seq), i32),
                "labels": _sds((gb, seq), i32),
            }
        elif fam == "vlm":
            from .llava_next_34b import PATCH_TOKENS
            pt = min(PATCH_TOKENS, seq // 2)
            batch = {
                "tokens": _sds((gb, seq - pt), i32),
                "patches": _sds((gb, pt, cfg.d_model), jnp.bfloat16),
                "labels": _sds((gb, seq), i32),
            }
        else:
            batch = {
                "tokens": _sds((gb, seq), i32),
                "labels": _sds((gb, seq), i32),
            }
        if kind == "prefill":
            batch.pop("labels")
        return CellSpec(arch_id, shape_name, kind, batch, seq, gb)

    # decode: one new token against a seq-long cache
    batch = {"token": _sds((gb, 1), i32)}
    return CellSpec(arch_id, shape_name, "decode", batch, seq, gb,
                    notes="cache specs from model.init_cache eval_shape")


def smoke_batch(cfg: ModelConfig, *, batch: int = 2, seq: int = 16,
                seed: int = 0) -> dict[str, np.ndarray]:
    """Concrete small batch for CPU smoke tests of any family."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)
    if cfg.family == "encdec":
        t = min(cfg.max_frames or 16, 16)
        return {"frames": rng.normal(size=(batch, t, cfg.d_model)
                                     ).astype(np.float32),
                "tokens": toks, "labels": toks.copy()}
    if cfg.family == "vlm":
        pt = max(2, seq // 4)
        patches = rng.normal(size=(batch, pt, cfg.d_model)
                             ).astype(np.float32)
        labels = np.concatenate(
            [np.full((batch, pt), -1, np.int32), toks], axis=1)
        return {"tokens": toks, "patches": patches, "labels": labels}
    return {"tokens": toks, "labels": toks.copy()}
