"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the host's
real device(s); only launch/dryrun.py fakes 512 devices."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
