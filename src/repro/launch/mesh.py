"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets
--xla_force_host_platform_device_count before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
