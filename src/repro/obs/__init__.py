# Telemetry layer: distributed job tracing + metrics registry
# (docs/observability.md).  Deliberately dependency-free — core and
# service both import obs, never the other way round.
from .metrics import (CATALOGUE, QUANTILES, Counter, Gauge, Histogram,
                      MetricsRegistry, catalogue_names, prometheus_name,
                      register_catalogue)
from .trace import (Span, Trace, TraceSpool, current_trace, new_span_id,
                    new_trace_id, render_gantt, use_trace)

__all__ = [
    "Span", "Trace", "TraceSpool", "current_trace", "use_trace",
    "new_trace_id",
    "new_span_id", "render_gantt", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "register_catalogue", "catalogue_names",
    "prometheus_name", "CATALOGUE", "QUANTILES",
]
