"""Pallas TPU kernel: blockwise (flash) attention with GQA head mapping.

FlashAttention-2-style streaming softmax: grid (B, Hq, Sq/bq, Sk/bk)
with the key axis innermost; running max m, normaliser l and the output
accumulator live in VMEM scratch across the k sweep.  GQA is expressed
in the k/v BlockSpec index maps (kv head = q head // group) so grouped
heads reuse the same KV block without materialising repeats — on real
hardware this is the difference between streaming K/V once per kv-head
group vs once per q head.

Causal blocks strictly above the diagonal are skipped with pl.when
(zero VMEM traffic, zero FLOPs), giving the ~2× causal speedup.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, d: int, causal: bool, scale: float):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # k block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (j <= i)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    last = i if causal else nk - 1

    @pl.when(j == last)
    def _finalise():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = True
                           ) -> jnp.ndarray:
    """q (B,Hq,S,D), k/v (B,Hkv,S,D) -> (B,Hq,S,D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(bq, s)
    bk = min(bk, s)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    if causal:
        bq = bk = min(bq, bk)   # diagonal finalisation needs bq == bk
    grid = (b, hq, s // bq, s // bk)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, d=d,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
