"""Cross-process serving: the HTTP front end over the JobQueue.

End-to-end per the PR acceptance criteria: an in-process server on an
ephemeral port, PipelineClient submissions at mixed priorities polled to
completion with results bit-identical to a serial PluginRunner; 429 on
admission rejection; 400 with the validation error for malformed specs;
compile-cache hits visible in GET /stats on identical resubmission."""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import ChunkedFileTransport, PluginRunner, ShardedTransport
from repro.service import (CompileCache, PipelineClient, PipelineService,
                           ServiceError, to_spec)
from repro.tomo import standard_chain

N = dict(n_det=20, n_angles=20, n_rows=1)


def _chain(seed=0, **over):
    return standard_chain(**{**N, **over}, seed=seed)


@pytest.fixture
def service():
    """A served PipelineService on an ephemeral port (sharded transport,
    shared compile cache) + a client for it."""
    cache = CompileCache()
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    svc = PipelineService(
        n_workers=2, compile_cache=cache,
        transport_factory=lambda job: ShardedTransport(
            mesh, donate=False, compile_cache=cache))
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=30.0)
    try:
        yield svc, client
    finally:
        svc.stop()


# ------------------------------------------------------------- end-to-end
def test_end_to_end_submit_poll_result(service):
    svc, client = service
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seeds_prios = [(0, 5), (1, 0), (2, 2)]
    ids = [client.submit(_chain(seed=s), priority=p,
                         metadata={"seed": s})
           for s, p in seeds_prios]
    for (seed, prio), jid in zip(seeds_prios, ids):
        snap = client.wait(jid, timeout=300)
        assert snap["state"] == "done", snap
        assert snap["priority"] == prio
        assert snap["metadata"]["seed"] == seed
        assert snap["plugin_index"] == snap["n_plugins"] > 0
        got = client.result(jid)
        # serial reference on the same transport type: bit-identical
        ref = PluginRunner(_chain(seed=seed),
                           ShardedTransport(mesh, donate=False)).run()
        want = np.asarray(ref["recon"].materialise())
        np.testing.assert_array_equal(got, want)

    # identical resubmission: zero new compiles, hits visible in /stats
    before = client.stats()["compile_cache"]
    jid = client.submit(_chain(seed=9))
    assert client.wait(jid, timeout=300)["state"] == "done"
    after = client.stats()["compile_cache"]
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]
    assert client.stats()["jobs_done"] == 4


def test_result_streams_from_chunked_files(tmp_path):
    svc = PipelineService(
        n_workers=1,
        transport_factory=lambda job: ChunkedFileTransport(
            str(tmp_path / job.job_id)))
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}")
    try:
        jid = client.submit(_chain(seed=3))
        assert client.wait(jid, timeout=300)["state"] == "done"
        got = client.result(jid, dataset="recon")
        ref = PluginRunner(_chain(seed=3)).run()
        np.testing.assert_allclose(
            got, np.asarray(ref["recon"].materialise()),
            rtol=1e-3, atol=1e-4)
    finally:
        svc.stop()


# ----------------------------------------------------------- error paths
def test_admission_rejection_is_429():
    svc = PipelineService(n_workers=1, max_pending=1)
    # scheduler workers deliberately NOT started: jobs stay pending
    host, port = svc.serve(port=0)
    svc.scheduler.shutdown()
    client = PipelineClient(f"http://{host}:{port}")
    try:
        client.submit(_chain())
        with pytest.raises(ServiceError) as ei:
            client.submit(_chain(seed=1))
        assert ei.value.status == 429
        assert "max_pending" in ei.value.message
    finally:
        svc.stop()


def test_unknown_plugin_spec_is_400(service):
    _, client = service
    with pytest.raises(ServiceError) as ei:
        client.submit({"plugins": [{"plugin": "warp_drive"}]})
    assert ei.value.status == 400
    assert "warp_drive" in ei.value.message


def test_structurally_broken_chain_is_400(service):
    _, client = service
    spec = {"plugins": [{"plugin": "synthetic_tomo_loader",
                         "params": {"n_det": 16},
                         "out_datasets": ["tomo"]}]}   # no saver
    with pytest.raises(ServiceError) as ei:
        client.submit(spec)
    assert ei.value.status == 400
    assert "saver" in ei.value.message


def test_malformed_json_body_is_400(service):
    svc, client = service
    req = urllib.request.Request(
        client.base_url + "/jobs", data=b"{not json",
        method="POST", headers={"Content-Type": "application/json"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=10)
    assert ei.value.code == 400
    assert "JSON" in json.loads(ei.value.read())["error"]


def test_unknown_job_is_404(service):
    _, client = service
    for call in (lambda: client.status("ghost"),
                 lambda: client.result("ghost"),
                 lambda: client.cancel("ghost")):
        with pytest.raises(ServiceError) as ei:
            call()
        assert ei.value.status == 404


def test_duplicate_active_job_id_is_409():
    svc = PipelineService(n_workers=1)
    host, port = svc.serve(port=0)
    svc.scheduler.shutdown()                 # keep the first job queued
    client = PipelineClient(f"http://{host}:{port}")
    try:
        client.submit(_chain(), job_id="twin")
        with pytest.raises(ServiceError) as ei:
            client.submit(_chain(seed=1), job_id="twin")
        assert ei.value.status == 409
    finally:
        svc.stop()


def test_result_before_done_is_409():
    svc = PipelineService(n_workers=1)
    host, port = svc.serve(port=0)
    svc.scheduler.shutdown()                 # job stays queued
    client = PipelineClient(f"http://{host}:{port}")
    try:
        jid = client.submit(_chain())
        with pytest.raises(ServiceError) as ei:
            client.result(jid)
        assert ei.value.status == 409
    finally:
        svc.stop()


def test_cancel_queued_job_via_http():
    svc = PipelineService(n_workers=1)
    host, port = svc.serve(port=0)
    svc.scheduler.shutdown()
    client = PipelineClient(f"http://{host}:{port}")
    try:
        jid = client.submit(_chain())
        out = client.cancel(jid)
        assert out["cancelled"] is True
        assert client.status(jid)["state"] == "cancelled"
        # a second cancel is consistently rejected (already terminal)
        with pytest.raises(ServiceError) as ei:
            client.cancel(jid)
        assert ei.value.status == 409
    finally:
        svc.stop()


def test_job_ids_with_url_unsafe_characters():
    """Ids containing spaces/'#'/'/' must stay addressable: the client
    percent-encodes path components and the server decodes them."""
    svc = PipelineService(n_workers=1)
    host, port = svc.serve(port=0)
    svc.scheduler.shutdown()                 # keep the job queued
    client = PipelineClient(f"http://{host}:{port}")
    try:
        jid = "scan 1/#7"
        assert client.submit(_chain(), job_id=jid) == jid
        assert client.status(jid)["job_id"] == jid
        assert client.cancel(jid)["cancelled"] is True
    finally:
        svc.stop()


def test_resumed_from_surfaces_over_http(tmp_path):
    """The docs §3 loop: a killed job's checkpoint + a resubmission
    under the same id → the snapshot reports resumed_from > 0."""
    from repro.service import CheckpointStore
    store = CheckpointStore(str(tmp_path))
    # simulate the kill: a partial run leaves a checkpoint behind
    r = PluginRunner(_chain(seed=7))
    r.prepare()
    r.step()
    store.save("scan-x", r)

    svc = PipelineService(n_workers=1, checkpoints=store)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}")
    try:
        jid = client.submit(_chain(seed=7), job_id="scan-x")
        snap = client.wait(jid, timeout=300)
        assert snap["state"] == "done", snap
        assert snap["resumed_from"] == 1
        ref = PluginRunner(_chain(seed=7)).run()
        np.testing.assert_allclose(
            client.result(jid), np.asarray(ref["recon"].materialise()),
            rtol=1e-3, atol=1e-4)
    finally:
        svc.stop()


# ------------------------------------------------------------- discovery
def test_healthz_jobs_and_plugins(service):
    svc, client = service
    assert client.health()["ok"] is True
    jid = client.submit(_chain())
    client.wait(jid, timeout=300)
    assert any(j["job_id"] == jid for j in client.jobs())
    reg = client.plugins()
    assert "fbp_recon" in reg
    assert reg["synthetic_tomo_loader"]["params"]["seed"]["data_param"]


def test_spec_submission_equals_processlist_submission(service):
    """A spec document POSTed raw behaves exactly like a ProcessList
    serialised client-side."""
    _, client = service
    spec = to_spec(_chain(seed=4))
    j1 = client.submit(spec)
    j2 = client.submit(_chain(seed=4))
    s1, s2 = (client.wait(j, timeout=300) for j in (j1, j2))
    assert s1["state"] == s2["state"] == "done"
    np.testing.assert_array_equal(client.result(j1), client.result(j2))
