"""Pallas TPU kernel: parallel-beam backprojection.

GPU codes (including the one Savu wrapped) implement backprojection as a
per-pixel *texture gather* along the detector axis.  TPUs have no
texture units and scalar gathers starve the VPU, so the kernel is
restructured around the MXU: for each angle the linear interpolation

    out[p] += (1-frac)·sino[θ, i0(p)] + frac·sino[θ, i1(p)]

is expressed as a dense *hat-function matmul*

    W[p, d] = max(0, 1 - |t(p) - d|)        (banded, built with iota)
    out    += W @ sino[θ, :]

so the accumulation over detector bins runs on the systolic array
(trading ~2·P·D redundant FLOPs for zero gathers — the right trade on
TPU where MXU FLOPs are ~3 orders cheaper than random access).

Grid = (H/bh, W/bw, A/ba); the angle axis is innermost and accumulates
into the output block (revisited across the last grid dim).  VMEM per
step: W tile (bh·bw, D)·4B + sino block (ba, D)·4B + out tile — the
BlockSpec shapes are chosen by the §IV.A chunking optimiser with
M = VMEM budget (see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bp_kernel(cos_ref, sin_ref, sino_ref, out_ref, *,
               bh: int, bw: int, ba: int, n_det: int, centre: float):
    h_idx = pl.program_id(0)
    w_idx = pl.program_id(1)
    a_idx = pl.program_id(2)
    n_a = pl.num_programs(2)

    # pixel coordinates of this tile, centred
    out_size_h = pl.num_programs(0) * bh
    cy = (out_size_h - 1) / 2.0  # assume square volume: cx == cy
    ys = (h_idx * bh + jax.lax.broadcasted_iota(jnp.float32, (bh, bw), 0)
          ) - cy
    xs = (w_idx * bw + jax.lax.broadcasted_iota(jnp.float32, (bh, bw), 1)
          ) - cy

    @pl.when(a_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = jax.lax.broadcasted_iota(jnp.float32, (bh * bw, n_det), 1)

    def body(k, acc):
        ct = cos_ref[k, 0]
        st = sin_ref[k, 0]
        t = xs * ct + ys * st + centre          # (bh, bw)
        tf = t.reshape(bh * bw, 1)
        # hat-function interpolation weights; clip keeps out-of-detector
        # rays at zero weight automatically (|t-d| >= 1 for all d).
        w = jnp.maximum(0.0, 1.0 - jnp.abs(tf - d))     # (P, D)
        row = sino_ref[k, :]                            # (D,)
        contrib = jax.lax.dot_general(
            w, row.reshape(n_det, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # (P, 1)
        return acc + contrib.reshape(bh, bw)

    acc = jax.lax.fori_loop(0, ba, body, jnp.zeros((bh, bw), jnp.float32))
    out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("out_size", "centre", "bh", "bw", "ba",
                                    "interpret"))
def backproject_pallas(sino: jnp.ndarray, cos_t: jnp.ndarray,
                       sin_t: jnp.ndarray, *, out_size: int,
                       centre: float | None = None,
                       bh: int = 8, bw: int = 128, ba: int = 16,
                       interpret: bool = True) -> jnp.ndarray:
    """(A, D) fp32 sinogram + angle tables (A, 1) -> (out_size, out_size).

    Scaling (π / A) is applied here, matching ref.backproject_ref.
    """
    n_angles, n_det = sino.shape
    if centre is None:
        centre = (n_det - 1) / 2.0
    assert out_size % bh == 0 and out_size % bw == 0, (out_size, bh, bw)
    assert n_angles % ba == 0, (n_angles, ba)
    grid = (out_size // bh, out_size // bw, n_angles // ba)

    kernel = functools.partial(_bp_kernel, bh=bh, bw=bw, ba=ba,
                               n_det=n_det, centre=float(centre))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, 1), lambda h, w, a: (a, 0)),       # cos
            pl.BlockSpec((ba, 1), lambda h, w, a: (a, 0)),       # sin
            pl.BlockSpec((ba, n_det), lambda h, w, a: (a, 0)),   # sino
        ],
        out_specs=pl.BlockSpec((bh, bw), lambda h, w, a: (h, w)),
        out_shape=jax.ShapeDtypeStruct((out_size, out_size), jnp.float32),
        interpret=interpret,
    )(cos_t.astype(jnp.float32), sin_t.astype(jnp.float32),
      sino.astype(jnp.float32))
    return out * (jnp.pi / n_angles)
