"""Wire format (repro.service.wire): spec v1 round-trips, loud
rejection of unknown plugins/params, and the param-introspection
registry served at GET /plugins."""
import json

import pytest

from repro.core import LambdaFilter, ProcessList
from repro.core.process_list import ProcessListError
from repro.service import (WireError, chain_signature, from_spec,
                           register_plugin, registered_plugins,
                           registry_spec, to_spec)
from repro.tomo import SyntheticTomoLoader, standard_chain


def test_round_trip_preserves_chain_signature():
    pl = standard_chain(n_det=24, n_angles=24, n_rows=1, paganin=True)
    spec = to_spec(pl)
    json.dumps(spec)                         # must be wire-able
    pl2 = from_spec(spec)
    assert chain_signature(pl) == chain_signature(pl2)
    assert pl2.check() == pl.check()


def test_round_trip_is_stable():
    spec = to_spec(standard_chain(n_det=16, n_angles=16))
    assert to_spec(from_spec(spec)) == spec


def test_from_spec_accepts_bare_plugin_list():
    spec = to_spec(standard_chain(n_det=16, n_angles=16))
    pl = from_spec(spec["plugins"])
    assert chain_signature(pl) == chain_signature(
        standard_chain(n_det=16, n_angles=16))


def test_unknown_plugin_rejected_loudly():
    with pytest.raises(WireError, match="unknown plugin 'warp_drive'"):
        from_spec({"plugins": [{"plugin": "warp_drive"}]})
    # the error names the registered alternatives
    with pytest.raises(WireError, match="synthetic_tomo_loader"):
        from_spec({"plugins": [{"plugin": "warp_drive"}]})


def test_unknown_param_rejected_loudly():
    spec = {"plugins": [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": 16, "warp": 9},
         "out_datasets": ["tomo"]}]}
    with pytest.raises(WireError, match=r"unknown params \['warp'\]"):
        from_spec(spec)


@pytest.mark.parametrize("spec", [
    42, "nope", {}, {"plugins": []}, {"plugins": [7]},
    {"plugins": [{"params": {}}]},
    {"version": 99, "plugins": [{"plugin": "fbp_recon"}]},
    {"plugins": [{"plugin": "fbp_recon", "params": ["not", "a", "dict"]}]},
    {"plugins": [{"plugin": "fbp_recon", "in_datasets": "tomo"}]},
])
def test_malformed_specs_rejected(spec):
    with pytest.raises(WireError):
        from_spec(spec)


def test_to_spec_rejects_unregistered_plugin():
    pl = ProcessList()
    pl.add(SyntheticTomoLoader, params={"n_det": 16, "n_angles": 16},
           out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("tomo",), out_datasets=("tomo",))
    with pytest.raises(WireError, match="not wire-registered"):
        to_spec(pl)


def test_register_plugin_conflict_rejected():
    class Impostor(SyntheticTomoLoader):
        name = "synthetic_tomo_loader"
    with pytest.raises(WireError, match="already registered"):
        register_plugin(Impostor)
    # re-registering the SAME class is a no-op
    register_plugin(SyntheticTomoLoader)
    assert registered_plugins()["synthetic_tomo_loader"] \
        is SyntheticTomoLoader


def test_structural_errors_still_caught_by_check():
    # wire-valid but structurally broken: no saver
    spec = {"plugins": [
        {"plugin": "synthetic_tomo_loader", "params": {"n_det": 16},
         "out_datasets": ["tomo"]}]}
    pl = from_spec(spec)                     # deserialises fine
    with pytest.raises(ProcessListError, match="saver"):
        pl.check()


def test_registry_spec_is_jsonable_introspection():
    reg = registry_spec()
    json.dumps(reg)
    loader = reg["synthetic_tomo_loader"]
    assert loader["params"]["seed"]["data_param"] is True
    assert loader["params"]["n_det"] == {"default": 64,
                                         "data_param": False}
    assert loader["n_in_datasets"] == 0
    recon = reg["fbp_recon"]
    assert recon["params"]["use_pallas"]["default"] is True
    assert recon["n_out_datasets"] == 1
