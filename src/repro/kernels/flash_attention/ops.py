"""Public attention entry point used by the model zoo.

Dispatch: Pallas flash kernel for prefill/train shapes on TPU (or
interpret mode when validating on CPU); pure-jnp reference otherwise.
The models call `attention(...)`; the switch is config-driven so the
dry-run can lower either implementation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import mha_chunked_ref, mha_ref

#: sequences at or above this length route to the chunked
#: online-softmax path (O(S·bq) memory) instead of materialised scores.
CHUNKED_THRESHOLD = 8192


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas",
                                             "interpret"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, use_pallas: bool = False,
              interpret: bool = True) -> jnp.ndarray:
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=interpret)
    if q.shape[2] >= CHUNKED_THRESHOLD and q.shape[2] == k.shape[2]:
        return mha_chunked_ref(q, k, v, causal=causal)
    return mha_ref(q, k, v, causal=causal)
