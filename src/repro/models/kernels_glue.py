"""Thin indirection so model code imports kernels from one place."""
from ..kernels.flash_attention.ops import attention as flash_attention

__all__ = ["flash_attention"]
