"""Unified model API: build_model(cfg) -> Model with init / loss /
forward / prefill / decode_step / init_cache, dispatching on family.

Batch conventions (all jnp arrays):
  dense/moe/ssm/hybrid : {tokens (B,S), labels (B,S)}
  vlm                  : {tokens (B,S_text), patches (B,S_patch,d),
                          labels (B,S_text+S_patch)}  (patches first)
  encdec               : {frames (B,T,d), tokens (B,S), labels (B,S)}

Labels < 0 are ignored (masked out of the CE mean).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import embed_tokens
from .sharding import get_rules
from .transformer import init_lm, lm_decode_step, lm_forward, lm_prefill
from .whisper import (init_whisper, whisper_decode_step, whisper_forward,
                      whisper_prefill)
from .xlstm_model import (init_xlstm, init_xlstm_cache, xlstm_decode_step,
                          xlstm_forward)
from .zamba import (init_zamba, init_zamba_cache, zamba_decode_step,
                    zamba_forward)

AUX_WEIGHT = 0.01


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], dict]
    forward: Callable[..., tuple[jnp.ndarray, jnp.ndarray]]
    loss: Callable[[dict, dict], jnp.ndarray]
    prefill: Callable[..., tuple[jnp.ndarray, Any]]
    decode_step: Callable[[dict, jnp.ndarray, Any],
                          tuple[jnp.ndarray, Any]]
    init_cache: Callable[..., Any]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over positions with label >= 0.  logits fp32 (B,S,V)."""
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


def _vlm_embeds(params, cfg: ModelConfig, tokens, patches):
    tok = embed_tokens(params["embed"], tokens, cfg.dtype)
    return jnp.concatenate([patches.astype(cfg.dtype), tok], axis=1)


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def forward(params, batch):
            if fam == "vlm" and "patches" in batch:
                embeds = _vlm_embeds(params, cfg, batch["tokens"],
                                     batch["patches"])
                return lm_forward(params, cfg, embeds=embeds)
            return lm_forward(params, cfg, tokens=batch["tokens"])

        def loss(params, batch):
            logits, aux = forward(params, batch)
            return cross_entropy(logits, batch["labels"]) + \
                AUX_WEIGHT * aux

        def prefill(params, batch, max_len):
            if fam == "vlm" and "patches" in batch:
                # the patch prefix is part of the prompt: prefill the
                # concatenated (patch, token) embeddings directly.
                embeds = _vlm_embeds(params, cfg, batch["tokens"],
                                     batch["patches"])
                from .transformer import lm_prefill_embeds
                return lm_prefill_embeds(params, cfg, embeds, max_len)
            return lm_prefill(params, cfg, batch["tokens"], max_len)

        def decode_step(params, token, cache):
            return lm_decode_step(params, cfg, token, cache)

        def init_cache(batch_size: int, max_len: int):
            from .attention import init_cache as ic
            kv = ic(cfg, batch_size, max_len)
            return {"k": kv.k, "v": kv.v, "length": kv.length}

        return Model(cfg, lambda key: init_lm(key, cfg), forward, loss,
                     prefill, decode_step, init_cache)

    if fam == "ssm":        # xLSTM
        def forward(params, batch):
            return xlstm_forward(params, cfg, tokens=batch["tokens"])

        def loss(params, batch):
            logits, _ = forward(params, batch)
            return cross_entropy(logits, batch["labels"])

        def prefill(params, batch, max_len):
            # recurrent prefill: run the full forward, then replay state
            # via decode for the last token is unnecessary — run forward
            # over the prompt in chunked mode and also return the state by
            # decoding the prompt sequentially is too slow; instead use
            # the chunked forward's final states (captured by decode loop
            # in serving). For the dry-run, prefill == forward.
            logits, _ = forward(params, batch)
            cache = init_xlstm_cache(cfg, batch["tokens"].shape[0])
            return logits[:, -1:, :], cache

        def decode_step(params, token, cache):
            return xlstm_decode_step(params, cfg, token, cache)

        def init_cache(batch_size: int, max_len: int):
            return init_xlstm_cache(cfg, batch_size)

        return Model(cfg, lambda key: init_xlstm(key, cfg), forward, loss,
                     prefill, decode_step, init_cache)

    if fam == "hybrid":     # Zamba2
        def forward(params, batch):
            return zamba_forward(params, cfg, tokens=batch["tokens"])

        def loss(params, batch):
            logits, _ = forward(params, batch)
            return cross_entropy(logits, batch["labels"])

        def prefill(params, batch, max_len):
            logits, _ = forward(params, batch)
            cache = init_zamba_cache(cfg, batch["tokens"].shape[0],
                                     max_len)
            return logits[:, -1:, :], cache

        def decode_step(params, token, cache):
            return zamba_decode_step(params, cfg, token, cache)

        def init_cache(batch_size: int, max_len: int):
            return init_zamba_cache(cfg, batch_size, max_len)

        return Model(cfg, lambda key: init_zamba(key, cfg), forward, loss,
                     prefill, decode_step, init_cache)

    if fam == "encdec":     # Whisper
        def forward(params, batch):
            return whisper_forward(params, cfg, frames=batch["frames"],
                                   tokens=batch["tokens"])

        def loss(params, batch):
            logits, _ = forward(params, batch)
            return cross_entropy(logits, batch["labels"])

        def prefill(params, batch, max_len):
            return whisper_prefill(params, cfg, batch["frames"],
                                   batch["tokens"], max_len)

        def decode_step(params, token, cache):
            return whisper_decode_step(params, cfg, token, cache)

        def init_cache(batch_size: int, max_len: int):
            t = cfg.max_frames or 1500
            rules = get_rules()

            def kv(s):
                return rules.constrain(
                    jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads,
                               s, cfg.hd), cfg.dtype),
                    "layers", "batch", "kv_heads", "kv_seq", None)

            return {"k": kv(max_len), "v": kv(max_len), "xk": kv(t),
                    "xv": kv(t), "length": jnp.zeros((), jnp.int32)}

        return Model(cfg, lambda key: init_whisper(key, cfg), forward,
                     loss, prefill, decode_step, init_cache)

    raise ValueError(f"unknown family {fam!r}")
