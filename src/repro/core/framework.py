"""The core framework — runs and controls the processing chain
(paper §III.D, Figs 5–7).

Phases:
  1. **check**  — the plugin-list check (delegated to ProcessList.check),
  2. **setup**  — loaders create lazy datasets; each processing plugin is
     "plugged in": its PluginData views are attached, its ``setup``
     describes the out_datasets, and the framework completes them by
     attaching backing storage via the transport (Fig 5),
  3. **main**   — per plugin: pre_process → frame loop (via transport) →
     post_process (MPI-barrier semantics = blocking jit), then the
     out_dataset *replaces* any in_dataset of the same name (Fig 6 (i)),
  4. **finalise** — savers persist surviving datasets; a NeXus-style JSON
     manifest links every intermediate file (paper §III.A).

Fusion (beyond paper): consecutive 1-in/1-out plugins that share a
driver are compiled as ONE jit on the sharded transport, so the
pattern-transition collective is scheduled by XLA inside a single
program instead of a host round-trip between plugins.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

from .dataset import DataSet
from .plugin import BaseLoader, BasePlugin, BaseSaver, PluginData
from .process_list import ProcessList
from .profiler import Profiler
from .transport import (ChunkedFileTransport, InMemoryTransport,
                        ShardedTransport, Transport)


class PluginRunner:
    def __init__(self, process_list: ProcessList,
                 transport: Transport | None = None,
                 profiler: Profiler | None = None,
                 fuse: bool = False,
                 output_dir: str | None = None):
        self.process_list = process_list
        self.transport = transport or InMemoryTransport()
        self.profiler = profiler or Profiler()
        self.fuse = fuse and isinstance(self.transport, ShardedTransport)
        self.output_dir = output_dir
        #: name -> DataSet currently available for processing
        self.datasets: dict[str, DataSet] = {}
        #: every dataset ever produced (for the NeXus-style manifest)
        self.lineage: list[DataSet] = []

    # ------------------------------------------------------------------
    def run(self) -> dict[str, DataSet]:
        self.process_list.check()
        loaders, processors, savers = self._split()
        self._setup_phase(loaders, processors, savers)
        self._main_phase(processors)
        self._finalise(savers)
        return self.datasets

    # ------------------------------------------------------------------
    def _split(self):
        loaders, procs, savers = [], [], []
        for entry in self.process_list:
            plugin = entry.instantiate()
            if isinstance(plugin, BaseLoader):
                loaders.append(plugin)
            elif isinstance(plugin, BaseSaver):
                savers.append(plugin)
            else:
                procs.append(plugin)
        return loaders, procs, savers

    def _setup_phase(self, loaders, processors, savers):
        # Loaders first (lazy — they create dataset descriptions).
        for ld in loaders:
            with self.profiler.timer(ld.name, "setup"):
                for ds in ld.load():
                    if not ld.out_dataset_names:
                        ld.out_dataset_names = []
                    self.datasets[ds.name] = ds
                    self.lineage.append(ds)
        # Savers are plugged in directly after loaders (paper §III.F.2)
        # and retain their link until finalise.
        # Processing plugins: attach PluginData, call setup, register outs.
        self._planned: list[tuple[BasePlugin, list[DataSet]]] = []
        sym: dict[str, DataSet] = dict(self.datasets)
        for i, p in enumerate(processors):
            ins = [sym[n] for n in p.in_dataset_names]
            p.in_data = [PluginData(d) for d in ins]
            p.out_data = []          # filled after setup describes them
            with self.profiler.timer(p.name, "setup"):
                outs = p.setup(ins)
            if len(outs) != len(p.out_dataset_names):
                raise ValueError(
                    f"plugin {p.name}: setup returned {len(outs)} datasets, "
                    f"process list names {p.out_dataset_names}")
            for ds, name in zip(outs, p.out_dataset_names):
                ds.name = name
                ds.produced_by = f"p{i + 1}.{p.name}"
                p.out_data.append(PluginData(ds))
            # propagate pattern/frames choice made in setup to out views
            for pd in p.out_data:
                pd.pattern_name = (p.out_pattern_name or pd.pattern_name
                                   or p.in_data[0].pattern_name)
                pd.n_frames = p.in_data[0].n_frames
                if pd.pattern_name not in pd.dataset.patterns and \
                        pd.pattern_name in ins[0].patterns and \
                        pd.dataset.shape == ins[0].shape:
                    pd.dataset.patterns[pd.pattern_name] = \
                        ins[0].patterns[pd.pattern_name]
            # transport attaches backing (file/None) using now/next patterns
            nxt = processors[i + 1] if i + 1 < len(processors) else None
            for pd in p.out_data:
                now_pat = pd.dataset.patterns.get(pd.pattern_name)
                next_pat = None
                if nxt is not None and pd.dataset.name in nxt.in_dataset_names:
                    # the next plugin's requested pattern, if resolvable
                    cand = nxt.__class__.__dict__.get("pattern_name")
                    if cand and cand in pd.dataset.patterns:
                        next_pat = pd.dataset.patterns[cand]
                if now_pat is not None:
                    self.transport.allocate(pd.dataset, now_pat, next_pat)
                self.lineage.append(pd.dataset)
            self._planned.append((p, outs))
            for ds in outs:
                sym[ds.name] = ds

    def _main_phase(self, processors):
        groups = self._fusion_groups(processors) if self.fuse else \
            [[p] for p in processors]
        for group in groups:
            if len(group) == 1:
                self._run_one(group[0])
            else:
                self._run_group(group)

    def _run_one(self, p: BasePlugin):
        # re-bind in_data to the *current* dataset registry (replacement
        # semantics may have swapped same-named datasets).
        for pd in p.in_data:
            pd.dataset = self.datasets[pd.dataset.name]
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        with self.profiler.timer(p.name, "pre", devices):
            p.pre_process()
        with self.profiler.timer(p.name, "process", devices):
            self.transport.run_plugin(p)
        with self.profiler.timer(p.name, "post", devices):
            p.post_process()
        self._replace(p)

    def _run_group(self, group):
        for p in group:
            for pd in p.in_data:
                if pd.dataset.name in self.datasets:
                    pd.dataset = self.datasets[pd.dataset.name]
            p.pre_process()
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        label = "+".join(p.name for p in group)
        with self.profiler.timer(label, "process", devices, fused=True):
            self.transport.run_fused(group)
        for p in group:
            p.post_process()
            self._replace(p)

    def _replace(self, p: BasePlugin):
        """out_dataset replaces in_dataset of the same name (Fig 6 (i))."""
        for pd in p.out_data:
            self.datasets[pd.dataset.name] = pd.dataset
        consumed = {pd.dataset.name for pd in p.in_data}
        produced = {pd.dataset.name for pd in p.out_data}
        # close in_datasets that were replaced (paper removes them)
        for name in consumed & produced:
            pass  # the registry overwrite above is the replacement

    def _fusion_groups(self, processors):
        """Group consecutive linear 1-in/1-out jax-traceable plugins."""
        groups: list[list[BasePlugin]] = []
        cur: list[BasePlugin] = []
        for p in processors:
            linear = (len(p.in_dataset_names) == 1
                      and len(p.out_dataset_names) == 1
                      and getattr(p, "fusable", True))
            chains = bool(cur) and \
                cur[-1].out_dataset_names[0] == p.in_dataset_names[0] and \
                cur[-1].driver == p.driver
            if linear and (not cur or chains):
                cur.append(p)
            else:
                if cur:
                    groups.append(cur)
                cur = [p] if linear else []
                if not linear:
                    groups.append([p])
        if cur:
            groups.append(cur)
        return groups

    # ------------------------------------------------------------------
    def _finalise(self, savers):
        for sv in savers:
            for name in sv.in_dataset_names:
                if name in self.datasets:
                    with self.profiler.timer(sv.name, "io"):
                        sv.save(self.datasets[name])
        if self.output_dir:
            os.makedirs(self.output_dir, exist_ok=True)
            manifest = {
                "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "datasets": [
                    {"name": d.name, "shape": list(d.shape),
                     "dtype": str(d.dtype), "axis_labels": list(d.axis_labels),
                     "produced_by": d.produced_by,
                     "patterns": sorted(d.patterns),
                     "file": getattr(getattr(d, "backing", None), "path", None)}
                    for d in self.lineage],
            }
            with open(os.path.join(self.output_dir, "savu_manifest.nxs.json"),
                      "w") as fh:
                json.dump(manifest, fh, indent=2)
        self.transport.close()


# convenience ----------------------------------------------------------
def run_process_list(process_list: ProcessList, data: dict[str, Any],
                     transport: Transport | None = None, **kw
                     ) -> dict[str, DataSet]:
    """One-shot helper used by examples/tests: ``data`` pre-populates
    loader-created datasets whose loaders are 'inline' loaders."""
    runner = PluginRunner(process_list, transport, **kw)
    out = runner.run()
    return out
