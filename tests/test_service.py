"""Service layer: queue ordering/priorities + admission control,
compile-cache hits on resubmitted process lists, gang batching, and
kill-then-resume recovering at the correct plugin."""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (BaseFilter, BaseLoader, BaseSaver, DataSet,
                        InMemoryTransport, LambdaFilter, PluginRunner,
                        ProcessList, ShardedTransport)
from repro.service import (CheckpointStore, CompileCache, JobQueue,
                           JobState, PipelineScheduler, QueueFull,
                           chain_signature)
from repro.tomo import standard_chain


# ---------------------------------------------------------------- helpers
class ArrayLoader(BaseLoader):
    name = "array_loader"
    parameters = {"array": None, "seed": None}
    data_params = ("array", "seed")

    def load(self):
        a = self.params["array"]
        d = DataSet(self.out_dataset_names[0], a.shape, a.dtype,
                    ("theta", "y", "x"), backing=a)
        d.add_pattern("PROJECTION", core=("y", "x"), slice_=("theta",))
        return [d]


class NullSaver(BaseSaver):
    name = "null_saver"

    def save(self, ds):
        ds.metadata["saved"] = True


class TraceFilter(BaseFilter):
    """Records every pre_process (one per executed plugin step)."""
    name = "trace_filter"
    parameters = {"add": 0.0, "tag": ""}
    executed: list = []         # class-level log, reset per test

    def pre_process(self):
        TraceFilter.executed.append(self.params["tag"])

    def process_frames(self, frames):
        return frames[0] + self.params["add"]


def _trace_chain(a, n_filters=4):
    pl = ProcessList()
    pl.add(ArrayLoader, params={"array": a}, out_datasets=("d",))
    for i in range(n_filters):
        pl.add(TraceFilter, params={"add": float(i + 1), "tag": f"f{i}"},
               in_datasets=("d",), out_datasets=("d",))
    pl.add(NullSaver, in_datasets=("d",))
    return pl


def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _double(b):
    return b * 2.0


def _inc(b):
    return b + 1.0


def _lambda_chain(a):
    pl = ProcessList()
    pl.add(ArrayLoader, params={"array": a}, out_datasets=("d",))
    pl.add(LambdaFilter, params={"fn": _double, "pattern": "PROJECTION"},
           in_datasets=("d",), out_datasets=("d",))
    pl.add(LambdaFilter, params={"fn": _inc, "pattern": "PROJECTION"},
           in_datasets=("d",), out_datasets=("d",))
    pl.add(NullSaver, in_datasets=("d",))
    return pl


@pytest.fixture
def data(rng):
    return rng.normal(size=(4, 6, 5)).astype(np.float32)


# ---------------------------------------------------------------- queue
def test_queue_priority_then_fifo(data):
    q = JobQueue()
    lo1 = q.submit(_trace_chain(data), priority=0)
    hi = q.submit(_trace_chain(data), priority=5)
    lo2 = q.submit(_trace_chain(data), priority=0)
    assert q.get(0).job_id == hi.job_id
    assert q.get(0).job_id == lo1.job_id     # FIFO within a priority
    assert q.get(0).job_id == lo2.job_id
    assert q.get(timeout=0.01) is None


def test_admission_control_backpressure(data):
    q = JobQueue(max_pending=2)
    j1 = q.submit(_trace_chain(data))
    q.submit(_trace_chain(data))
    with pytest.raises(QueueFull):
        q.submit(_trace_chain(data))
    with pytest.raises(QueueFull):
        q.submit(_trace_chain(data), block=True, timeout=0.05)
    # capacity frees when a job reaches a terminal state
    def finish():
        time.sleep(0.05)
        j1.state = JobState.DONE
        q.notify_terminal()
    t = threading.Thread(target=finish)
    t.start()
    j3 = q.submit(_trace_chain(data), block=True, timeout=5.0)
    t.join()
    assert j3.state is JobState.QUEUED


def test_cancel_before_dispatch(data):
    q = JobQueue()
    a = q.submit(_trace_chain(data))
    b = q.submit(_trace_chain(data))
    assert q.cancel(a.job_id)
    assert q.get(0).job_id == b.job_id
    assert q.get(timeout=0.01) is None
    assert a.state is JobState.CANCELLED
    assert not q.cancel(b.job_id)            # already dispatched


def test_chain_signature_ignores_data_params():
    s0 = chain_signature(standard_chain(n_det=16, n_angles=16, seed=0))
    s1 = chain_signature(standard_chain(n_det=16, n_angles=16, seed=7))
    assert s0 == s1                          # same pipeline, new dataset
    s2 = chain_signature(standard_chain(n_det=16, n_angles=16, ring=False))
    assert s0 != s2                          # different pipeline


def test_get_batch_members_join_in_dispatch_order(data):
    """Gang members must be picked in (-priority, seq) order, not raw
    heap-array order: a truncated gang takes the jobs whose turn it is."""
    q = JobQueue()
    head = q.submit(_trace_chain(data), priority=9)
    members = [q.submit(_trace_chain(data), priority=0) for _ in range(4)]
    batch = q.get_batch(max_jobs=3, timeout=0)
    assert [j.job_id for j in batch] == \
        [head.job_id, members[0].job_id, members[1].job_id]
    # the passed-over jobs stay queued, FIFO intact
    assert q.get(0).job_id == members[2].job_id
    assert q.get(0).job_id == members[3].job_id


def test_get_batch_prefers_higher_priority_members(data):
    q = JobQueue()
    head = q.submit(_trace_chain(data), priority=9)
    lo = q.submit(_trace_chain(data), priority=0)
    hi = q.submit(_trace_chain(data), priority=5)
    batch = q.get_batch(max_jobs=2, timeout=0)
    assert [j.job_id for j in batch] == [head.job_id, hi.job_id]
    assert q.get(0).job_id == lo.job_id


def test_get_batch_groups_identical_chains(data, rng):
    other = rng.normal(size=(4, 6, 5)).astype(np.float32)
    q = JobQueue()
    a = q.submit(_trace_chain(data))
    b = q.submit(_trace_chain(other))        # same chain, other data
    c = q.submit(_trace_chain(data, n_filters=2))   # different chain
    batch = q.get_batch(max_jobs=4, timeout=0)
    assert {j.job_id for j in batch} == {a.job_id, b.job_id}
    assert q.get(0).job_id == c.job_id


def test_cancel_running_job_is_rejected(data):
    """Once dispatched (checking/running) a job is uncancellable — the
    worker owns it; cancel must refuse without corrupting state."""
    q = JobQueue()
    job = q.submit(_trace_chain(data))
    assert q.get(0).job_id == job.job_id     # dispatched: CHECKING
    assert not q.cancel(job.job_id)
    job.state = JobState.RUNNING
    assert not q.cancel(job.job_id)
    assert job.state is JobState.RUNNING     # untouched
    job.state = JobState.DONE
    assert not q.cancel(job.job_id)          # terminal: still rejected


def test_cancel_race_with_dispatch(data):
    """Exactly one of {dispatcher, canceller} may win a queued job;
    the loser must observe a consistent refusal."""
    for _ in range(25):
        q = JobQueue()
        job = q.submit(_trace_chain(data))
        results = {}
        barrier = threading.Barrier(2)

        def dispatch():
            barrier.wait()
            results["got"] = q.get(timeout=0.2)

        def cancel():
            barrier.wait()
            results["cancelled"] = q.cancel(job.job_id)

        ts = [threading.Thread(target=dispatch),
              threading.Thread(target=cancel)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        if results["cancelled"]:
            assert results["got"] is None
            assert job.state is JobState.CANCELLED
        else:
            assert results["got"] is not None
            assert job.state is JobState.CHECKING


def test_cancelled_jobs_are_pruned(data):
    q = JobQueue(max_history=1)
    stale = [q.submit(_trace_chain(data)) for _ in range(3)]
    for j in stale:
        assert q.cancel(j.job_id)
    q.submit(_trace_chain(data))             # submit triggers pruning
    ids = {s["job_id"] for s in q.snapshot()}
    assert stale[0].job_id not in ids and stale[1].job_id not in ids
    assert stale[2].job_id in ids            # newest terminal retained


def test_cancel_frees_admission_capacity(data):
    q = JobQueue(max_pending=1)
    j1 = q.submit(_trace_chain(data))
    def free():
        time.sleep(0.05)
        q.cancel(j1.job_id)
    t = threading.Thread(target=free)
    t.start()
    j2 = q.submit(_trace_chain(data), block=True, timeout=5.0)
    t.join()
    assert j2.state is JobState.QUEUED


def test_wait_all_returns_when_last_job_cancelled(data):
    """wait_all must not hang when the final non-terminal job is
    cancelled rather than run (no scheduler attached at all)."""
    q = JobQueue()
    jobs = [q.submit(_trace_chain(data)) for _ in range(2)]
    assert not q.wait_all(timeout=0.05)      # nothing ran yet
    for j in jobs:
        assert q.cancel(j.job_id)
    assert q.wait_all(timeout=5.0)


def test_wait_all_with_scheduler_and_cancelled_tail(data):
    """Cancel the tail job while a 1-worker scheduler drains the head:
    drain() completes, the cancelled job never executes."""
    TraceFilter.executed = []
    q = JobQueue()
    head = q.submit(_trace_chain(data))
    tail = q.submit(_trace_chain(data))
    assert q.cancel(tail.job_id)
    sched = PipelineScheduler(q, n_workers=1).start()
    assert sched.drain(timeout=60)
    sched.shutdown()
    assert head.state is JobState.DONE
    assert tail.state is JobState.CANCELLED
    assert len(TraceFilter.executed) == 4    # only the head's 4 filters


# ---------------------------------------------------------- stepping/resume
def test_stepping_equals_run(data):
    r1 = PluginRunner(_trace_chain(data), InMemoryTransport())
    out1 = r1.run()
    r2 = PluginRunner(_trace_chain(data), InMemoryTransport())
    r2.prepare()
    assert r2.n_steps == 4
    steps = 0
    while r2.step():
        steps += 1
    r2.finalise()
    assert steps == 4
    np.testing.assert_allclose(np.asarray(out1["d"].materialise()),
                               np.asarray(r2.datasets["d"].materialise()))


def test_kill_then_resume_recovers_at_correct_plugin(tmp_path, data):
    store = CheckpointStore(str(tmp_path))
    ref = PluginRunner(_trace_chain(data), InMemoryTransport()).run()

    # run two of four plugins, checkpoint after each step, then "die"
    TraceFilter.executed = []
    r = PluginRunner(_trace_chain(data), InMemoryTransport())
    r.prepare()
    for _ in range(2):
        r.step()
        store.save("j1", r)
    assert TraceFilter.executed == ["f0", "f1"]

    # fresh runner resumes from the store at plugin 2
    TraceFilter.executed = []
    r2 = PluginRunner(_trace_chain(data), InMemoryTransport())
    resumed = store.restore("j1", r2)
    assert resumed == 2
    while r2.step():
        pass
    r2.finalise()
    assert TraceFilter.executed == ["f2", "f3"]     # f0/f1 NOT re-run
    np.testing.assert_allclose(np.asarray(r2.datasets["d"].materialise()),
                               np.asarray(ref["d"].materialise()))


def test_restore_rejects_different_chain(tmp_path, data):
    store = CheckpointStore(str(tmp_path))
    r = PluginRunner(_trace_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    store.save("j1", r)
    other = PluginRunner(_trace_chain(data, n_filters=2),
                         InMemoryTransport())
    assert store.restore("j1", other) == 0          # signature mismatch


def test_scheduler_resumes_resubmitted_job(tmp_path, data):
    store = CheckpointStore(str(tmp_path))
    ref = PluginRunner(_trace_chain(data), InMemoryTransport()).run()

    # simulate a killed job: partial run left a checkpoint behind
    r = PluginRunner(_trace_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    store.save("jobX", r)

    TraceFilter.executed = []
    q = JobQueue()
    sched = PipelineScheduler(q, n_workers=1, checkpoints=store).start()
    job = q.submit(_trace_chain(data), job_id="jobX")
    assert sched.drain(timeout=60)
    sched.shutdown()
    assert job.state is JobState.DONE, job.snapshot()
    assert job.resumed_from == 1
    assert TraceFilter.executed == ["f1", "f2", "f3"]
    got = job.runner.transport.read(job.runner.datasets["d"])
    np.testing.assert_allclose(got, np.asarray(ref["d"].materialise()))


def test_gang_path_resumes_checkpointed_job(tmp_path, data, rng):
    """The gang path must set resumed_from too: a checkpointed job that
    lands in a gang is restored and driven solo (a gang would force it
    back into lockstep from step 0), while its gang-mates run normally."""
    store = CheckpointStore(str(tmp_path))
    ref = PluginRunner(_trace_chain(data), InMemoryTransport()).run()
    # simulate a killed job: partial run left a checkpoint behind
    r = PluginRunner(_trace_chain(data), InMemoryTransport())
    r.prepare()
    r.step()
    store.save("jobX", r)

    other = rng.normal(size=data.shape).astype(np.float32)
    TraceFilter.executed = []
    q = JobQueue()
    sched = PipelineScheduler(q, n_workers=1, checkpoints=store,
                              batch_identical=True, batch_max=4)
    jx = q.submit(_trace_chain(data), job_id="jobX")
    jy = q.submit(_trace_chain(other), job_id="jobY")
    sched.start()
    assert sched.drain(timeout=60)
    sched.shutdown()
    assert jx.state is JobState.DONE, jx.snapshot()
    assert jy.state is JobState.DONE, jy.snapshot()
    assert jx.resumed_from == 1
    assert jy.resumed_from == 0
    assert TraceFilter.executed.count("f0") == 1     # only jobY ran f0
    got = jx.runner.transport.read(jx.runner.datasets["d"])
    np.testing.assert_allclose(got, np.asarray(ref["d"].materialise()))


# ---------------------------------------------------------- scheduler
def test_scheduler_concurrent_jobs_match_serial(rng):
    arrays = [rng.normal(size=(4, 5, 5)).astype(np.float32)
              for _ in range(3)]
    q = JobQueue()
    sched = PipelineScheduler(q, n_workers=2).start()
    jobs = [q.submit(_trace_chain(a)) for a in arrays]
    assert sched.drain(timeout=60)
    sched.shutdown()
    for a, j in zip(arrays, jobs):
        assert j.state is JobState.DONE, j.snapshot()
        ref = PluginRunner(_trace_chain(a), InMemoryTransport()).run()
        got = j.runner.transport.read(j.runner.datasets["d"])
        np.testing.assert_allclose(got, np.asarray(ref["d"].materialise()))


def test_scheduler_marks_failed_job(data):
    pl = ProcessList()
    pl.add(ArrayLoader, params={"array": data}, out_datasets=("d",))
    pl.add(LambdaFilter,
           params={"fn": lambda b: (_ for _ in ()).throw(RuntimeError("boom")),
                   "pattern": "PROJECTION"},
           in_datasets=("d",), out_datasets=("d",))
    pl.add(NullSaver, in_datasets=("d",))
    q = JobQueue()
    sched = PipelineScheduler(q, n_workers=1).start()
    job = q.submit(pl)
    assert sched.drain(timeout=60)
    sched.shutdown()
    assert job.state is JobState.FAILED
    assert "boom" in job.error
    assert "running" not in job.status


# ------------------------------------------------------- compile cache
def test_compile_cache_hit_on_resubmitted_process_list(data, rng):
    cache = CompileCache()
    mesh = _mesh1()

    def run_once(a):
        tr = ShardedTransport(mesh, compile_cache=cache)
        runner = PluginRunner(_lambda_chain(a), tr)
        out = runner.run()
        return tr.read(out["d"])

    got1 = run_once(data)
    after_first = cache.stats()
    assert after_first["misses"] == 2 and after_first["hits"] == 0

    other = rng.normal(size=data.shape).astype(np.float32)
    got2 = run_once(other)                   # identical list, new dataset
    after_second = cache.stats()
    assert after_second["misses"] == 2       # zero new compiles
    assert after_second["hits"] == 2
    np.testing.assert_allclose(got1, data * 2 + 1, rtol=1e-5)
    np.testing.assert_allclose(got2, other * 2 + 1, rtol=1e-5)


def test_compile_cache_single_build_under_race():
    cache = CompileCache()
    builds = []

    def builder():
        time.sleep(0.05)
        builds.append(1)
        return "artifact"

    results = []
    threads = [threading.Thread(
        target=lambda: results.append(cache.get_or_build("k", builder)))
        for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results == ["artifact"] * 4
    assert len(builds) == 1                  # losers waited, not rebuilt


def test_jit_constants_flow_as_arguments(rng):
    """Two plugin instances with different setup constants share one
    compiled function and still get THEIR OWN constants applied."""
    class BiasFilter(BaseFilter):
        name = "bias_filter"
        pattern_name = "PROJECTION"
        parameters = {"which": 0}
        data_params = ("which",)

        def setup(self, in_datasets):
            (din,) = in_datasets
            self._bias = jnp.full(din.shape[1:], float(self.params["which"]))
            dout = din.like(self.out_dataset_names[0])
            self.chunk_frames(self.pattern_name, 1)
            return [dout]

        def process_frames(self, frames):
            return frames[0] + self._bias[None]

    cache = CompileCache()
    mesh = _mesh1()
    a = rng.normal(size=(3, 4, 4)).astype(np.float32)

    def chain(which):
        pl = ProcessList()
        pl.add(ArrayLoader, params={"array": a}, out_datasets=("d",))
        pl.add(BiasFilter, params={"which": which},
               in_datasets=("d",), out_datasets=("d",))
        pl.add(NullSaver, in_datasets=("d",))
        return pl

    tr = ShardedTransport(mesh, compile_cache=cache)
    out5 = tr.read(PluginRunner(chain(5), tr).run()["d"])
    tr2 = ShardedTransport(mesh, compile_cache=cache)
    out9 = tr2.read(PluginRunner(chain(9), tr2).run()["d"])
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 1
    np.testing.assert_allclose(out5, a + 5, rtol=1e-6)
    np.testing.assert_allclose(out9, a + 9, rtol=1e-6)   # not stale 5!


def test_max_history_evicts_terminal_jobs(data):
    q = JobQueue(max_history=2)
    sched = PipelineScheduler(q, n_workers=1).start()
    jobs = [q.submit(_trace_chain(data)) for _ in range(4)]
    assert sched.drain(timeout=60)
    # a new submission triggers pruning of all but the 2 newest terminal
    q.submit(_trace_chain(data))
    assert sched.drain(timeout=60)
    sched.shutdown()
    assert all(j.state is JobState.DONE for j in jobs)
    ids = {s["job_id"] for s in q.snapshot()}
    assert jobs[0].job_id not in ids and jobs[1].job_id not in ids
    assert jobs[0].runner is None            # memory released


# ------------------------------------------------------- gang batching
def test_gang_shape_mismatch_falls_back_to_solo(rng):
    """Same chain signature (array is a data_param) but different shapes:
    the batched call is impossible; the gang must fall back, not fail."""
    a = rng.normal(size=(4, 5, 5)).astype(np.float32)
    b = rng.normal(size=(4, 6, 6)).astype(np.float32)
    cache = CompileCache()
    mesh = _mesh1()
    q = JobQueue()
    sched = PipelineScheduler(
        q, n_workers=1, batch_identical=True, batch_max=4,
        transport_factory=lambda job: ShardedTransport(
            mesh, donate=False, compile_cache=cache))
    jobs = [q.submit(_lambda_chain(x)) for x in (a, b)]
    sched.start()
    assert sched.drain(timeout=120)
    sched.shutdown()
    for x, j in zip((a, b), jobs):
        assert j.state is JobState.DONE, j.snapshot()
        got = j.runner.transport.read(j.runner.datasets["d"])
        np.testing.assert_allclose(got, x * 2 + 1, rtol=1e-5)


def test_gang_batch_matches_serial(rng):
    arrays = [rng.normal(size=(4, 5, 5)).astype(np.float32)
              for _ in range(3)]
    cache = CompileCache()
    mesh = _mesh1()
    q = JobQueue()
    sched = PipelineScheduler(
        q, n_workers=1, batch_identical=True, batch_max=4,
        compile_cache=cache,
        transport_factory=lambda job: ShardedTransport(
            mesh, donate=False, compile_cache=cache))
    jobs = [q.submit(_lambda_chain(a)) for a in arrays]
    sched.start()
    assert sched.drain(timeout=120)
    sched.shutdown()
    assert sched.gangs_run == 1
    for a, j in zip(arrays, jobs):
        assert j.state is JobState.DONE, j.snapshot()
        got = j.runner.transport.read(j.runner.datasets["d"])
        np.testing.assert_allclose(got, a * 2 + 1, rtol=1e-5)
