"""Pure-jnp oracle for parallel-beam filtered backprojection."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def backproject_ref(sino: jnp.ndarray, angles: jnp.ndarray, out_size: int,
                    centre: float | None = None) -> jnp.ndarray:
    """(n_angles, n_det) filtered sinogram -> (out_size, out_size) image.

    out(y, x) = (π / n_angles) · Σ_θ lerp(sino_zeropad[θ], t),
    t = (x - cx)·cosθ + (y - cy)·sinθ + centre.

    Boundary convention: the detector row is zero-padded, so rays whose
    t falls in (-1, 0) or (n_det-1, n_det) taper linearly to zero and
    rays further outside contribute exactly 0 — identical to the
    hat-function-matmul semantics of the Pallas kernel.
    """
    n_angles, n_det = sino.shape
    if centre is None:
        centre = (n_det - 1) / 2.0
    c = (out_size - 1) / 2.0
    xs = jnp.arange(out_size, dtype=sino.dtype) - c
    ys = jnp.arange(out_size, dtype=sino.dtype) - c

    def one_angle(row, theta):
        row_p = jnp.pad(row, (1, 1))
        ct, st = jnp.cos(theta), jnp.sin(theta)
        t = xs[None, :] * ct + ys[:, None] * st + centre
        tp = jnp.clip(t + 1.0, 0.0, n_det + 1.0)  # into padded coords
        t0 = jnp.floor(tp)
        frac = tp - t0
        i0 = jnp.clip(t0.astype(jnp.int32), 0, n_det)
        i1 = jnp.clip(i0 + 1, 0, n_det + 1)
        val = row_p[i0] * (1 - frac) + row_p[i1] * frac
        inside = (t > -1.0) & (t < n_det)
        return jnp.where(inside, val, 0.0)

    acc = jax.vmap(one_angle)(sino, angles.astype(sino.dtype))
    return jnp.sum(acc, axis=0) * (jnp.pi / n_angles)
