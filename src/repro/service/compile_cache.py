"""Process-level compiled-plugin cache, with a persistent disk tier.

The paper's headline workload is "the same pipeline over many datasets":
at a facility, hundreds of scans a day run one tuned process list.  On
the jax substrate the expensive part of a repeat submission is the
``jax.jit`` retrace+compile of every plugin, so the service keeps ONE
cache for the whole process, shared by every job's
:class:`~repro.core.transport.ShardedTransport`.

Keys come from ``ShardedTransport._plugin_key``: (plugin static identity,
in/out dataset shapes/dtypes/patterns, constants structure, driver, mesh,
donation).  Values are compiled callables whose setup-derived constants
(dark/flat fields, filter banks...) are jit *arguments*, so a hit is
valid across jobs even when calibration data differs.

Beyond the in-memory tier (valid for one process), entries whose builder
produces an AOT-compiled executable can be **persisted**: serialized via
``jax.experimental.serialize_executable`` into an :class:`ExecutableStore`
keyed by :func:`executable_signature` — a digest of the cache key PLUS
the jax/jaxlib version and backend/device fingerprint, so an entry built
under a different toolchain can never be silently loaded (it simply has a
different signature, and its header is re-verified on load anyway).  A
fresh worker process pointed at the same store — or prefetching from the
broker's spool (``GET /executables/{sig}``) — deserializes hot programs
in milliseconds instead of recompiling them: the "kill the retrace tax"
warm pool (docs/worker-protocol.md).

Thread-safety: one build per key even under concurrent misses — losers
of the build race block on the winner's per-key event rather than
compiling twice.  :meth:`CompileCache.clear` bumps a generation counter
so a build that was already in flight when the clear happened cannot
re-insert its (now unwanted) entry afterwards.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import threading
import time
from typing import Any, Callable

from ..obs.trace import current_trace

#: on-disk payload framing: magic + one JSON header line + pickle body
_MAGIC = b"SAVUEXE1\n"

_HEX = frozenset("0123456789abcdef")


class StaleExecutable(Exception):
    """A persisted executable payload cannot be loaded into THIS process:
    corrupted/truncated bytes, a header written by a different jax/jaxlib
    version or backend, or a signature mismatch.  Always recoverable —
    the caller falls back to a fresh compile."""


_fingerprint_cache: dict[str, Any] | None = None


def env_fingerprint() -> dict[str, Any]:
    """The toolchain+hardware identity a serialized executable is only
    valid under: jax/jaxlib versions, backend, and device kinds/count.
    Baked into every payload header AND into
    :func:`executable_signature`, so stale entries are rejected twice
    over (different signature, and a header mismatch on load) rather
    than ever being silently loaded."""
    global _fingerprint_cache
    if _fingerprint_cache is None:
        import jax
        try:
            import jaxlib
            jaxlib_ver = getattr(jaxlib, "__version__", "unknown")
        except ImportError:              # pragma: no cover
            jaxlib_ver = "none"
        devs = jax.devices()
        _fingerprint_cache = {
            "fmt": 1,
            "jax": jax.__version__,
            "jaxlib": jaxlib_ver,
            "backend": jax.default_backend(),
            "devices": sorted({d.device_kind for d in devs}),
            "n_devices": len(devs),
        }
    return _fingerprint_cache


def executable_signature(key: Any) -> str:
    """Stable hex digest naming one compiled program across processes:
    sha256 over the cache key's repr (plugin identity, shapes, mesh,
    donation — all stable-repr tuples) salted with
    :func:`env_fingerprint`.  This is the ``{sig}`` in
    ``GET/PUT /executables/{sig}``."""
    fp = json.dumps(env_fingerprint(), sort_keys=True)
    return hashlib.sha256(f"{fp}|{key!r}".encode()).hexdigest()


def serialize_payload(compiled: Any, sig: str) -> bytes:
    """Frame an AOT-compiled executable for disk/wire: magic + JSON
    header (signature + env fingerprint) + pickled
    ``jax.experimental.serialize_executable`` triple.  Raises whatever
    ``serialize`` raises for executables jax cannot serialize."""
    from jax.experimental import serialize_executable as se
    ser, in_tree, out_tree = se.serialize(compiled)
    header = json.dumps({"sig": sig, "fingerprint": env_fingerprint()},
                        sort_keys=True).encode()
    return _MAGIC + header + b"\n" + pickle.dumps((ser, in_tree, out_tree))


def deserialize_payload(payload: bytes, sig: str | None = None) -> Any:
    """Load a framed payload back into a runnable executable.

    Every failure mode — bad magic, truncated bytes, unparseable
    header, a fingerprint from another jax version/backend, a signature
    mismatch, an undeserializable body — raises
    :class:`StaleExecutable`; nothing is ever silently loaded wrong.
    """
    if not payload.startswith(_MAGIC):
        raise StaleExecutable("bad magic (not a serialized executable)")
    try:
        nl = payload.index(b"\n", len(_MAGIC))
        header = json.loads(payload[len(_MAGIC):nl])
    except (ValueError, UnicodeDecodeError) as e:
        raise StaleExecutable(f"unparseable header: {e}") from None
    if not isinstance(header, dict):
        raise StaleExecutable("header is not an object")
    if header.get("fingerprint") != env_fingerprint():
        raise StaleExecutable(
            f"toolchain mismatch: payload built under "
            f"{header.get('fingerprint')!r}, this process is "
            f"{env_fingerprint()!r}")
    if sig is not None and header.get("sig") != sig:
        raise StaleExecutable(
            f"signature mismatch: header says {header.get('sig')!r}")
    try:
        from jax.experimental import serialize_executable as se
        ser, in_tree, out_tree = pickle.loads(payload[nl + 1:])
        return se.deserialize_and_load(ser, in_tree, out_tree)
    except StaleExecutable:
        raise
    except Exception as e:               # noqa: BLE001 — any decode fault
        raise StaleExecutable(
            f"undeserializable body: {type(e).__name__}: {e}") from None


def _safe_sig(sig: str) -> str:
    """A signature that may become a filename: lowercase hex only."""
    if not (isinstance(sig, str) and 8 <= len(sig) <= 128
            and set(sig) <= _HEX):
        raise ValueError(f"not a hex executable signature: {sig!r}")
    return sig


class ExecutableStore:
    """Disk spool of serialized executables keyed by signature.

    Used on both ends of the warm-pool protocol: a worker's local disk
    tier (payloads it built or prefetched) and the broker's spool
    (payloads uploaded by workers, served to newly registered ones).
    Raw payload bytes only — the broker never deserializes.

    Retention is LRU by total bytes (``max_bytes``); use counts feed
    :meth:`hot` — the "prefetch these first" list a registration reply
    carries.  All writes are atomic (tmp + rename), so a reader never
    sees a torn payload.
    """

    def __init__(self, directory: str, max_bytes: int = 512 << 20):
        self.dir = directory
        os.makedirs(self.dir, exist_ok=True)
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        #: per-signature use count (puts + gets) — the heat signal
        self._uses: dict[str, int] = {}
        #: insertion/use order for LRU eviction
        self._order: list[str] = []
        self.puts = 0
        self.evictions = 0
        for name in sorted(os.listdir(self.dir)):   # adopt prior spool
            if name.endswith(".exe"):
                sig = name[:-4]
                self._uses.setdefault(sig, 0)
                self._order.append(sig)

    def _path(self, sig: str) -> str:
        return os.path.join(self.dir, f"{_safe_sig(sig)}.exe")

    def _touch_locked(self, sig: str) -> None:
        self._uses[sig] = self._uses.get(sig, 0) + 1
        if sig in self._order:
            self._order.remove(sig)
        self._order.append(sig)

    def has(self, sig: str) -> bool:
        try:
            return os.path.exists(self._path(sig))
        except ValueError:
            return False

    def get_bytes(self, sig: str) -> bytes | None:
        """The raw payload for ``sig`` (None if absent).  Counts a use
        — repeated fetches mark the signature hot."""
        try:
            path = self._path(sig)
        except ValueError:
            return None
        try:
            with open(path, "rb") as fh:
                payload = fh.read()
        except OSError:
            return None
        with self._lock:
            self._touch_locked(sig)
        return payload

    def put_bytes(self, sig: str, payload: bytes) -> bool:
        """Store one payload (idempotent: re-putting an existing
        signature just marks it hot).  Only framed payloads are
        accepted — arbitrary bytes can't enter the spool.  Evicts LRU
        entries beyond ``max_bytes``.  Returns True if stored/present.
        """
        try:
            path = self._path(sig)
        except ValueError:
            return False
        if not payload.startswith(_MAGIC):
            return False
        with self._lock:
            if not os.path.exists(path):
                tmp = f"{path}.{os.getpid()}.tmp"
                try:
                    with open(tmp, "wb") as fh:
                        fh.write(payload)
                    os.replace(tmp, path)
                except OSError:
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
                    return False
                self.puts += 1
            self._touch_locked(sig)
            self._evict_locked()
        return True

    def discard(self, sig: str) -> None:
        """Drop one entry (e.g. a payload that failed to deserialize —
        no point re-parsing it on every miss)."""
        try:
            path = self._path(sig)
        except ValueError:
            return
        with self._lock:
            try:
                os.unlink(path)
            except OSError:
                pass
            self._uses.pop(sig, None)
            if sig in self._order:
                self._order.remove(sig)

    def _evict_locked(self) -> None:
        while self.total_bytes() > self.max_bytes and len(self._order) > 1:
            victim = self._order.pop(0)
            self._uses.pop(victim, None)
            try:
                os.unlink(os.path.join(self.dir, f"{victim}.exe"))
            except OSError:
                pass
            self.evictions += 1

    def total_bytes(self) -> int:
        total = 0
        try:
            for name in os.listdir(self.dir):
                if name.endswith(".exe"):
                    try:
                        total += os.path.getsize(
                            os.path.join(self.dir, name))
                    except OSError:
                        pass
        except OSError:
            pass
        return total

    def signatures(self) -> list[str]:
        with self._lock:
            return list(self._order)

    def hot(self, n: int = 8) -> list[str]:
        """The ``n`` most-used signatures, hottest first — what a
        registration reply tells a fresh worker to prefetch."""
        with self._lock:
            ranked = sorted(self._uses.items(),
                            key=lambda kv: (-kv[1],
                                            -self._order.index(kv[0])
                                            if kv[0] in self._order
                                            else 0))
        return [sig for sig, _ in ranked[:n] if self.has(sig)]

    def clear(self) -> None:
        """Drop every entry (a cache invalidation must reach disk too —
        otherwise a cleared program would come straight back on the
        next miss)."""
        with self._lock:
            for sig in list(self._order):
                try:
                    os.unlink(os.path.join(self.dir, f"{sig}.exe"))
                except OSError:
                    pass
            self._order.clear()
            self._uses.clear()

    def stats(self) -> dict[str, Any]:
        with self._lock:
            n = len(self._order)
        return {"entries": n, "bytes": self.total_bytes(),
                "puts": self.puts, "evictions": self.evictions}


class CompileCache:
    """Process-level compiled-plugin cache (paper §I: "the same
    pipeline, many datasets" — resubmission must not retrace), with an
    optional persistent tier that survives the process."""

    def __init__(self, max_entries: int | None = None,
                 store: ExecutableStore | str | None = None,
                 fetch: Callable[[str], bytes | None] | None = None,
                 publish: Callable[[str, bytes], Any] | None = None):
        """Args:
            max_entries: FIFO-evict beyond this many compiled programs
                (None = unbounded).
            store: disk tier — an :class:`ExecutableStore` or a
                directory path (None = in-memory only).  Only entries
                built with ``serializable=True`` use it.
            fetch: optional ``sig -> payload bytes | None`` callback
                consulted on a disk miss BEFORE compiling (the worker
                wires ``GET /executables/{sig}`` here).  Failures fall
                back to a fresh compile.
            publish: optional ``(sig, payload) -> None`` callback run
                after a fresh serializable build (the worker wires
                ``PUT /executables/{sig}`` here).  Best-effort.

        Note: an EMPTY cache is falsy (``__len__``) — test ``is None``,
        never truthiness, when defaulting."""
        self.max_entries = max_entries
        self.store = (ExecutableStore(store) if isinstance(store, str)
                      else store)
        self.fetch = fetch
        self.publish = publish
        self._entries: dict[Any, Any] = {}
        self._building: dict[Any, threading.Event] = {}
        self._lock = threading.Lock()
        self._generation = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.build_s = 0.0               # total wall spent compiling
        self.disk_hits = 0               # deserialized instead of compiled
        self.disk_misses = 0             # persisted tier had nothing usable
        self.disk_rejects = 0            # stale/corrupt payloads refused
        self.uploads = 0                 # payloads handed to ``publish``

    def get_or_build(self, key, builder: Callable[[], Any],
                     serializable: bool = False):
        """Return the cached value for ``key``, building it (once) on a
        miss.

        Args:
            key: hashable identity (see
                ``ShardedTransport._plugin_key`` / ARCHITECTURE.md).
            builder: zero-arg callable producing the compiled program;
                invoked at most once per key even under concurrent
                misses — losers of the build race block on the winner.
            serializable: the builder produces an AOT-compiled
                executable (``jit(...).lower(...).compile()``) — on a
                memory miss the persistent tier is consulted first
                (disk, then the ``fetch`` callback), and a fresh build
                is serialized back out (disk + ``publish``).

        Returns: the cached/built value.  A ``builder`` that raises
        propagates to its caller; waiting losers retry (and one of them
        becomes the next builder).
        """
        while True:
            with self._lock:
                if key in self._entries:
                    self.hits += 1
                    return self._entries[key]
                ev = self._building.get(key)
                if ev is None:
                    self._building[key] = threading.Event()
                    self.misses += 1
                    # snapshot the generation BEFORE building: a clear()
                    # issued mid-build bumps it, and the late winner
                    # below must then be dropped, not re-inserted
                    gen = self._generation
                    break
            ev.wait()                    # someone else is compiling this key
        try:
            fn = None
            sig = None
            if serializable and self.store is not None:
                sig = executable_signature(key)
                fn = self._load_persisted(sig)
            if fn is None:
                t0 = time.perf_counter()
                t0_epoch = time.time()
                fn = builder()
                dt = time.perf_counter() - t0
                tr = current_trace()
                if tr is not None:
                    # actual builds (never hits) show up as ``compile``
                    # spans on whichever job triggered them
                    tr.record("compile", t0_epoch, t0_epoch + dt,
                              attrs={"kind": key[0] if isinstance(key, tuple)
                                     and key else "plugin"})
                with self._lock:
                    self.build_s += dt
                if sig is not None:
                    self._persist(sig, fn)
            with self._lock:
                if self._generation != gen:
                    # cleared while we were building: this program was
                    # invalidated before it existed — hand it to the
                    # caller (it is still correct for THIS call) but
                    # never cache it
                    return fn
                self._entries[key] = fn
                if (self.max_entries is not None
                        and len(self._entries) > self.max_entries):
                    # FIFO eviction — plugin programs are all roughly the
                    # same size; recency tracking is not worth the locking
                    oldest = next(iter(self._entries))
                    del self._entries[oldest]
                    self.evictions += 1
            return fn
        finally:
            with self._lock:
                self._building.pop(key).set()

    # -- persistent tier ------------------------------------------------
    def _load_persisted(self, sig: str):
        """A runnable executable for ``sig`` from the persistent tier —
        local disk first, then the broker ``fetch`` callback — or None
        (count a disk miss; the caller compiles).  Loads record
        ``executable.fetch`` + ``executable.deserialize`` spans on the
        current trace, mirroring how real builds record ``compile``."""
        tr = current_trace()
        t0 = time.time()
        payload = self.store.get_bytes(sig)
        source = "disk"
        if payload is None and self.fetch is not None:
            try:
                payload = self.fetch(sig)
            except Exception:            # noqa: BLE001 — network is advisory
                payload = None
            source = "broker"
            if payload is not None:
                self.store.put_bytes(sig, payload)
        if payload is None:
            with self._lock:
                self.disk_misses += 1
            return None
        if tr is not None:
            tr.record("executable.fetch", t0, time.time(),
                      attrs={"sig": sig[:16], "source": source,
                             "bytes": len(payload)})
        t1 = time.time()
        try:
            fn = deserialize_payload(payload, sig)
        except StaleExecutable:
            # never silently loaded: corrupt/version-mismatched payloads
            # are dropped from disk and the caller compiles fresh
            with self._lock:
                self.disk_rejects += 1
                self.disk_misses += 1
            self.store.discard(sig)
            return None
        if tr is not None:
            tr.record("executable.deserialize", t1, time.time(),
                      attrs={"sig": sig[:16]})
        with self._lock:
            self.disk_hits += 1
        return fn

    def _persist(self, sig: str, fn: Any) -> None:
        """Serialize a fresh build into the store and hand it to
        ``publish``.  Best-effort on both counts: an executable jax
        cannot serialize (or a broker that refuses the upload) must
        never fail the job that compiled it."""
        try:
            payload = serialize_payload(fn, sig)
        except Exception:                # noqa: BLE001 — not serializable
            return
        self.store.put_bytes(sig, payload)
        if self.publish is not None:
            try:
                self.publish(sig, payload)
                with self._lock:
                    self.uploads += 1
            except Exception:            # noqa: BLE001 — upload is advisory
                pass

    def prefetch(self, sigs: list[str]) -> int:
        """Warm-pool fill: fetch every signature not already on disk
        via the ``fetch`` callback (the broker's hottest list, carried
        on the registration reply).  Returns how many payloads landed.
        Purely additive — failures are skipped."""
        if self.store is None or self.fetch is None:
            return 0
        n = 0
        for sig in sigs or ():
            if not isinstance(sig, str) or self.store.has(sig):
                continue
            try:
                payload = self.fetch(sig)
            except Exception:            # noqa: BLE001
                continue
            if payload and self.store.put_bytes(sig, payload):
                n += 1
        return n

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        """Drop every cached program (counters are kept) — including
        the persistent tier, and including builds currently in flight:
        the generation bump makes a pre-clear builder's late insert a
        no-op."""
        with self._lock:
            self._generation += 1
            self._entries.clear()
        if self.store is not None:
            self.store.clear()

    def stats(self) -> dict[str, Any]:
        """Counters for ``GET /stats``: ``hits``, ``misses``,
        ``entries``, ``evictions``, total compile ``build_s``, and —
        when a persistent tier is configured — a ``disk`` block with
        its hit/miss/reject/upload counters and store occupancy."""
        with self._lock:
            out: dict[str, Any] = {
                "hits": self.hits, "misses": self.misses,
                "entries": len(self._entries),
                "evictions": self.evictions,
                "build_s": round(self.build_s, 4),
                "generation": self._generation}
            disk = {"hits": self.disk_hits, "misses": self.disk_misses,
                    "rejects": self.disk_rejects, "uploads": self.uploads}
        if self.store is not None:
            out["disk"] = {**disk, **self.store.stats()}
        return out
