"""Process-list construction + the pre-flight plugin-list check."""
import numpy as np
import pytest

from repro.core import (BaseLoader, BaseSaver, DataSet, LambdaFilter,
                        ProcessList, ProcessListError)


class L(BaseLoader):
    name = "loader"

    def load(self):
        d = DataSet(self.out_dataset_names[0], (4, 4), np.float32,
                    ("a", "b"), backing=np.zeros((4, 4), np.float32))
        d.add_pattern("P", core=("b",), slice_=("a",))
        return [d]


class S(BaseSaver):
    name = "saver"

    def save(self, ds):
        pass


def _ok_list():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(S, in_datasets=("tomo",))
    return pl


def test_valid_list_passes():
    assert "tomo" in _ok_list().check()


def test_empty_list_rejected():
    with pytest.raises(ProcessListError):
        ProcessList().check()


def test_missing_loader_rejected():
    pl = ProcessList()
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("x",), out_datasets=("x",))
    pl.add(S, in_datasets=("x",))
    with pytest.raises(ProcessListError, match="loader"):
        pl.check()


def test_missing_saver_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    with pytest.raises(ProcessListError, match="saver"):
        pl.check()


def test_unknown_input_dataset_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("nope",), out_datasets=("x",))
    pl.add(S, in_datasets=("x",))
    with pytest.raises(ProcessListError, match="nope"):
        pl.check()


def test_wrong_dataset_counts_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("tomo", "tomo2"), out_datasets=("x",))
    pl.add(S, in_datasets=("x",))
    with pytest.raises(ProcessListError, match="in_datasets"):
        pl.check()


def test_unknown_param_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b, "bogus_param": 3},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(S, in_datasets=("tomo",))
    with pytest.raises(ProcessListError, match="bogus_param"):
        pl.check()


def test_loader_after_processing_rejected():
    pl = ProcessList()
    pl.add(L, out_datasets=("a",))
    pl.add(LambdaFilter, params={"fn": lambda b: b},
           in_datasets=("a",), out_datasets=("a",))
    pl.add(L, out_datasets=("b",))
    pl.add(S, in_datasets=("a",))
    with pytest.raises(ProcessListError, match="loaders"):
        pl.check()


def test_json_roundtrip(tmp_path):
    pl = _ok_list()
    path = str(tmp_path / "chain.json")
    pl.save(path)
    pl2 = ProcessList.load(path)
    assert len(pl2) == len(pl)
    assert [e.cls for e in pl2] == [e.cls for e in pl]
    # function params are not serialisable and are dropped — the check
    # re-validates structure
    assert pl2.entries[1].in_datasets == ("tomo",)
