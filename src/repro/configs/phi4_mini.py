"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA (arXiv:2412.08905; hf).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
Partial rotary factor 0.75 per the released config.
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "phi4-mini-3.8b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064, head_dim=128,
    rope_theta=10_000.0, rope_fraction=0.75, dtype=jnp.bfloat16,
    tie_embeddings=True)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=311, head_dim=16, rope_fraction=0.75,
    dtype=jnp.float32, remat=False, tie_embeddings=True)
