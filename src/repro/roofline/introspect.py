"""Collective introspection: per-(type, shape) weighted byte totals with
loop multipliers — the §Perf "profile" for finding which collective
dominates a compiled cell."""
from __future__ import annotations

import re
from collections import Counter

from . import hlo_cost as hc


def collective_profile(text: str, top: int = 12) -> list[tuple]:
    comps = hc._split_computations(text)
    entry = comps.pop("__entry_name__")
    comps.pop("__entry__")
    per: dict[str, Counter] = {}
    calls: dict[str, list] = {}
    for name, lines in comps.items():
        agg: Counter = Counter()
        edges = []
        for line in lines:
            m = hc._DEF_RE.match(line)
            if not m:
                continue
            ts, op = m.group(2), m.group(3)
            base = op.rstrip("0123456789.")
            for coll in hc._COLLECTIVES:
                if base == coll or base == coll + "-start":
                    w = 2 if coll == "all-reduce" else 1
                    agg[f"{coll} {ts.split('{')[0]}"] += \
                        w * hc._nbytes(ts)
                    break
            wm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                           line)
            if wm:
                trip = hc._trip_count(comps.get(wm.group(1), []))
                edges.append((wm.group(2), trip))
                edges.append((wm.group(1), trip))
            else:
                for cm in re.finditer(
                        r"(?:calls|to_apply|branch_computations)="
                        r"\{?%?([\w.\-]+(?:, ?%?[\w.\-]+)*)\}?", line):
                    for tgt in re.split(r",\s*", cm.group(1)):
                        edges.append((tgt.lstrip("%"), 1))
        per[name] = agg
        calls[name] = edges

    total: Counter = Counter()

    def acc(name, mult, depth=0):
        if name not in per or depth > 30:
            return
        for k, v in per[name].items():
            total[k] += v * mult
        for child, trip in calls[name]:
            acc(child, mult * trip, depth + 1)

    acc(entry, 1.0)
    return total.most_common(top)
