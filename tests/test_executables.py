"""The persistent executable tier ("kill the retrace tax") and the two
bugfixes riding along.

Covers: payload framing (corrupt/truncated/version-mismatched payloads
are ALWAYS rejected, never silently loaded), the ExecutableStore spool
(LRU retention, heat ranking), CompileCache's disk tier (a second
process-alike cache pointed at the same store deserializes instead of
recompiling, bit-identically), the clear()-during-build generation
guard, the monotonic lease clock (a wall-clock step must not expire
leases; a monotonic advance must), per-worker secrets over HTTP, and
the broker warm pool end-to-end: a freshly registered worker prefetches
the spool's hot list and serves its first job with ``executable.fetch``
spans but NO ``compile`` span.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import PluginRunner, ShardedTransport
from repro.service import (CompileCache, PipelineClient, PipelineService,
                           PipelineWorker, ServiceError, from_spec)
from repro.service import scheduler as sched_mod
from repro.service.compile_cache import (_MAGIC, ExecutableStore,
                                         StaleExecutable,
                                         deserialize_payload,
                                         env_fingerprint,
                                         executable_signature)
from repro.service.worker import _transport_factory
from repro.tomo import standard_chain


def _framed(sig: str, body: bytes = b"opaque-executable-bytes") -> bytes:
    """A payload that passes the store's framing check (the store never
    deserializes, so the body can be anything)."""
    header = json.dumps({"sig": sig, "fingerprint": env_fingerprint()},
                        sort_keys=True).encode()
    return _MAGIC + header + b"\n" + body


def _spec(seed=0):
    """The standard tomo chain as a wire spec (matches test_worker)."""
    return {"version": 1, "plugins": [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": 16, "n_angles": 8, "n_rows": 1,
                    "seed": seed},
         "out_datasets": ["tomo"]},
        {"plugin": "dark_flat_correction",
         "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["tomo"]},
        {"plugin": "fbp_recon", "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["recon"]},
        {"plugin": "hdf5_saver", "in_datasets": ["recon"]},
    ]}


@pytest.fixture
def broker(tmp_path):
    svc = PipelineService(workers_remote=True, lease_ttl=30.0,
                          sweep_interval=999.0,
                          executables_dir=str(tmp_path / "spool"))
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    try:
        yield svc, client
    finally:
        svc.stop()


# ======================================================== signatures
def test_executable_signature_stable_hex_and_key_sensitive():
    a = executable_signature(("plugin", (1, 2, 3)))
    assert a == executable_signature(("plugin", (1, 2, 3)))
    assert a != executable_signature(("plugin", (1, 2, 4)))
    assert len(a) == 64 and set(a) <= set("0123456789abcdef")


def test_deserialize_rejects_every_bad_payload():
    """No payload that isn't exactly a framed, fingerprint-matching,
    this-process-loadable executable may ever load."""
    good_sig = "ab" * 16
    with pytest.raises(StaleExecutable):
        deserialize_payload(b"not an executable at all")
    with pytest.raises(StaleExecutable):        # truncated mid-header
        deserialize_payload(_MAGIC + b'{"sig": "abc')
    with pytest.raises(StaleExecutable):        # unpicklable body
        deserialize_payload(_framed(good_sig, b"\x00garbage"))
    stale = dict(env_fingerprint())
    stale["jax"] = "0.0.1"                      # another toolchain
    header = json.dumps({"sig": good_sig, "fingerprint": stale}).encode()
    with pytest.raises(StaleExecutable):
        deserialize_payload(_MAGIC + header + b"\n" + b"body")
    with pytest.raises(StaleExecutable):        # signature mismatch
        deserialize_payload(_framed("cd" * 16), sig=good_sig)


# ==================================================== ExecutableStore
def test_store_framing_lru_and_heat(tmp_path):
    store = ExecutableStore(str(tmp_path / "s"), max_bytes=4096)
    sig_a, sig_b = "aa" * 8, "bb" * 8
    assert store.put_bytes(sig_a, b"raw junk") is False    # unframed
    assert store.put_bytes("NOT-HEX!", _framed("aa" * 8)) is False
    assert store.put_bytes(sig_a, _framed(sig_a)) is True
    assert store.put_bytes(sig_b, _framed(sig_b)) is True
    assert store.get_bytes(sig_a) == _framed(sig_a)
    assert store.get_bytes("ee" * 8) is None
    # heat: every put/get counts a use; sig_a has 2, sig_b has 1
    assert store.hot(2) == [sig_a, sig_b]
    # LRU: a payload pushing past max_bytes evicts the least recent
    big = _framed("cc" * 8, b"x" * 4096)
    assert store.put_bytes("cc" * 8, big) is True
    assert not store.has(sig_b)                 # b was least recent
    assert store.has(sig_a) or store.evictions >= 1
    # a new store over the same directory adopts surviving entries
    adopted = ExecutableStore(str(tmp_path / "s"), max_bytes=4096)
    assert set(adopted.signatures()) == set(store.signatures())
    store.clear()
    assert store.signatures() == [] and store.total_bytes() == 0


# ================================================== CompileCache tiers
def test_disk_tier_second_cache_loads_instead_of_compiling(tmp_path):
    """Two caches over one store directory = two worker processes over
    a shared disk tier: the second deserializes every program the first
    compiled — zero builder calls — and produces bit-identical output."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    store_dir = str(tmp_path / "exe")
    pl = standard_chain(n_det=16, n_angles=8, n_rows=1, use_pallas=False)

    def run(cache):
        tr = ShardedTransport(mesh, donate=False, compile_cache=cache)
        out = PluginRunner(standard_chain(n_det=16, n_angles=8, n_rows=1,
                                          use_pallas=False), tr).run()
        return tr.read(out["recon"])

    warm = CompileCache(store=store_dir)
    got1 = run(warm)
    assert warm.stats()["disk"]["hits"] == 0    # nothing persisted yet
    persisted = warm.stats()["disk"]["puts"]
    assert persisted >= 1                       # AOT programs landed

    cold = CompileCache(store=store_dir)
    got2 = run(cold)
    st = cold.stats()
    assert st["disk"]["hits"] == persisted      # every program loaded
    assert st["disk"]["rejects"] == 0
    assert st["build_s"] == 0.0                 # ZERO fresh compiles
    np.testing.assert_array_equal(got1, got2)   # bit-identical


def test_corrupt_store_entries_fall_back_to_fresh_compile(tmp_path):
    """Corrupted/truncated/version-mismatched disk entries must never
    crash or produce wrong results: the cache rejects them, drops them
    from disk, and compiles fresh."""
    store_dir = str(tmp_path / "exe")
    key = ("k", (16, 8))
    sig = executable_signature(key)
    builds = []

    def builder():
        builds.append(1)
        return "freshly-built"                  # not serializable: fine

    for bad in (b"not even framed",
                _framed(sig)[:20],              # truncated
                _framed(sig, b"\x00junk")):     # undeserializable body
        cache = CompileCache(store=store_dir)
        cache.store.put_bytes(sig, _framed(sig))   # seed a file...
        with open(os.path.join(store_dir, f"{sig}.exe"), "wb") as fh:
            fh.write(bad)                          # ...then corrupt it
        got = cache.get_or_build(key, builder, serializable=True)
        assert got == "freshly-built"
        if bad.startswith(_MAGIC):              # framed-but-broken ones
            assert cache.disk_rejects == 1      # counted + dropped
            assert not cache.store.has(sig)
    assert len(builds) == 3                     # compiled fresh each time


def test_clear_generation_guard_blocks_inflight_reinsert():
    """clear() during a build: the build still returns its value to the
    caller, but may NOT re-enter the cache afterwards."""
    cache = CompileCache()
    entered, release = threading.Event(), threading.Event()
    out = []

    def slow_builder():
        entered.set()
        release.wait(5)
        return "stale-program"

    t = threading.Thread(target=lambda: out.append(
        cache.get_or_build("k", slow_builder)))
    t.start()
    assert entered.wait(5)
    cache.clear()                               # invalidate mid-build
    release.set()
    t.join(5)
    assert out == ["stale-program"]             # caller still served
    assert len(cache) == 0                      # ...but never cached
    builds = []
    cache.get_or_build("k", lambda: builds.append(1) or "fresh")
    assert builds == [1]                        # next call rebuilds


def test_clear_invalidates_disk_tier(tmp_path):
    cache = CompileCache(store=str(tmp_path / "exe"))
    sig = "ab" * 16
    cache.store.put_bytes(sig, _framed(sig))
    assert cache.store.has(sig)
    cache.clear()
    assert not cache.store.has(sig)             # cleared through to disk


# ================================================= lease clock (bugfix)
def test_lease_survives_wall_clock_step_but_not_monotonic(broker):
    """The regression this PR fixes: lease expiry must use the
    monotonic clock.  An NTP/DST wall-clock step of +2h may not expire
    a live lease; genuine monotonic passage beyond the TTL must."""
    svc, client = broker
    b = svc.broker
    client.register_worker(worker_id="cw")
    jid = client.submit(_spec(seed=1))
    assert client.lease("cw")
    real_wall, real_mono = sched_mod._wall, sched_mod._mono
    try:
        sched_mod._wall = lambda: real_wall() + 7200    # +2h step
        b._expire_locked_sweep()
        assert client.status(jid)["state"] != "queued"  # NOT requeued
        assert client.progress(jid, "cw")["verdict"] == "ok"
        assert client.stats()["leases_expired"] == 0

        sched_mod._mono = lambda: real_mono() + svc.broker.lease_ttl + 1
        b._expire_locked_sweep()
        assert client.stats()["leases_expired"] == 1
        assert client.status(jid)["state"] == "queued"  # requeued
        assert client.progress(jid, "cw")["verdict"] == "lost"
    finally:
        sched_mod._wall, sched_mod._mono = real_wall, real_mono


# ============================================ worker identity (bugfix)
def test_worker_secret_required_and_rotated(broker):
    """lease/complete demand the secret minted at registration: a rogue
    client reusing a worker_id (the bug: any client could complete any
    worker's jobs) gets 403; re-registration rotates the secret."""
    svc, client = broker
    client.register_worker(worker_id="sw")
    old_secret = client.worker_secret("sw")
    jid = client.submit(_spec(seed=2))

    rogue = PipelineClient(client.base_url, timeout=30.0)
    with pytest.raises(ServiceError) as ei:     # no secret at all
        rogue.lease("sw")
    assert ei.value.status == 403
    rogue.adopt_worker_secret("sw", "deadbeef" * 4)
    with pytest.raises(ServiceError) as ei:     # wrong secret
        rogue.lease("sw")
    assert ei.value.status == 403
    with pytest.raises(ServiceError) as ei:     # unregistered worker
        rogue.lease("ghost")
    assert ei.value.status == 404

    assert client.lease("sw")                   # the real holder works
    with pytest.raises(ServiceError) as ei:     # rogue can't complete it
        rogue.complete(jid, "sw", "done")
    assert ei.value.status == 403

    # re-registration mints a FRESH secret — the old one dies with it
    client.register_worker(worker_id="sw")
    assert client.worker_secret("sw") != old_secret
    rogue.adopt_worker_secret("sw", old_secret)
    with pytest.raises(ServiceError) as ei:
        rogue.lease("sw")
    assert ei.value.status == 403


# =========================================== executable endpoints (HTTP)
def test_executable_upload_fetch_and_hot_list(broker):
    svc, client = broker
    reply = client.register_worker(worker_id="ew")
    sig = executable_signature(("wire-test", 1))
    payload = _framed(sig)

    out = client.upload_executable(sig, "ew", payload)
    assert out["stored"] is True and out["bytes"] == len(payload)
    assert client.fetch_executable(sig) == payload
    assert sig in client.hot_executables()
    with pytest.raises(ServiceError) as ei:     # unknown signature
        client.fetch_executable("ee" * 16)
    assert ei.value.status == 404
    with pytest.raises(ServiceError) as ei:     # unframed payload
        client.upload_executable(sig, "ew", b"arbitrary junk")
    assert ei.value.status == 400

    rogue = PipelineClient(client.base_url, timeout=30.0)
    rogue.adopt_worker_secret("ew", "f00d" * 8)
    with pytest.raises(ServiceError) as ei:     # bad secret
        rogue.upload_executable(sig, "ew", payload)
    assert ei.value.status == 403
    # a fresh registration's reply advertises the hot list
    reply2 = client.register_worker(worker_id="ew2")
    assert sig in reply2["hot_executables"]


def test_executable_reads_are_token_authed(tmp_path):
    """Unlike the read-only job endpoints, /executables is token-authed
    (serialized programs are code)."""
    svc = PipelineService(workers_remote=True, token="sesame",
                          executables_dir=str(tmp_path / "spool"))
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    try:
        bare = PipelineClient(url, timeout=30.0)
        for call in (bare.hot_executables,
                     lambda: bare.fetch_executable("ab" * 16)):
            with pytest.raises(ServiceError) as ei:
                call()
            assert ei.value.status == 401
        armed = PipelineClient(url, timeout=30.0, token="sesame")
        assert armed.hot_executables() == []
    finally:
        svc.stop()


# ================================================= warm pool end-to-end
def test_cold_worker_prefetches_and_skips_compile(broker, tmp_path):
    """The acceptance path: worker A compiles the standard chain and
    uploads its executables; a brand-new worker B prefetches them at
    registration and serves its first job with ``executable.fetch``
    spans and NO ``compile`` span — bit-identical results."""
    svc, client = broker
    url = client.base_url

    def make_worker(wid, sub):
        cache = CompileCache(store=str(tmp_path / sub / "exe"))
        return PipelineWorker(
            url, worker_id=wid, poll=0.01, compile_cache=cache,
            transport_factory=_transport_factory(
                "sharded", str(tmp_path / sub), compile_cache=cache))

    hot = make_worker("hot-w", "wA")
    hot.register()
    assert hot.prefetched == 0                  # spool was empty
    j1 = client.submit(_spec(seed=3))
    assert hot.run_once() is True
    assert client.wait(j1, timeout=120)["state"] == "done"
    assert hot.compile_cache.uploads >= 1       # published to the broker
    assert svc.broker.executables.stats()["entries"] >= 1

    cold = make_worker("cold-w", "wB")
    cold.register()
    assert cold.prefetched >= 1                 # warm pool landed
    # hot-w must not race for the job: deregister it from contention by
    # simply not calling run_once on it again
    j2 = client.submit(_spec(seed=3))
    assert cold.run_once() is True
    assert client.wait(j2, timeout=120)["state"] == "done"

    st = cold.compile_cache.stats()
    assert st["disk"]["hits"] >= 1
    assert st["build_s"] == 0.0                 # zero fresh compiles
    names = [s["name"] for s in client.trace(j2)["spans"]]
    assert "executable.fetch" in names
    assert "compile" not in names               # the retrace tax, killed
    np.testing.assert_array_equal(client.result(j1), client.result(j2))
