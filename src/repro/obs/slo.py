"""Declarative SLO rule engine with a full alert lifecycle.

The load proof (``benchmarks/bench_load.py``) showed the service can
*report* queue age, latency quantiles and lease expiries; this module
makes the service *judge* them continuously.  A :class:`SloRule` names
one metric in the registry, how to read it (gauge value, histogram
quantile, or increase of a counter over a trailing window), a threshold,
and hold-down windows; the :class:`SloEngine` evaluates every rule
periodically and walks each through the alert lifecycle::

    ok ──breach──▶ pending ──breached ≥ for_s──▶ firing
    firing ──clear ≥ resolve_s──▶ ok   (one ``alert.resolved`` event)

Transitions are exactly-once events into the structured
:class:`~repro.obs.log.EventLog` (``alert.pending`` / ``alert.firing`` /
``alert.resolved``), stamped with the engine's own trace id so every
event record joins the common schema.  ``critical=True`` rules feed the
degrade-aware readiness probe: ``GET /healthz?ready=1`` answers 503
while any critical rule is firing.

Defaults cover the signals the ROADMAP calls out — queue oldest-age,
``job.latency.e2e`` p99, lease-expiry rate, streaming ingest lag, and
executable-store rejects — and a ``spec`` dict overrides or extends
them per deployment (see :func:`rules_from_spec`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any

from .log import EventLog
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import new_trace_id

#: alert lifecycle states
OK, PENDING, FIRING = "ok", "pending", "firing"


@dataclasses.dataclass
class SloRule:
    """One declarative service-level objective.

    ``kind`` selects how ``metric`` is read from the registry:

    * ``"gauge"`` — the gauge's current value.
    * ``"quantile"`` — the histogram's ``quantile(q)`` (no breach while
      the histogram is empty).
    * ``"rate"`` — the counter's INCREASE over the trailing
      ``window_s`` seconds (events per window, not per second): the
      natural reading for "any lease expired recently?".

    The rule breaches while ``value <op> threshold``; it must stay
    breached ``for_s`` seconds to go firing, and stay clear
    ``resolve_s`` seconds to resolve — hold-downs against flapping.
    """

    name: str
    metric: str
    threshold: float
    kind: str = "gauge"              # "gauge" | "quantile" | "rate"
    op: str = ">"                    # ">" | "<"
    quantile: float = 0.99           # for kind="quantile"
    window_s: float = 30.0           # for kind="rate"
    for_s: float = 0.0               # breach hold-down before firing
    resolve_s: float = 0.0           # clear hold-down before resolving
    critical: bool = False           # feeds /healthz?ready=1
    help: str = ""

    def __post_init__(self):
        if self.kind not in ("gauge", "quantile", "rate"):
            raise ValueError(f"rule {self.name!r}: unknown kind "
                             f"{self.kind!r}")
        if self.op not in (">", "<"):
            raise ValueError(f"rule {self.name!r}: op must be '>' or "
                             f"'<', got {self.op!r}")

    def breached(self, value: float | None) -> bool:
        if value is None:
            return False
        return value > self.threshold if self.op == ">" \
            else value < self.threshold


def default_rules() -> list[SloRule]:
    """The rule set every service evaluates out of the box.  Thresholds
    are deliberately generous — a facility overrides them per
    deployment via the ``spec`` dict; the engine's job here is to make
    the lifecycle machinery always-on, not to guess one site's SLOs."""
    return [
        SloRule("queue-oldest-age", "queue.oldest_age_s", 120.0,
                kind="gauge", for_s=5.0, resolve_s=5.0,
                help="oldest queued job is starving"),
        SloRule("job-latency-p99", "job.latency.e2e", 300.0,
                kind="quantile", quantile=0.99, for_s=5.0,
                resolve_s=10.0,
                help="end-to-end p99 latency out of budget"),
        SloRule("lease-expiry-rate", "lease.expired", 0.0,
                kind="rate", window_s=30.0, critical=True,
                help="a worker stopped heartbeating (lease expired "
                     "recently)"),
        SloRule("ingest-lag", "stream.ingest_lag_s", 30.0,
                kind="quantile", quantile=0.95, for_s=5.0,
                resolve_s=10.0,
                help="streaming executors fell behind the beamline"),
        SloRule("executable-rejects", "executables.rejected", 0.0,
                kind="rate", window_s=60.0,
                help="workers uploading corrupt/unframed executables"),
    ]


def rules_from_spec(spec: dict[str, Any] | None) -> list[SloRule]:
    """The default rules merged with a user ``spec`` dict.

    ``spec`` maps rule name -> field overrides (any :class:`SloRule`
    field).  Overriding a default rule patches it in place; a new name
    defines a new rule (``"metric"`` and ``"threshold"`` required);
    mapping a name to ``None`` (or ``False``) disables that rule::

        {"lease-expiry-rate": {"window_s": 5.0},   # tighten a default
         "my-depth": {"metric": "queue.depth", "threshold": 50,
                      "critical": True},           # add a rule
         "ingest-lag": None}                       # disable a default

    Raises ValueError on unknown fields or an incomplete new rule.
    """
    rules = {r.name: r for r in default_rules()}
    fields = {f.name for f in dataclasses.fields(SloRule)}
    for name, patch in (spec or {}).items():
        if patch is None or patch is False:
            rules.pop(name, None)
            continue
        if not isinstance(patch, dict):
            raise ValueError(f"slo spec for {name!r} must be a dict "
                             f"(or None to disable), got {patch!r}")
        unknown = set(patch) - fields
        if unknown:
            raise ValueError(f"slo spec for {name!r}: unknown fields "
                             f"{sorted(unknown)}")
        if name in rules:
            rules[name] = dataclasses.replace(rules[name], **patch)
        else:
            if "metric" not in patch or "threshold" not in patch:
                raise ValueError(
                    f"new slo rule {name!r} needs at least 'metric' "
                    f"and 'threshold'")
            rules[name] = SloRule(name=name, **patch)
    return list(rules.values())


class _RuleState:
    """Mutable per-rule lifecycle bookkeeping."""

    __slots__ = ("state", "since", "breach_since", "clear_since",
                 "value", "fired", "resolved", "samples")

    def __init__(self):
        self.state = OK
        self.since: float | None = None       # current state entered at
        self.breach_since: float | None = None
        self.clear_since: float | None = None
        self.value: float | None = None
        self.fired = 0                        # lifetime firing count
        self.resolved = 0
        #: (t, counter value) samples for kind="rate"
        self.samples: deque[tuple[float, float]] = deque()


class SloEngine:
    """Periodic evaluator: rules over a registry, transitions into an
    event log.

    The service owns one engine and drives :meth:`evaluate` from a
    background thread (and opportunistically from ``GET /slo`` /
    ``GET /healthz?ready=1`` so responses are fresh); evaluation is
    serialised under an internal lock, so extra callers never
    double-emit a transition.
    """

    def __init__(self, registry: MetricsRegistry,
                 events: EventLog | None = None,
                 spec: dict[str, Any] | None = None):
        self.registry = registry
        self.events = events
        self.rules = rules_from_spec(spec)
        self.trace_id = new_trace_id()   # the health plane's own trace
        self._states = {r.name: _RuleState() for r in self.rules}
        self._lock = threading.Lock()
        self._evaluated_at: float | None = None

    # -- reading metrics ------------------------------------------------
    def _read(self, rule: SloRule, st: _RuleState,
              now: float) -> float | None:
        m = self.registry.get(rule.metric)
        if m is None:
            return None
        if rule.kind == "gauge":
            if not isinstance(m, (Gauge, Counter)):
                return None
            v = float(m.value)
            return None if v != v else v          # NaN -> unknown
        if rule.kind == "quantile":
            if not isinstance(m, Histogram):
                return None
            return m.quantile(rule.quantile)
        # kind == "rate": increase over the trailing window
        if not isinstance(m, Counter):
            return None
        v = float(m.value)
        st.samples.append((now, v))
        horizon = now - rule.window_s
        # keep one sample at-or-before the horizon as the baseline
        while len(st.samples) > 1 and st.samples[1][0] <= horizon:
            st.samples.popleft()
        return v - st.samples[0][1]

    # -- lifecycle ------------------------------------------------------
    def _emit(self, event: str, rule: SloRule, st: _RuleState) -> None:
        if self.events is not None:
            self.events.emit(event, trace_id=self.trace_id,
                             rule=rule.name, metric=rule.metric,
                             value=st.value, threshold=rule.threshold,
                             critical=rule.critical)

    def evaluate(self, now: float | None = None) -> list[str]:
        """One evaluation pass over every rule.  Returns the transition
        events emitted this pass (``["alert.firing", ...]``) — mostly
        for tests; the real outputs are the event log and the states
        :meth:`snapshot` reports."""
        now = time.time() if now is None else now
        emitted: list[str] = []
        with self._lock:
            self._evaluated_at = now
            for rule in self.rules:
                st = self._states[rule.name]
                st.value = self._read(rule, st, now)
                if rule.breached(st.value):
                    st.clear_since = None
                    if st.breach_since is None:
                        st.breach_since = now
                    if st.state == OK:
                        st.state, st.since = PENDING, now
                        self._emit("alert.pending", rule, st)
                        emitted.append("alert.pending")
                    if st.state == PENDING and \
                            now - st.breach_since >= rule.for_s:
                        st.state, st.since = FIRING, now
                        st.fired += 1
                        self._emit("alert.firing", rule, st)
                        emitted.append("alert.firing")
                        self.registry.counter("alerts.fired").inc()
                else:
                    st.breach_since = None
                    if st.state == PENDING:
                        # never fired: fold back silently (no alert
                        # lifecycle event was owed to operators)
                        st.state, st.since = OK, now
                        st.clear_since = None
                    elif st.state == FIRING:
                        if st.clear_since is None:
                            st.clear_since = now
                        if now - st.clear_since >= rule.resolve_s:
                            st.state, st.since = OK, now
                            st.clear_since = None
                            st.resolved += 1
                            self._emit("alert.resolved", rule, st)
                            emitted.append("alert.resolved")
                            self.registry.counter(
                                "alerts.resolved").inc()
        return emitted

    # -- reading --------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        """The ``GET /slo`` payload: every rule's definition, current
        reading and lifecycle state, plus the firing summary."""
        with self._lock:
            rules = []
            for rule in self.rules:
                st = self._states[rule.name]
                rules.append({
                    "name": rule.name, "metric": rule.metric,
                    "kind": rule.kind, "op": rule.op,
                    "threshold": rule.threshold,
                    **({"quantile": rule.quantile}
                       if rule.kind == "quantile" else {}),
                    **({"window_s": rule.window_s}
                       if rule.kind == "rate" else {}),
                    "for_s": rule.for_s, "resolve_s": rule.resolve_s,
                    "critical": rule.critical, "help": rule.help,
                    "state": st.state, "value": st.value,
                    "since": st.since, "fired": st.fired,
                    "resolved": st.resolved,
                })
            return {
                "rules": rules,
                "firing": [r["name"] for r in rules
                           if r["state"] == FIRING],
                "critical_firing": [r["name"] for r in rules
                                    if r["state"] == FIRING
                                    and r["critical"]],
                "evaluated_at": self._evaluated_at,
                "trace_id": self.trace_id,
            }

    def critical_firing(self) -> list[dict[str, Any]]:
        """Firing critical rules, as machine-readable detail for the
        503 readiness reply."""
        snap = self.snapshot()
        return [r for r in snap["rules"]
                if r["state"] == FIRING and r["critical"]]

    def n_firing(self) -> int:
        """Count of rules currently firing (the ``slo.firing`` gauge)."""
        with self._lock:
            return sum(1 for st in self._states.values()
                       if st.state == FIRING)
