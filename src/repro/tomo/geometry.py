"""Parallel-beam tomography geometry (paper §II.B, Fig 2/3).

Full-field geometry: a parallel x-ray beam traverses the sample; the
detector records a 2-D projection at each rotation angle θ ∈ [0, π).
Raw data layout follows the paper's NeXus convention: (θ, y, x) with x
the detector column (sinogram detector axis) and y the detector row
(slice axis).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ParallelGeometry:
    n_angles: int
    n_det: int                 # detector columns (x)
    n_rows: int = 1            # detector rows (y) = number of slices
    angle_start: float = 0.0
    angle_end: float = math.pi  # [0, π) single scan
    det_spacing: float = 1.0
    centre_offset: float = 0.0  # rotation-centre mis-set, in pixels

    @property
    def angles(self) -> np.ndarray:
        return np.linspace(self.angle_start, self.angle_end, self.n_angles,
                           endpoint=False, dtype=np.float64)

    @property
    def centre(self) -> float:
        return (self.n_det - 1) / 2.0 + self.centre_offset

    def image_shape(self, n: int | None = None) -> tuple[int, int]:
        n = n or self.n_det
        return (n, n)

    def scaled(self, factor: int) -> "ParallelGeometry":
        return ParallelGeometry(self.n_angles // factor,
                                self.n_det // factor,
                                max(1, self.n_rows // factor),
                                self.angle_start, self.angle_end,
                                self.det_spacing * factor,
                                self.centre_offset / factor)
