"""Distributed substrate: checkpointing (incl. elastic restore),
compression (properties), straggler monitor, sharding-rule assignment,
roofline HLO parsing."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.distributed import (CheckpointManager, StragglerMonitor,
                               dequantise_int8, quantise_int8)
from repro.distributed.param_sharding import spec_for
from repro.models.sharding import make_rules
from repro.roofline import analyse, collective_bytes


# ------------------------------------------------------------ checkpoint
def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    cm.save(5, tree, extra={"note": "x"}, blocking=True)
    restored, man = cm.restore(tree)
    assert man["step"] == 5 and man["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path, rng):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        cm.save(s, tree, blocking=True)
    assert cm.steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_async_then_wait(tmp_path, rng):
    cm = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    cm.save(1, tree, blocking=False)
    cm.wait()
    assert cm.latest_step() == 1


def test_checkpoint_incompatible_tree_rejected(tmp_path, rng):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree(rng), blocking=True)
    with pytest.raises(ValueError, match="leaves"):
        cm.restore({"only": jnp.zeros((2,))})


def test_checkpoint_elastic_reshard(tmp_path, rng):
    """Restore with explicit shardings (elastic restart path)."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding
    cm = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    cm.save(1, tree, blocking=True)
    sh = jax.tree.map(
        lambda _: NamedSharding(mesh, PartitionSpec()), tree)
    restored, _ = cm.restore(tree, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- compression
@given(st.integers(1, 3000), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_int8_quantisation_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray((rng.normal(size=(n,)) * scale).astype(np.float32))
    q, s = quantise_int8(x)
    xr = dequantise_int8(q, s, x.size, x.shape)
    # error bounded by half a quantisation step per block
    blocks = np.asarray(jnp.pad(x, (0, (-n) % 256)).reshape(-1, 256))
    steps = np.abs(blocks).max(1) / 127.0
    err = np.abs(np.asarray(xr - x))
    err_blocks = np.pad(err, (0, (-n) % 256)).reshape(-1, 256)
    assert np.all(err_blocks.max(1) <= steps * 0.51 + 1e-7)


def test_error_feedback_reduces_bias(rng):
    from repro.distributed import quantise_tree
    g = {"w": jnp.asarray(rng.normal(size=(512,)).astype(np.float32))}
    res = None
    acc = np.zeros(512)
    for _ in range(50):
        _, deq, res = quantise_tree(g, res)
        acc += np.asarray(deq["w"])
    # accumulated dequantised grads converge to 50x true grad
    np.testing.assert_allclose(acc / 50, np.asarray(g["w"]), atol=2e-3)


def test_compressed_psum_single_device():
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from repro.distributed import compressed_psum
    x = jnp.asarray(np.random.default_rng(0).normal(size=(300,))
                    .astype(np.float32))
    y = compressed_psum(x, mesh, axis="pod")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=2e-2)


# -------------------------------------------------------------- straggler
def test_straggler_detection_and_eviction():
    warns, evicts = [], []
    m = StragglerMonitor(window=16, factor=2.0, patience=2,
                         on_warn=warns.append, on_evict=evicts.append)
    for i in range(8):
        m.observe(i, 1.0)
    m.observe(8, 3.0)        # warn
    m.observe(9, 3.5)        # evict (2 consecutive)
    assert len(warns) == 1 and len(evicts) == 1
    assert evicts[0].ratio >= 2.0


def test_straggler_recovers():
    m = StragglerMonitor(window=16, factor=2.0, patience=3)
    for i in range(8):
        m.observe(i, 1.0)
    m.observe(8, 5.0)
    m.observe(9, 1.0)        # back to normal resets patience
    assert m._consecutive == 0


def test_straggler_timer_interface():
    m = StragglerMonitor()
    m.start_step(1)
    ev = m.end_step(wall=0.01)
    assert ev is None


# ------------------------------------------------------ sharding rules
def test_rules_divisibility_gate():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    r = make_rules(mesh)
    # size-1 mesh axes never shard
    assert tuple(r.divisible_spec((8, 16), "batch", "ffn")) == (None, None)


def test_rules_kv_seq_fallback():
    """When kv_heads can't take `model`, the cache seq dim should."""
    import os
    # build a fake 4-way model mesh out of a reshaped 1-device mesh is
    # impossible on 1 device; test the pure logic with mesh=None rules
    # via spec() and a crafted 2x2... skip if <4 devices.
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    r = make_rules(None)
    spec = r.spec("batch", "kv_heads", "kv_seq", None)
    # without a mesh, spec keeps the declared preferences
    assert spec[1] == "model" and spec[2] is None  # model consumed once


def test_param_spec_assignment():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.tree_util import DictKey
    s = spec_for((DictKey("layers"), DictKey("attn"), DictKey("wq")),
                 (4, 64, 8, 16), mesh)
    assert isinstance(s, PartitionSpec)


# ------------------------------------------------------------- roofline
HLO = """
ENTRY %main {
  %p0 = bf16[8,128] parameter(0)
  %ag = bf16[8,2048] all-gather(%p0), dimensions={1}
  %ar = f32[16,16] all-reduce(%x), to_apply=%sum
  %rs = f32[4,16] reduce-scatter(%y), dimensions={0}
  %a2a = bf16[8,64] all-to-all(%z), dimensions={0}
  %cp = u8[100] collective-permute(%w), source_target_pairs={{0,1}}
  %ags = bf16[2,4] all-gather-start(%q), dimensions={0}
  %dot = f32[8,8] dot(%a, %b)
}
"""


def test_collective_bytes_parsing():
    cb = collective_bytes(HLO)
    assert cb["all-gather"] == 8 * 2048 * 2 + 2 * 4 * 2
    assert cb["all-reduce"] == 16 * 16 * 4
    assert cb["reduce-scatter"] == 4 * 16 * 4
    assert cb["all-to-all"] == 8 * 64 * 2
    assert cb["collective-permute"] == 100
    assert cb["count"] == 6


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    r = analyse(cost, HLO, n_devices=4, model_flops=197e12 * 2)
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 2.0) < 1e-6
    assert r.bottleneck == "memory"
    assert 0 < r.useful_ratio <= 1.0
