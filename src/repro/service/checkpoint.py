"""Pipeline checkpoint/resume — Savu's MPI checkpointing, service-grade.

Savu checkpoints a run by keeping every intermediate parallel-HDF5 file
plus a NeXus file that links them; a killed job restarts at the last
finished plugin.  Here each job gets a directory under the store root
holding

* ``checkpoint.nxs.json`` — the **manifest v2**: chain signature,
  completed plugin steps, the required-live dataset set, and one entry
  per surviving dataset (name, shape, dtype, provenance, patterns, file
  link, chunk layout, per-checkpoint chunk increment),
* one ``<dataset>.ckpt`` per surviving dataset — a chunk-addressed file
  (:class:`~repro.core.transport.ChunkedFile` layout, chunks chosen by
  the paper's §IV.A optimiser) standing in for parallel HDF5.

Incremental behaviour (the paper's O(frames)-not-O(dataset) guarantee):

* a dataset whose backing already IS a :class:`ChunkedFile`
  (``ChunkedFileTransport`` jobs) is checkpointed by flushing its dirty
  chunks and **hard-linking** the backing file into the checkpoint
  directory — no dense round-trip through RAM, and steady-state
  checkpoints write only the dirty-chunk bytes;
* a dense dataset (numpy / jax backing) is written as a chunk file once,
  at the step that produced it; later checkpoints that still see the
  same version (same ``produced_by``) reuse the file and write nothing.

``format="npy"`` keeps the v1 dense writer (one ``.npy`` per dataset,
rewritten every checkpoint) for comparison benchmarks, and ``restore``
still reads v1 manifests/files, so old checkpoints stay resumable.

Correctness is liveness-driven: the runner's
:meth:`~repro.core.framework.PluginRunner.required_live_names` names
exactly the datasets a resume needs.  ``save`` refuses to checkpoint
past a required dataset whose device buffer was donated (that would be
an unresumable checkpoint), and ``restore`` raises
:class:`CheckpointError` — loudly, not a silent "start over" — when a
required dataset is absent or unreadable.  Manifest writes stay atomic
(tmp + rename) so a kill mid-checkpoint leaves the previous consistent
state; hard-linked chunk files trade that atomicity for zero-copy
checkpoints of write-once datasets.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any

import numpy as np

from ..core.chunking import DEFAULT_CACHE_BYTES, naive_chunks, \
    optimise_chunks
from ..core.dataset import DataSet
from ..core.framework import PluginRunner
from ..core.transport import ChunkedFile
from .job import chain_signature


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot produce a correct resume."""


def _sig_str(sig: tuple) -> str:
    return json.dumps(sig, sort_keys=True)


class CheckpointStore:
    """Per-job checkpoint directories under one root (module docstring
    has the format; spec in ``docs/checkpoint-format.md``)."""

    def __init__(self, root: str, format: str = "chunked",
                 cache_bytes: int = DEFAULT_CACHE_BYTES):
        """Args:
            root: directory holding one subdirectory per job id
                (created if missing).
            format: ``"chunked"`` (manifest v2, incremental) or
                ``"npy"`` (v1 dense rewrite, for comparison).
            cache_bytes: chunk-cache budget for checkpoint file I/O.

        Raises:
            ValueError: unknown ``format``.
        """
        if format not in ("chunked", "npy"):
            raise ValueError(f"unknown checkpoint format {format!r}")
        self.root = root
        self.format = format
        self.cache_bytes = cache_bytes
        self.last_stats: dict[str, Any] = {}
        os.makedirs(root, exist_ok=True)

    def _dir(self, job_id: str) -> str:
        return os.path.join(self.root, job_id)

    def _manifest_path(self, job_id: str) -> str:
        return os.path.join(self._dir(job_id), "checkpoint.nxs.json")

    # -- layout choice ---------------------------------------------------
    def _layout(self, ds: DataSet) -> tuple[int, ...]:
        itemsize = np.dtype(ds.dtype).itemsize
        if ds.patterns:
            now = next(iter(ds.patterns.values()))
            return optimise_chunks(ds.shape, now, None, itemsize=itemsize,
                                   cache_bytes=self.cache_bytes)
        return naive_chunks(ds.shape, itemsize, self.cache_bytes)

    # ------------------------------------------------------------------
    def save(self, job_id: str, runner: PluginRunner) -> dict[str, Any]:
        """Persist the registry of surviving datasets + completion state
        after a finished plugin step.  Returns per-checkpoint IO stats
        (``bytes_written``, ``files_written``, ``files_linked``,
        ``chunks_written``, ``wall``)."""
        t0 = time.perf_counter()
        d = self._dir(job_id)
        os.makedirs(d, exist_ok=True)
        sig = _sig_str(chain_signature(runner.process_list))
        prev = self.load(job_id)
        prev_entries = {}
        if prev and prev.get("chain") == sig:
            prev_entries = {e["name"]: e for e in prev.get("datasets", [])}
        required = runner.required_live_names(runner.current_step)

        entries = []
        st = {"bytes_written": 0, "files_written": 0, "files_linked": 0,
              "files_reused": 0, "chunks_written": 0}
        for name, ds in runner.datasets.items():
            if not ds.is_populated:
                continue
            if getattr(ds.backing, "is_deleted", None) and \
                    ds.backing.is_deleted():
                # a donated device buffer is dead the moment its FINAL
                # consumer ran — liveness guarantees nothing downstream
                # (or in a resume) needs it, so it may be skipped; a dead
                # *required* dataset means liveness was bypassed and the
                # checkpoint would be unresumable: refuse loudly.
                if name in required:
                    raise CheckpointError(
                        f"dataset {name!r} is required to resume job "
                        f"{job_id!r} from step {runner.current_step} but "
                        f"its device buffer was donated — transport "
                        f"donation must respect PluginData.last_use")
                continue
            entry = {
                "name": name, "shape": list(ds.shape),
                "dtype": str(np.dtype(ds.dtype)),
                "axis_labels": list(ds.axis_labels),
                "produced_by": ds.produced_by,
                "patterns": sorted(ds.patterns)}
            if self.format == "npy":
                self._save_npy(d, name, ds, runner, entry, st)
            elif isinstance(ds.backing, ChunkedFile):
                self._save_linked(d, name, ds.backing, entry, st)
            else:
                self._save_dense(d, name, ds, runner, entry,
                                 prev_entries.get(name), st)
            entries.append(entry)

        manifest = {
            "version": 2,
            "job_id": job_id,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "chain": sig,
            "completed_steps": runner.current_step,
            "n_steps": runner.n_steps,
            "step_labels": runner.step_labels(),
            "required": sorted(required),
            "datasets": entries,
        }
        stream = runner.stream_state()
        if stream is not None:
            # streaming job (docs/streaming.md): persist the ingest
            # watermark so a resume re-fetches frames from where this
            # worker stopped.  Window cursors are NOT persisted — the
            # restored runner recomputes the windowed head from the
            # saved prefix (deterministic per-frame kernels).
            manifest["stream"] = stream
        tmp = self._manifest_path(job_id) + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(manifest, fh, indent=2)
        os.replace(tmp, self._manifest_path(job_id))
        st["wall"] = time.perf_counter() - t0
        self.last_stats = st
        return st

    # -- writers ---------------------------------------------------------
    def _save_npy(self, d: str, name: str, ds: DataSet,
                  runner: PluginRunner, entry: dict, st: dict) -> None:
        """v1 dense path: one .npy per dataset, rewritten every time."""
        arr = np.asarray(runner.transport.read(ds))
        path = os.path.join(d, f"{name}.npy")
        tmp = path + ".tmp"
        with open(tmp, "wb") as fh:
            np.save(fh, arr)
        os.replace(tmp, path)
        entry.update(file=os.path.basename(path), format="npy")
        st["bytes_written"] += arr.nbytes
        st["files_written"] += 1

    def _save_linked(self, d: str, name: str, backing: ChunkedFile,
                     entry: dict, st: dict) -> None:
        """ChunkedFile backing: flush dirty chunks, hard-link the backing
        file — the checkpoint shares the inode, so steady-state cost is
        the dirty-chunk flush, not a dense volume round-trip."""
        path = os.path.join(d, f"{name}.ckpt")
        b0 = backing.stats.bytes_written
        dirty = sorted(backing.dirty)
        backing.flush()
        st["bytes_written"] += backing.stats.bytes_written - b0
        same = os.path.exists(path) and \
            os.path.samefile(backing.path, path)
        if same:
            chunks: Any = dirty           # increment only
            st["files_reused"] += 1
        else:
            try:
                tmp = path + ".tmp"
                if os.path.exists(tmp):
                    os.remove(tmp)
                os.link(backing.path, tmp)
                os.replace(tmp, path)
                st["files_linked"] += 1
            except OSError:               # cross-device: fall back to copy
                tmp = path + ".tmp"       # atomic, like the dense writers
                shutil.copyfile(backing.path, tmp)
                os.replace(tmp, path)
                st["bytes_written"] += os.path.getsize(path)
                st["files_written"] += 1
            chunks = "all"
        backing.mark_clean()
        n_chunks = int(np.prod(backing.grid))
        st["chunks_written"] += (n_chunks if chunks == "all"
                                 else len(chunks))
        entry.update(file=os.path.basename(path), format="chunked",
                     layout=list(backing.chunks), chunks_written=chunks)

    def _save_dense(self, d: str, name: str, ds: DataSet,
                    runner: PluginRunner, entry: dict,
                    prev: dict | None, st: dict) -> None:
        """Dense (numpy/jax) backing: write a chunk-addressed file with a
        §IV.A-optimised layout — once.  Dataset versions are write-once
        (a plugin's out replaces its in), so a later checkpoint that sees
        the same ``produced_by`` reuses the file untouched."""
        path = os.path.join(d, f"{name}.ckpt")
        if (prev is not None and prev.get("format") == "chunked"
                and prev.get("produced_by") == ds.produced_by
                and prev.get("shape") == list(ds.shape)
                and prev.get("dtype") == str(np.dtype(ds.dtype))
                and ds.available_extent is None
                and os.path.exists(path)):
            entry.update(file=prev["file"], format="chunked",
                         layout=list(prev["layout"]), chunks_written=[])
            st["files_reused"] += 1
            return
        arr = np.asarray(runner.transport.read(ds))
        layout = self._layout(ds)
        tmp = path + ".tmp"
        cf = ChunkedFile(tmp, ds.shape, ds.dtype, layout,
                         cache_bytes=self.cache_bytes)
        cf.write_all(arr)
        os.replace(tmp, path)
        entry.update(file=os.path.basename(path), format="chunked",
                     layout=list(cf.chunks), chunks_written="all")
        st["bytes_written"] += arr.nbytes
        st["files_written"] += 1
        st["chunks_written"] += int(np.prod(cf.grid))

    # ------------------------------------------------------------------
    def load(self, job_id: str) -> dict[str, Any] | None:
        """Read a job's manifest as a dict (None if absent/corrupt —
        callers treat both as "no checkpoint")."""
        try:
            with open(self._manifest_path(job_id)) as fh:
                return json.load(fh)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def restore(self, job_id: str, runner: PluginRunner) -> int:
        """Fast-forward a PREPARED-or-fresh runner to the checkpointed
        step, reloading surviving dataset contents.  Returns the number
        of plugin steps skipped (0 = no usable checkpoint: absent, for a
        different chain, or a different step basis).  Raises
        :class:`CheckpointError` when the checkpoint matches this chain
        but a dataset the resume REQUIRES is missing or unreadable —
        resuming would silently feed garbage to a downstream plugin."""
        man = self.load(job_id)
        if man is None:
            return 0
        runner.prepare()
        if man["chain"] != _sig_str(chain_signature(runner.process_list)):
            return 0                      # different pipeline: start over
        # the step basis must match too: the same chain re-run under a
        # different fuse setting has different groups, and skipping N of
        # THOSE would skip plugins that never ran
        if (man.get("n_steps") != runner.n_steps
                or man.get("step_labels") != runner.step_labels()):
            return 0
        step = int(man["completed_steps"])
        stream = man.get("stream")
        # a streaming checkpoint at step 0 still carries real state (the
        # ingested frame prefix + watermark) and is worth restoring
        lo = 0 if stream is not None else 1
        if not lo <= step <= runner.n_steps:
            return 0
        entries = {e["name"]: e for e in man["datasets"]}
        required = runner.required_live_names(step)
        missing = sorted(required - set(entries))
        if missing:
            raise CheckpointError(
                f"checkpoint for job {job_id!r} at step {step} is missing "
                f"required dataset(s) {missing}; a resume would read "
                f"garbage — clear the checkpoint to restart from scratch")
        if stream is not None:
            # BEFORE skip_to/entry loading: enabling streaming swaps the
            # loader thunk for zeros, which would clobber loaded data if
            # done after
            runner.enable_streaming(dataset=stream["dataset"],
                                    axis=stream["axis"])
        runner.skip_to(step)
        d = self._dir(job_id)
        for name, ent in entries.items():
            ds = runner.datasets.get(name)
            if ds is None or name not in required:
                # nothing at-or-after `step` reads it — reloading would
                # pull a dead volume through RAM for no consumer
                continue
            try:
                self._load_entry(d, ent, ds)
            except (FileNotFoundError, ValueError, OSError) as e:
                raise CheckpointError(
                    f"checkpoint for job {job_id!r}: required dataset "
                    f"{name!r} is unreadable ({e})") from e
        if stream is not None:
            runner.restore_stream_state(stream)
        return step

    def _load_entry(self, d: str, ent: dict, ds: DataSet) -> None:
        path = os.path.join(d, ent["file"])
        if ent.get("format", "npy") == "npy":    # v1 compatibility
            self._assign(ds, np.load(path))
            return
        shape = tuple(int(s) for s in ent["shape"])
        layout = tuple(int(c) for c in ent["layout"])
        if (isinstance(ds.backing, ChunkedFile)
                and ds.backing.shape == shape
                and ds.backing.chunks == layout
                and ds.backing.dtype == np.dtype(ent["dtype"])):
            ds.backing.load_from(path)    # file-level copy, O(1) RAM
            return
        src = ChunkedFile(path, shape, ent["dtype"], layout,
                          cache_bytes=self.cache_bytes, mode="r")
        self._assign(ds, src.read_all())

    @staticmethod
    def _assign(ds: DataSet, arr: np.ndarray) -> None:
        if hasattr(ds.backing, "write_all"):
            ds.backing.write_all(arr)
        else:
            ds.backing = arr

    def clear(self, job_id: str) -> None:
        """Delete a job's checkpoint directory (called on successful
        completion; idempotent)."""
        shutil.rmtree(self._dir(job_id), ignore_errors=True)
