"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
(arXiv:2411.15242; hf).

38L d_model=2048, shared attn 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  The shared transformer block's weights are reused at
every application (Zamba's parameter-sharing trick, attn_every=6:
6 groups of 6 mamba layers + shared block, then 2 trailing mamba
layers).  Sub-quadratic backbone: eligible for long_500k.
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "zamba2-1.2b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, ssm_heads=64, ssm_expand=2, conv_width=4,
    attn_every=6, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=293, head_dim=16,
    ssm_state=16, ssm_heads=4, ssm_expand=2, conv_width=4,
    attn_every=3, dtype=jnp.float32, remat=False)
