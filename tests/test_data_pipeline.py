"""LM data as Savu loader plugins + restart-safe streams."""
import numpy as np

from repro.data import SyntheticTokenLoader, TokenBatcher, token_stream


def test_token_stream_deterministic_and_restart_safe():
    a = token_stream(100, 4, 8, seed=7, step=3)
    b = token_stream(100, 4, 8, seed=7, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_stream(100, 4, 8, seed=7, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted with -1 terminator
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert np.all(a["labels"][:, -1] == -1)


def test_loader_plugin_and_batcher():
    ld = SyntheticTokenLoader(out_datasets=["tokens"],
                              vocab=50, samples=12, seq=16, seed=1)
    (ds,) = ld.load()
    assert ds.shape == (12, 16)
    assert "BATCH" in ds.patterns
    batches = list(TokenBatcher(ds, global_batch=4))
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (4, 16)
    assert np.all(batches[0]["tokens"] < 50)
