"""granite-8b [dense] — llama-arch code model (arXiv:2405.04324; hf).

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "granite-8b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, head_dim=128,
    rope_theta=10_000.0, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=257, head_dim=16,
    dtype=jnp.float32, remat=False)
