"""Sustained-load proof for the service layer: an OPEN-LOOP harness.

Closed-loop benchmarks (submit, wait, submit...) let a slow server set
its own pace and hide queueing collapse.  This harness submits a mixed
stream of solo jobs and parameter sweeps at a FIXED arrival rate
against a broker-mode service with N worker subprocesses, regardless
of how the backlog looks — then reports what the paper's service story
must sustain:

* throughput (completed jobs/s over the busy interval),
* client-observed end-to-end latency p50/p99 (``finished_at -
  submitted_at`` from job snapshots — includes queueing),
* the queue-depth time series sampled from ``GET /stats`` (the
  open-loop tell: a stable system plateaus, an overloaded one grows
  without bound),
* lease expiries + requeues (zero under healthy load),

and writes ``BENCH_service.json``.  It also asserts that ``/metrics``
exposes every catalogued metric name — exiting nonzero on a miss, so
CI catches a metric that silently fell off the exposition.

The health-plane row (``run_health``) kills a worker mid-job and
proves the full observability story on a live cluster: the critical
``lease-expiry-rate`` SLO rule fires and resolves, the event log holds
the job's complete submit→lease→expire→requeue→complete chain on ONE
trace id (and every record carries a trace id — nonzero exit
otherwise), ``GET /slo`` serves every default rule, the OTLP export
matches the native trace span-for-span, and cost-analysis workers
stamp ``flops`` / ``bytes_accessed`` / ``peak_memory`` onto process
spans.  It writes ``BENCH_events.json`` and ``BENCH_otlp_trace.json``
for the CI artifact upload.

Standalone:   PYTHONPATH=src python benchmarks/bench_load.py
CI smoke:     PYTHONPATH=src python benchmarks/bench_load.py --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
import urllib.request

from repro.obs import catalogue_names, prometheus_name
from repro.service import PipelineClient, PipelineService
from repro.service.worker import spawn_local_workers
from repro.tomo import standard_chain


def _spec(seed: int, *, n_det: int, n_angles: int):
    return standard_chain(n_det=n_det, n_angles=n_angles, n_rows=1,
                          use_pallas=False, seed=seed)


class _StatsSampler(threading.Thread):
    """Poll ``GET /stats`` on a fixed period; keep (t, queue depth,
    active leases) samples."""

    def __init__(self, client: PipelineClient, period: float = 0.2):
        super().__init__(daemon=True)
        self.client, self.period = client, period
        self.samples: list[dict] = []
        self._halt = threading.Event()

    def run(self):
        t0 = time.time()
        while not self._halt.is_set():
            try:
                st = self.client.stats()
                self.samples.append({
                    "t": round(time.time() - t0, 3),
                    "queue_depth": st["queue"]["depth"],
                    "oldest_pending_age":
                        st["queue"]["oldest_pending_age"],
                    "active_leases": st.get("active_leases", 0)})
            except Exception:
                pass                       # server mid-shutdown: stop soon
            self._halt.wait(self.period)

    def stop(self):
        self._halt.set()


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank on a pre-sorted list (same rule as obs.Histogram)."""
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


def check_metrics_complete(url: str) -> list[str]:
    """Every catalogued metric must appear on ``/metrics``.  Returns
    the missing names (CI fails on any)."""
    with urllib.request.urlopen(f"{url}/metrics", timeout=30) as resp:
        text = resp.read().decode("utf-8")
    return [n for n in catalogue_names()
            if prometheus_name(n) not in text]


def run_load(*, n_jobs: int, rate: float, n_workers: int,
             sweep_every: int, sweep_points: int, n_det: int,
             n_angles: int, lease_ttl: float = 10.0) -> dict:
    svc = PipelineService(workers_remote=True, lease_ttl=lease_ttl,
                          sweep_interval=0.2)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(url, n_workers, transport="inmemory",
                                  poll=0.05, heartbeat=1.0)
    sampler = _StatsSampler(client)
    try:
        # workers online before the clock starts
        deadline = time.time() + 60
        while len(client.workers()) < n_workers:
            assert time.time() < deadline, "workers never registered"
            time.sleep(0.05)
        sampler.start()

        # -- open loop: fixed arrival times, submit on schedule even
        # if the backlog grows ------------------------------------------
        job_ids: list[str] = []
        sweep_ids: list[str] = []
        late = 0
        t0 = time.time()
        for i in range(n_jobs):
            due = t0 + i / rate
            lag = due - time.time()
            if lag > 0:
                time.sleep(lag)
            else:
                late += 1
            if sweep_every and i % sweep_every == sweep_every - 1:
                reply = client.sweep(
                    _spec(i, n_det=n_det, n_angles=n_angles),
                    {"plugin": "sinogram_filter", "param": "cutoff",
                     "values": [0.5 + 0.4 * k / max(1, sweep_points - 1)
                                for k in range(sweep_points)]})
                sweep_ids.append(reply["sweep_id"])
                job_ids.extend(reply["job_ids"])
            else:
                job_ids.append(client.submit(
                    _spec(i, n_det=n_det, n_angles=n_angles),
                    priority=i % 3))
        submit_wall = time.time() - t0

        # -- drain: wait for every submission ----------------------------
        snaps = [client.wait(j, timeout=600) for j in job_ids]
        bad = [s for s in snaps if s["state"] != "done"]
        assert not bad, f"{len(bad)} jobs not done, first: {bad[0]}"
        sampler.stop()
        sampler.join(timeout=5)

        lats = sorted(s["finished_at"] - s["submitted_at"]
                      for s in snaps)
        busy = max(s["finished_at"] for s in snaps) \
            - min(s["submitted_at"] for s in snaps)
        st = client.stats()
        depths = [s["queue_depth"] for s in sampler.samples] or [0]
        return {
            "config": {"n_submissions": n_jobs, "arrival_rate": rate,
                       "n_workers": n_workers,
                       "sweep_every": sweep_every,
                       "sweep_points": sweep_points,
                       "n_det": n_det, "n_angles": n_angles},
            "n_jobs_completed": len(snaps),
            "n_sweeps": len(sweep_ids),
            "late_submissions": late,
            "submit_wall_s": round(submit_wall, 3),
            "busy_wall_s": round(busy, 3),
            "throughput_jobs_per_s": round(len(snaps) / busy, 3),
            "latency_p50_s": round(_percentile(lats, 0.5), 4),
            "latency_p99_s": round(_percentile(lats, 0.99), 4),
            "latency_max_s": round(lats[-1], 4),
            "queue_depth_max": max(depths),
            "queue_depth_final": depths[-1],
            "queue_depth_series": sampler.samples[:500],
            "leases_expired": st["leases_expired"],
            "jobs_requeued": st["jobs_requeued"],
            "server_metrics": {
                k: v for k, v in st["metrics"].items()
                if k.startswith(("job.latency", "plugin.wall"))},
            "metrics_missing": check_metrics_complete(url),
        }
    finally:
        sampler.stop()
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


def run_stream(*, n_det: int, n_angles: int, chunk: int = 6,
               rate: float = 8.0) -> dict:
    """Streaming-acquisition smoke (docs/streaming.md): one v2
    streaming job on a scheduler-mode service, frames POSTed at a fixed
    chunk rate, and after each chunk the time until ``GET
    /jobs/{id}/preview`` covers the new watermark — the
    ingest-to-preview latency a beamline operator would see."""
    from repro.service import ServiceError, to_spec

    svc = PipelineService(n_workers=1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    try:
        pl = _spec(0, n_det=n_det, n_angles=n_angles)
        entry = pl.entries[0]
        loader = entry.cls(**entry.params,
                           in_datasets=list(entry.in_datasets),
                           out_datasets=list(entry.out_datasets))
        frames = loader.load()[0].materialise()
        jid = client.submit({**to_spec(pl), "version": 2,
                             "streaming": True})
        lags: list[float] = []
        t0 = time.time()
        for i, lo in enumerate(range(0, frames.shape[0], chunk)):
            due = t0 + i / rate
            if due - time.time() > 0:
                time.sleep(due - time.time())
            out = client.ingest(jid, frames[lo:lo + chunk], lo)
            fed_at, watermark = time.time(), out["watermark"]
            # poll until the preview has folded this chunk in
            while True:
                try:
                    _, cut = client.preview(jid)
                    if cut >= watermark:
                        break
                except ServiceError as e:
                    if e.status != 409:          # 409: not started yet
                        raise
                assert time.time() - fed_at < 60, "preview never caught up"
                time.sleep(0.01)
            lags.append(time.time() - fed_at)
        client.eof(jid)
        snap = client.wait(jid, timeout=120)
        assert snap["state"] == "done", snap
        lags.sort()
        return {
            "config": {"n_det": n_det, "n_angles": n_angles,
                       "chunk": chunk, "rate": rate},
            "n_chunks": len(lags),
            "stream_wall_s": round(snap["finished_at"]
                                   - snap["submitted_at"], 3),
            "ingest_to_preview_p50_s": round(_percentile(lags, 0.5), 4),
            "ingest_to_preview_p99_s": round(_percentile(lags, 0.99), 4),
            "metrics_missing": check_metrics_complete(url),
        }
    finally:
        svc.stop()


def _downsample_spec(parent: str, factor: int = 2) -> dict:
    return {"version": 1, "plugins": [
        {"plugin": "upstream_loader",
         "params": {"data": {"from_job": parent, "dataset": "recon"}},
         "out_datasets": ["vol"]},
        {"plugin": "downsample", "params": {"factor": factor},
         "in_datasets": ["vol"], "out_datasets": ["small"]},
        {"plugin": "hdf5_saver", "in_datasets": ["small"]}]}


def _quantify_spec(parent: str) -> dict:
    return {"version": 1, "plugins": [
        {"plugin": "upstream_loader",
         "params": {"data": {"from_job": parent, "dataset": "small"}},
         "out_datasets": ["vol"]},
        {"plugin": "quantify",
         "in_datasets": ["vol"], "out_datasets": ["stats"]},
        {"plugin": "hdf5_saver", "in_datasets": ["stats"]}]}


def run_workflow(*, n_det: int, n_angles: int, n_workers: int = 2) -> dict:
    """Workflow-DAG smoke (docs/workflows.md): the 3-stage
    recon -> downsample -> quantify DAG as ONE ``POST /workflows``
    against a broker with worker subprocesses, vs the same stages
    submitted sequentially (submit, wait, submit, wait...) — the
    dependency-aware queue should hide the client round-trips."""
    import numpy as np

    from repro.service import to_spec

    svc = PipelineService(workers_remote=True, lease_ttl=10.0,
                          sweep_interval=0.2)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(url, n_workers, transport="inmemory",
                                  poll=0.05, heartbeat=1.0)
    recon = to_spec(_spec(0, n_det=n_det, n_angles=n_angles))
    try:
        deadline = time.time() + 60
        while len(client.workers()) < n_workers:
            assert time.time() < deadline, "workers never registered"
            time.sleep(0.05)
        # sequential first: it doubles as the warm-up, so the DAG row
        # measures orchestration, not first-compile cost
        t0 = time.time()
        j1 = client.submit(recon)
        assert client.wait(j1, timeout=300)["state"] == "done"
        j2 = client.submit(_downsample_spec(j1))
        assert client.wait(j2, timeout=300)["state"] == "done"
        j3 = client.submit(_quantify_spec(j2))
        assert client.wait(j3, timeout=300)["state"] == "done"
        seq_wall = time.time() - t0

        t0 = time.time()
        client.workflow({
            "recon": {"process_list": recon},
            "downsample": {"process_list": _downsample_spec("recon")},
            "quantify": {"process_list": _quantify_spec("downsample")},
        }, workflow_id="bench-wf")
        snap = client.wait_workflow("bench-wf", timeout=300)
        dag_wall = time.time() - t0
        assert snap["state"] == "done", snap
        np.testing.assert_array_equal(
            client.result("bench-wf/quantify", "stats"),
            client.result(j3, "stats"))
        return {
            "config": {"n_det": n_det, "n_angles": n_angles,
                       "n_workers": n_workers, "n_stages": 3},
            "dag_e2e_s": round(dag_wall, 3),
            "sequential_e2e_s": round(seq_wall, 3),
            "speedup": round(seq_wall / dag_wall, 3),
            "metrics_missing": check_metrics_complete(url),
        }
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


def run_cold_worker(*, n_det: int, n_angles: int) -> dict:
    """The retrace-tax proof (docs/worker-protocol.md): first-job e2e
    latency of a COLD sharded worker that must jit-compile the standard
    chain, vs a FRESH worker that prefetched the broker's warm pool at
    registration and only deserializes.  The prefetched worker's first
    job must be >= 3x faster and its trace must show ``executable.fetch``
    with NO ``compile`` span."""
    import tempfile

    svc = PipelineService(workers_remote=True, lease_ttl=30.0,
                          sweep_interval=0.2,
                          executables_dir=tempfile.mkdtemp(
                              prefix="bench-exe-spool-"))
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=120.0)
    # paganin widens the chain to 5 compiled plugins: more retrace tax
    # on the cold side, milliseconds of extra deserialize on the warm
    spec = standard_chain(n_det=n_det, n_angles=n_angles, n_rows=1,
                          use_pallas=False, paganin=True, seed=0)

    def first_job_e2e(wid: str) -> tuple[float, list[str]]:
        """Spawn ONE fresh sharded worker (its own empty local
        executable tier), run one standard-chain job on it, return the
        client-observed e2e latency and the job's span names."""
        workers = spawn_local_workers(url, 1, transport="sharded",
                                      poll=0.02, heartbeat=5.0,
                                      worker_ids=[wid])
        try:
            deadline = time.time() + 120
            while wid not in client.workers():
                assert time.time() < deadline, "worker never registered"
                time.sleep(0.05)
            jid = client.submit(spec)
            snap = client.wait(jid, timeout=300)
            assert snap["state"] == "done", snap
            spans = [s["name"] for s in client.trace(jid)["spans"]]
            return snap["finished_at"] - snap["submitted_at"], spans
        finally:
            for p in workers:
                if p.poll() is None:
                    p.kill()
            for p in workers:
                p.wait(timeout=10)

    try:
        cold_s, cold_spans = first_job_e2e("bench-cold")
        assert "compile" in cold_spans, \
            f"cold worker never compiled? spans: {cold_spans}"
        st = svc.broker.executables.stats()
        assert st["entries"] >= 1, "cold worker uploaded nothing"

        # one retry guards the ratio against a CI scheduling hiccup on
        # the warm side (each attempt is still a fully fresh worker)
        for attempt in range(2):
            warm_s, warm_spans = first_job_e2e(
                f"bench-prefetched-{attempt}")
            assert "executable.fetch" in warm_spans, \
                f"prefetched worker never fetched: {warm_spans}"
            assert "compile" not in warm_spans, \
                f"prefetched worker still compiled: {warm_spans}"
            if cold_s / warm_s >= 3.0:
                break
        speedup = cold_s / warm_s
        assert speedup >= 3.0, \
            f"warm pool too slow: cold {cold_s:.3f}s vs " \
            f"prefetched {warm_s:.3f}s ({speedup:.2f}x < 3x)"
        return {
            "config": {"n_det": n_det, "n_angles": n_angles},
            "cold_first_job_e2e_s": round(cold_s, 4),
            "prefetched_first_job_e2e_s": round(warm_s, 4),
            "speedup": round(speedup, 2),
            "spool": svc.broker.executables.stats(),
            "metrics_missing": check_metrics_complete(url),
        }
    finally:
        svc.stop()


def run_health(*, n_det: int, n_angles: int,
               events_out: str = "BENCH_events.json",
               otlp_out: str = "BENCH_otlp_trace.json") -> dict:
    """The health-plane proof (docs/observability.md): kill a sharded
    cost-analysis worker mid-job and verify the SLO lifecycle, the
    event-log transition chain, the OTLP export's 1:1 span mapping,
    and the per-step device profiles.  Returns a dict whose
    ``failures`` list must be empty for CI to pass."""
    import os
    import signal
    import tempfile

    from repro.obs import default_rules, iter_spans

    failures: list[str] = []
    svc = PipelineService(
        workers_remote=True, lease_ttl=1.5, sweep_interval=0.1,
        slo_interval=0.1,
        # tighten the rate window so fire->resolve happens in seconds
        slo_spec={"lease-expiry-rate": {"window_s": 4.0}})
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    ckpt = tempfile.mkdtemp(prefix="bench-health-ckpt-")
    workers = spawn_local_workers(
        url, 2, transport="sharded", checkpoint_dir=ckpt,
        poll=0.05, heartbeat=0.3, cost_analysis=True,
        worker_ids=["health-w0", "health-w1"])
    pids = dict(zip(["health-w0", "health-w1"], workers))
    try:
        deadline = time.time() + 120
        while len(client.workers()) < 2:
            assert time.time() < deadline, "workers never registered"
            time.sleep(0.05)
        assert client.health(ready=True)["ready"] is True

        # -- kill the worker holding the first lease mid-job -------------
        ids = [client.submit(_spec(i, n_det=n_det, n_angles=n_angles))
               for i in range(3)]
        while True:
            running = [s for s in (client.status(j) for j in ids)
                       if s["state"] == "running" and s["worker_id"]]
            if running:
                victim_job, victim = (running[0]["job_id"],
                                      running[0]["worker_id"])
                break
            assert time.time() < deadline, "nothing ever ran"
            time.sleep(0.02)
        os.kill(pids[victim].pid, signal.SIGKILL)

        # the critical rule must fire: readiness flips to 503
        while client.health(ready=True)["ready"]:
            assert time.time() < deadline, "expiry rule never fired"
            time.sleep(0.05)
        if "lease-expiry-rate" not in client.slo()["critical_firing"]:
            failures.append("slo_rule_never_fired")

        # the survivor drains everything (the killed job resumes from
        # its shared checkpoint or restarts)
        snaps = [client.wait(j, timeout=300) for j in ids]
        bad = [s for s in snaps if s["state"] != "done"]
        assert not bad, f"{len(bad)} jobs not done, first: {bad[0]}"
        # ...and once the rate window slides past the expiry the rule
        # resolves: readiness back to 200
        while not client.health(ready=True)["ready"]:
            assert time.time() < deadline, "expiry rule never resolved"
            time.sleep(0.1)

        # -- GET /slo: every default rule present, fire+resolve counted --
        slo = client.slo()
        by_rule = {r["name"]: r for r in slo["rules"]}
        missing_rules = [r.name for r in default_rules()
                         if r.name not in by_rule]
        if missing_rules:
            failures.append(f"slo_missing_rules:{missing_rules}")
        expiry = by_rule.get("lease-expiry-rate", {})
        if not (expiry.get("fired", 0) >= 1
                and expiry.get("resolved", 0) >= 1
                and expiry.get("state") == "ok"):
            failures.append(f"slo_lifecycle_incomplete:{expiry}")

        # -- event log: full transition chain on ONE trace id ------------
        events = client.events()["events"]
        with open(events_out, "w") as fh:
            json.dump(events, fh, indent=2)
        if any(not e["trace_id"] for e in events):
            failures.append("event_records_missing_trace_id")
        mine = [e for e in events if e["job_id"] == victim_job]
        chain = [e["event"] for e in mine]
        for needed in ("job.submit", "job.lease", "lease.expire",
                       "job.requeue", "job.complete"):
            if needed not in chain:
                failures.append(f"event_chain_missing:{needed}")
        if len({e["trace_id"] for e in mine}) != 1:
            failures.append("event_chain_trace_id_not_unique")
        for name in ("alert.firing", "alert.resolved"):
            n = sum(1 for e in events if e["event"] == name
                    and e["attrs"].get("rule") == "lease-expiry-rate")
            if n != 1:
                failures.append(f"alert_event_count:{name}={n}")

        # -- OTLP export: spans match the native trace 1:1 ---------------
        native = client.trace(victim_job)["spans"]
        otlp = client.trace(victim_job, otlp=True)
        with open(otlp_out, "w") as fh:
            json.dump(otlp, fh, indent=2)
        exported = list(iter_spans(otlp))
        if len(exported) != len(native):
            failures.append(f"otlp_span_count:{len(exported)}"
                            f"!={len(native)}")
        native_ids = {str(s["span_id"]).lower().rjust(16, "0")
                      for s in native}
        otlp_ids = {s["spanId"] for s in exported}
        if native_ids != otlp_ids:
            failures.append("otlp_span_ids_mismatch")

        # -- device profiles on jitted process spans ---------------------
        profiled = [s for s in native
                    if s["name"].startswith("plugin.")
                    and s["name"].endswith(".process")
                    and "flops" in (s.get("attrs") or {})]
        if not profiled:
            failures.append("no_process_span_with_cost_attrs")
        for key in ("bytes_accessed", "peak_memory"):
            if not any(key in s["attrs"] for s in profiled):
                failures.append(f"cost_attr_missing:{key}")

        resumed = next((s for s in snaps
                        if s["job_id"] == victim_job), {})
        st = client.stats()
        return {
            "config": {"n_det": n_det, "n_angles": n_angles},
            "leases_expired": st["leases_expired"],
            "jobs_requeued": st["jobs_requeued"],
            "victim_job_attempts": resumed.get("attempt"),
            "victim_resumed_from": resumed.get("resumed_from"),
            "slo_rules": sorted(by_rule),
            "expiry_rule": {k: expiry.get(k)
                            for k in ("fired", "resolved", "state")},
            "n_events": len(events),
            "n_spans_native": len(native),
            "n_spans_otlp": len(exported),
            "n_process_spans_profiled": len(profiled),
            "events_out": events_out, "otlp_out": otlp_out,
            "failures": failures,
            "metrics_missing": check_metrics_complete(url),
        }
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small CI config (seconds, 2 workers)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="number of submissions (solo jobs + sweeps)")
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate, submissions/s")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker subprocesses")
    ap.add_argument("--sweep-every", type=int, default=4,
                    help="every Kth submission is a sweep (0: none)")
    ap.add_argument("--sweep-points", type=int, default=3,
                    help="variants per sweep")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = dict(n_jobs=args.jobs or 8, rate=args.rate or 4.0,
                   n_workers=args.workers or 2, n_det=16, n_angles=8)
    else:
        cfg = dict(n_jobs=args.jobs or 40, rate=args.rate or 2.0,
                   n_workers=args.workers or 4, n_det=48, n_angles=48)
    result = run_load(sweep_every=args.sweep_every,
                      sweep_points=args.sweep_points, **cfg)
    result["streaming"] = run_stream(n_det=cfg["n_det"],
                                     n_angles=cfg["n_angles"])
    result["workflow"] = run_workflow(n_det=cfg["n_det"],
                                      n_angles=cfg["n_angles"],
                                      n_workers=cfg["n_workers"])
    result["cold_worker"] = run_cold_worker(n_det=cfg["n_det"],
                                            n_angles=cfg["n_angles"])
    result["health"] = run_health(n_det=cfg["n_det"],
                                  n_angles=cfg["n_angles"])

    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"{result['n_jobs_completed']} jobs "
          f"({result['n_sweeps']} sweeps) @ "
          f"{result['throughput_jobs_per_s']} jobs/s — "
          f"p50 {result['latency_p50_s']}s, "
          f"p99 {result['latency_p99_s']}s, "
          f"queue depth max {result['queue_depth_max']}, "
          f"{result['leases_expired']} lease expiries "
          f"-> {args.out}")
    sm = result["streaming"]
    print(f"streaming: {sm['n_chunks']} chunks, ingest-to-preview "
          f"p50 {sm['ingest_to_preview_p50_s']}s, "
          f"p99 {sm['ingest_to_preview_p99_s']}s")
    wf = result["workflow"]
    print(f"workflow: 3-stage DAG e2e {wf['dag_e2e_s']}s vs "
          f"sequential {wf['sequential_e2e_s']}s "
          f"({wf['speedup']}x)")
    cw = result["cold_worker"]
    print(f"cold worker: first job {cw['cold_first_job_e2e_s']}s "
          f"compiling vs {cw['prefetched_first_job_e2e_s']}s "
          f"prefetched ({cw['speedup']}x — the retrace tax)")
    hp = result["health"]
    print(f"health plane: expiry rule fired/resolved "
          f"{hp['expiry_rule']['fired']}/{hp['expiry_rule']['resolved']}"
          f", {hp['n_events']} events, {hp['n_spans_otlp']} OTLP spans "
          f"(= {hp['n_spans_native']} native), "
          f"{hp['n_process_spans_profiled']} profiled process spans "
          f"-> {hp['events_out']}, {hp['otlp_out']}")
    missing = sorted(set(result["metrics_missing"])
                     | set(sm["metrics_missing"])
                     | set(wf["metrics_missing"])
                     | set(cw["metrics_missing"])
                     | set(hp["metrics_missing"]))
    failed = False
    if missing:
        print(f"MISSING from /metrics: {missing}", file=sys.stderr)
        failed = True
    if hp["failures"]:
        print(f"HEALTH-PLANE failures: {hp['failures']}",
              file=sys.stderr)
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
