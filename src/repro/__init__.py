"""repro — Savu-in-JAX: a pattern-driven, multi-pod processing framework.

The paper's pipeline engine lives in repro.core; the tomography
substrate in repro.tomo; the LM model zoo, training/serving and
distribution layers support the assigned architecture × shape grid.
"""
__version__ = "1.0.0"
