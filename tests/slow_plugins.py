"""Wire-registered test plugins shared by the broker/worker tests.

Imported BOTH by the test process (so the server-side spec validation
knows the plugin) and by worker subprocesses via
``python -m repro.service.worker --import slow_plugins`` (so the worker
can execute it) — which also exercises the capability filter: a worker
started WITHOUT the import must never be leased a chain containing
``slow_identity``.
"""
import time

from repro.core.patterns import PROJECTION, VOLUME_XZ
from repro.core.plugin import BaseFilter
from repro.service import register_plugin


@register_plugin
class SlowIdentity(BaseFilter):
    """Pass-through that sleeps per frame call — makes a chain slow
    enough to SIGKILL a worker mid-job deterministically."""

    name = "slow_identity"
    pattern_name = PROJECTION
    frames = 1
    fusable = False
    parameters = {"delay": 0.1}

    def process_frames(self, frames):
        time.sleep(self.params["delay"])
        return frames[0]


@register_plugin
class SlowVolumeIdentity(BaseFilter):
    """Volume-pattern pass-through that sleeps per slice — slows a
    workflow's DOWNSTREAM node (which consumes an upstream VOLUME
    output, docs/workflows.md) so its worker can be SIGKILLed
    mid-node."""

    name = "slow_volume_identity"
    pattern_name = VOLUME_XZ
    frames = 1
    fusable = False
    parameters = {"delay": 0.1}

    def process_frames(self, frames):
        time.sleep(self.params["delay"])
        return frames[0]


@register_plugin
class FailingPlugin(BaseFilter):
    """Raises on the first frame — drives a workflow node to FAILED so
    the downstream cascade (cancelled(reason="upstream_failed")) can be
    asserted."""

    name = "failing_plugin"
    pattern_name = PROJECTION
    frames = 1
    fusable = False
    parameters = {"message": "injected failure"}

    def process_frames(self, frames):
        raise RuntimeError(self.params["message"])
