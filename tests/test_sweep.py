"""Parameter sweeps — Savu-style parameter tuning as a service workload.

The PR acceptance path: a sweep over N values of one tunable param
expands into N variant jobs with IDENTICAL chain signatures, admitted
atomically so the gang path batches them — exactly one compile per
plugin (cache stats), gang execution visible in scheduler/worker stats,
and a stacked ``(N, ...)`` result bit-identical to N independently
submitted solo jobs, both through the local scheduler and through
``workers_remote`` gang workers.  Plus the 400/404/409/429 error
contract, metric scoring / best_variant, group cancel, atomic
admission, and the broker result-spool GC satellite.
"""
import os

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import PluginRunner, ShardedTransport
from repro.service import (CompileCache, JobQueue, PipelineClient,
                           PipelineService, PipelineWorker, ServiceError,
                           SweepManager, chain_signature, expand_sweep,
                           parse_sweep_block, to_spec)
from repro.tomo import standard_chain

N = dict(n_det=20, n_angles=20, n_rows=1)
CUTOFFS = [0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


def _chain(seed=0, **over):
    return standard_chain(**{**N, **over}, seed=seed)


def _axis(values=CUTOFFS):
    return {"plugin": "sinogram_filter", "param": "cutoff",
            "values": list(values)}


def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _solo_ref(mesh, seed, **params):
    """Serial PluginRunner reference on the sharded transport."""
    pl = standard_chain(**N, seed=seed)
    for e in pl.entries:
        if e.cls.name == "sinogram_filter":
            e.params.update(params)
    ref = PluginRunner(pl, ShardedTransport(mesh, donate=False)).run()
    return np.asarray(ref["recon"].materialise())


# ==================================================== expansion (units)
def test_variants_share_one_chain_signature():
    axes = parse_sweep_block(_axis(), _chain())
    variants = expand_sweep(_chain(), axes)
    assert len(variants) == len(CUTOFFS)
    sigs = {chain_signature(pl) for _, pl in variants}
    assert len(sigs) == 1                  # identical chains => they gang
    assert sigs == {chain_signature(_chain())}
    for (combo, pl), want in zip(variants, CUTOFFS):
        assert combo == (want,)
        (sf,) = [e for e in pl.entries if e.cls.name == "sinogram_filter"]
        assert sf.params["cutoff"] == want


def test_two_param_grid_expands_in_c_order():
    pl = _chain(ring=True)
    axes = parse_sweep_block(
        [_axis([0.5, 1.0]),
         {"plugin": "ring_removal", "param": "strength",
          "values": [0.0, 1.0, 2.0]}], pl)
    variants = expand_sweep(pl, axes)
    assert [c for c, _ in variants] == [
        (0.5, 0.0), (0.5, 1.0), (0.5, 2.0),
        (1.0, 0.0), (1.0, 1.0), (1.0, 2.0)]   # first axis outermost
    assert len({chain_signature(p) for _, p in variants}) == 1


def test_queue_submit_many_is_atomic():
    q = JobQueue(max_pending=3)
    q.submit(_chain(seed=0))
    with pytest.raises(Exception) as ei:      # QueueFull
        q.submit_many([_chain(seed=s) for s in range(3)])
    assert "max_pending" in str(ei.value)
    assert q.pending() == 1                   # nothing admitted
    q2 = JobQueue()
    q2.submit(_chain(seed=0), job_id="dup")
    with pytest.raises(ValueError):
        q2.submit_many([_chain(seed=1), _chain(seed=2)],
                       job_ids=["fresh", "dup"])
    assert q2.pending() == 1                  # all-or-nothing held


# ============================================== acceptance path (local)
@pytest.fixture
def gang_service():
    """Gang-batching service on an ephemeral port: sharded transport,
    one shared CompileCache, batch_max wide enough for a 7-point
    sweep."""
    cache = CompileCache()
    mesh = _mesh1()
    svc = PipelineService(
        n_workers=2, compile_cache=cache, batch_identical=True,
        batch_max=8,
        transport_factory=lambda job: ShardedTransport(
            mesh, donate=False, compile_cache=cache))
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    try:
        yield svc, client, cache, mesh
    finally:
        svc.stop()


def test_sweep_bit_identical_one_compile_per_plugin(gang_service):
    """POST /sweeps with 7 values of one param: stacked (7, ...) result
    bit-identical to 7 solo jobs, exactly one compile per plugin, gang
    execution visible in /stats."""
    svc, client, cache, mesh = gang_service
    reply = client.sweep(_chain(seed=3), _axis(), metric="sharpness")
    assert reply["n_variants"] == 7 and reply["shape"] == [7]
    snap = client.wait_sweep(reply["sweep_id"], timeout=300)
    assert snap["state"] == "done", snap

    # exactly ONE compile per plugin: 4 processing steps in the chain
    # (correction, ring removal, sino filter, FBP), each compiled once
    # as the batched program — zero retrace across the 7 variants
    st = cache.stats()
    n_steps = snap["variants"][0]["n_plugins"]
    assert st["misses"] == n_steps == 4, st
    # ...and the gang path ran it (scheduler stats)
    assert client.stats()["gangs_run"] >= 1

    stacked = client.sweep_result(reply["sweep_id"])
    assert stacked.shape[0] == 7
    # bit-identical to 7 independently submitted solo jobs (same
    # service; submitted one-at-a-time so each runs the solo path)
    for k, cutoff in enumerate(CUTOFFS):
        pl = _chain(seed=3)
        for e in pl.entries:
            if e.cls.name == "sinogram_filter":
                e.params["cutoff"] = cutoff
        jid = client.submit(pl)
        assert client.wait(jid, timeout=300)["state"] == "done"
        np.testing.assert_array_equal(stacked[k], client.result(jid))

    # metric scored per variant, best surfaced
    best = snap["best_variant"]
    assert best["index"] in range(7)
    assert set(best["values"]) == {"sinogram_filter.cutoff"}
    scores = [v["score"] for v in snap["variants"]]
    assert best["score"] == max(scores)       # sharpness: higher wins


def test_sweep_two_param_grid_result_layout(gang_service):
    """A 2x2 grid stacks as (2, 2, *variant_shape), variants in C
    order."""
    svc, client, _, mesh = gang_service
    reply = client.sweep(
        _chain(seed=1),
        [_axis([0.5, 1.0]),
         {"plugin": "ring_removal", "param": "strength",
          "values": [0.0, 1.0]}])
    snap = client.wait_sweep(reply["sweep_id"], timeout=300)
    assert snap["state"] == "done", snap
    stacked = client.sweep_result(reply["sweep_id"])
    assert stacked.shape[:2] == (2, 2)
    for k, v in enumerate(snap["variants"]):
        i, j = divmod(k, 2)
        got = client.result(v["job_id"])
        np.testing.assert_array_equal(stacked[i, j], got)
    # grid corner sanity: (cutoff=1.0, strength=1.0) == plain chain
    np.testing.assert_array_equal(stacked[1, 1], _solo_ref(mesh, 1))


# ====================================================== workers_remote
def test_sweep_remote_gang_worker_bit_identical(tmp_path):
    """The same acceptance path through the broker: one gang worker
    (max_batch=7, sharded) leases the whole sweep, runs it through
    run_plugin_batch — one compile per plugin in ITS cache — and the
    stacked result is bit-identical to solo references."""
    svc = PipelineService(workers_remote=True, lease_ttl=15.0)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=60.0)
    mesh = _mesh1()
    cache = CompileCache()
    try:
        reply = client.sweep(_chain(seed=5), _axis(), metric="sharpness")
        w = PipelineWorker(
            client.base_url, worker_id="gang-w", max_batch=8,
            poll=0.01, heartbeat=1.0,
            transport_factory=lambda d: ShardedTransport(
                mesh, donate=False, compile_cache=cache))
        w.register()
        assert w.run_once() is True
        snap = client.wait_sweep(reply["sweep_id"], timeout=120)
        assert snap["state"] == "done", snap
        assert w.jobs_done == 7
        assert cache.stats()["misses"] == 4   # one compile per plugin
        stacked = client.sweep_result(reply["sweep_id"])
        assert stacked.shape[0] == 7
        for k, cutoff in enumerate(CUTOFFS):
            np.testing.assert_array_equal(
                stacked[k], _solo_ref(mesh, 5, cutoff=cutoff))
        assert snap["best_variant"] is not None
    finally:
        svc.stop()


def test_no_sweeps_worker_never_leases_variants():
    """sweep-aware capability filtering: a worker registered with
    sweeps=False leases plain jobs but never sweep variants."""
    svc = PipelineService(workers_remote=True, lease_ttl=5.0)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}")
    try:
        client.register_worker(worker_id="plain-w", sweeps=False)
        client.sweep(_chain(seed=1), _axis([0.5, 1.0]))
        assert client.lease("plain-w", max_jobs=4) == []
        jid = client.submit(_chain(seed=2))
        assert [d["job_id"] for d in client.lease("plain-w")] == [jid]
        # an unrestricted worker drains the sweep (as ONE gang lease)
        client.register_worker(worker_id="full-w", max_batch=4)
        got = client.lease("full-w", max_jobs=4)
        assert len(got) == 2
    finally:
        svc.stop()


# ======================================================== error contract
@pytest.fixture
def idle_service():
    """Service whose scheduler is stopped — jobs stay queued."""
    svc = PipelineService(n_workers=1, max_pending=8,
                          max_sweep_variants=16)
    host, port = svc.serve(port=0)
    svc.scheduler.shutdown()
    client = PipelineClient(f"http://{host}:{port}")
    try:
        yield svc, client
    finally:
        svc.stop()


def test_sweep_validation_is_400(idle_service):
    _, client = idle_service
    cases = [
        ({"plugin": "sinogram_filter", "param": "kind",
          "values": ["shepp", "hann"]}, "not sweepable"),
        ({"plugin": "fbp_recon", "param": "warp", "values": [1]},
         "no parameter"),
        ({"plugin": "ghost_plugin", "param": "x", "values": [1]},
         "matches 0 entries"),
        ({"plugin_index": 99, "param": "cutoff", "values": [1]},
         "plugin_index"),
        ({"plugin": "sinogram_filter", "param": "cutoff", "values": []},
         "non-empty"),
        ([_axis([0.5]), _axis([0.6])], "distinct"),
        ([{"plugin": "sinogram_filter", "param": "cutoff",
           "values": [0.1 * i]} for i in range(3)], "at most 2"),
    ]
    for sweep, needle in cases:
        with pytest.raises(ServiceError) as ei:
            client.sweep(_chain(), sweep)
        assert ei.value.status == 400, sweep
        assert needle in ei.value.message, (sweep, ei.value.message)
    with pytest.raises(ServiceError) as ei:
        client.sweep(_chain(), _axis([0.5]), metric="vibes")
    assert ei.value.status == 400
    assert "vibes" in ei.value.message
    # grid too wide for max_sweep_variants=16
    with pytest.raises(ServiceError) as ei:
        client.sweep(_chain(), [_axis([0.1] * 5),
                                {"plugin": "ring_removal",
                                 "param": "strength",
                                 "values": [0.1] * 5}])
    assert ei.value.status == 400
    assert "max_variants" in ei.value.message


def test_sweep_atomic_admission_is_429(idle_service):
    """A sweep that would overflow max_pending is rejected WHOLE —
    no variant sneaks in."""
    svc, client = idle_service                # max_pending=8
    client.submit(_chain(seed=0))
    client.submit(_chain(seed=1))
    before = len(client.jobs())
    with pytest.raises(ServiceError) as ei:
        client.sweep(_chain(seed=2), _axis())  # 7 variants, 2+7 > 8
    assert ei.value.status == 429
    assert len(client.jobs()) == before       # nothing admitted
    assert svc.queue.pending() == 2


def test_sweep_lifecycle_404_409(idle_service):
    svc, client = idle_service
    for call in (lambda: client.sweep_status("ghost"),
                 lambda: client.sweep_result("ghost"),
                 lambda: client.cancel_sweep("ghost")):
        with pytest.raises(ServiceError) as ei:
            call()
        assert ei.value.status == 404
    reply = client.sweep(_chain(seed=3), _axis([0.5, 1.0]),
                         sweep_id="tune-1")
    assert reply["sweep_id"] == "tune-1"
    assert reply["job_ids"] == ["tune-1/v000", "tune-1/v001"]
    # result before done is 409 (names the blocking states)
    with pytest.raises(ServiceError) as ei:
        client.sweep_result("tune-1")
    assert ei.value.status == 409
    # duplicate active sweep id is 409
    with pytest.raises(ServiceError) as ei:
        client.sweep(_chain(seed=4), _axis([0.5]), sweep_id="tune-1")
    assert ei.value.status == 409


def test_sweep_cancel_cancels_all_variants(idle_service):
    svc, client = idle_service
    reply = client.sweep(_chain(seed=1), _axis([0.4, 0.7, 1.0]))
    out = client.cancel_sweep(reply["sweep_id"])
    assert sorted(out["cancelled"]) == sorted(reply["job_ids"])
    snap = client.sweep_status(reply["sweep_id"])
    assert snap["state"] == "cancelled" and snap["all_terminal"]
    assert {v["state"] for v in snap["variants"]} == {"cancelled"}
    assert any(s["sweep_id"] == reply["sweep_id"]
               for s in client.sweeps())
    # a second cancel is a no-op, not an error
    out2 = client.cancel_sweep(reply["sweep_id"])
    assert out2["cancelled"] == []


# ================================================= spool GC (satellite)
def test_broker_spool_gc_on_history_eviction():
    """Uploaded .npy results die with their job: when max_history
    evicts a terminal job, its result spool directory is deleted."""
    svc = PipelineService(workers_remote=True, lease_ttl=15.0,
                          max_history=1)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}")
    try:
        w = PipelineWorker(client.base_url, worker_id="w0", poll=0.01)
        w.register()
        ids = []
        for s in range(3):
            jid = client.submit(_chain(seed=s))
            ids.append(jid)
            assert w.run_once() is True
            assert client.status(jid)["state"] == "done"
        spool = lambda jid: os.path.join(          # noqa: E731
            svc.broker.results_dir, jid.replace(os.sep, "_"))
        assert os.path.exists(spool(ids[-1]))
        # pruning runs at submit: this pushes the 2 oldest out
        jid = client.submit(_chain(seed=9))
        assert w.run_once() is True
        assert not os.path.exists(spool(ids[0])), "spool leaked"
        assert not os.path.exists(spool(ids[1])), "spool leaked"
        # the freshest result is still retained and streamable
        np.testing.assert_array_equal(
            client.result(jid),
            np.asarray(PluginRunner(_chain(seed=9)).run()[
                "recon"].materialise()))
        with pytest.raises(ServiceError) as ei:
            client.result(ids[0])
        assert ei.value.status == 404
    finally:
        svc.stop()
