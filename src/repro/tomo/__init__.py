# Tomography substrate: the paper's own domain (full-field parallel-beam
# CT) — geometry, synthetic scans, and the standard processing plugins.
from .geometry import ParallelGeometry
from .phantom import (forward_project, phantom_stack, shepp_logan,
                      simulate_raw_scan)
from .plugins import (DarkFlatCorrection, Downsample, FBPRecon,
                      HDF5LikeSaver, PaganinFilter, Quantify, RingRemoval,
                      SinogramFilter, SyntheticTomoLoader, UpstreamLoader)

__all__ = [
    "ParallelGeometry", "shepp_logan", "phantom_stack", "forward_project",
    "simulate_raw_scan", "SyntheticTomoLoader", "DarkFlatCorrection",
    "PaganinFilter", "RingRemoval", "SinogramFilter", "FBPRecon",
    "HDF5LikeSaver", "UpstreamLoader", "Downsample", "Quantify",
]


def standard_chain(n_det: int = 64, n_angles: int = 64, n_rows: int = 4,
                   *, paganin: bool = False, ring: bool = True,
                   noise: float = 0.0, use_pallas: bool = True,
                   seed: int = 0):
    """The paper's typical full-field process list (Figs 5–7):
    loader → correction → [paganin] → [ring removal] → sino filter →
    FBP → saver, all on one dataset name ('tomo').  ``seed`` varies the
    simulated scan so a batch of jobs processes distinct datasets."""
    from ..core.process_list import ProcessList
    pl = ProcessList()
    pl.add(SyntheticTomoLoader,
           params={"n_det": n_det, "n_angles": n_angles, "n_rows": n_rows,
                   "noise": noise, "seed": seed},
           out_datasets=("tomo",))
    pl.add(DarkFlatCorrection, params={"use_pallas": use_pallas},
           in_datasets=("tomo",), out_datasets=("tomo",))
    if paganin:
        pl.add(PaganinFilter, in_datasets=("tomo",), out_datasets=("tomo",))
    if ring:
        pl.add(RingRemoval, in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(SinogramFilter, params={"use_pallas": use_pallas},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(FBPRecon, params={"use_pallas": use_pallas},
           in_datasets=("tomo",), out_datasets=("recon",))
    pl.add(HDF5LikeSaver, in_datasets=("recon",))
    return pl
