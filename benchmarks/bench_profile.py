"""Reproduces Fig 9: the per-plugin profiler output for a full chain."""
from __future__ import annotations

from repro.core import InMemoryTransport, PluginRunner
from repro.tomo import standard_chain


def run(report):
    runner = PluginRunner(standard_chain(n_det=64, n_angles=96, n_rows=2,
                                         paganin=True),
                          InMemoryTransport())
    runner.run()
    totals = runner.profiler.totals()
    for name, wall in totals.items():
        report(f"profile_{name}", wall * 1e6, "per-plugin wall")
    print()
    print(runner.profiler.report())
    print()
