"""HTTP front end over the JobQueue — cross-process serving.

The scheduler (ROADMAP PR 1) and checkpoint/resume layer (PR 2) were
only reachable in-process; this module is the step that turns them into
facility infrastructure in the Nanosurveyor/Daisy sense: a remote
submit/monitor interface over the scheduler, so the paper's "3000
scientific users per year" can submit process lists to a pipeline they
do not run themselves.  Stdlib only (``http.server``) — no new deps.

Endpoints (JSON unless noted; see ``docs/service.md``):

==========================  ==========================================
``POST /jobs``              submit a spec envelope -> ``{"job_id"}``;
                            400 on validation errors, 409 on duplicate
                            active id, **429** on admission rejection
``GET /jobs``               every job's ``Job.snapshot()``
``GET /jobs/{id}``          one snapshot (``running(plugin i/N)``
                            progress, ``resumed_from``, ...)
``GET /jobs/{id}/result``   output dataset as ``.npy`` bytes
                            (``?dataset=`` selects; chunk-streamed)
``DELETE /jobs/{id}``       cancel a queued job (409 once dispatched)
``POST /sweeps``            expand a parameter-sweep envelope into a
                            gang of variant jobs (``docs/sweeps.md``)
``GET /sweeps[/{id}]``      sweep group status (per-variant snapshots,
                            ``best_variant`` when a metric was set)
``GET /sweeps/{id}/result`` the stacked ``.npy`` — parameter axes as
                            the new leading dimension(s)
``DELETE /sweeps/{id}``     cancel every live variant
``POST /workflows``         submit a spec-v3 DAG of process lists in
                            one atomic request (``docs/workflows.md``;
                            400 on cycles/dangling refs)
``GET /workflows[/{id}]``   workflow group status (per-node snapshots,
                            DAG edges, aggregate state)
``GET /workflows/{id}/trace``  linked trace: every node's span
                            timeline in one document
``DELETE /workflows/{id}``  cancel every live node (queued downstream
                            nodes cascade automatically)
``GET /jobs/{id}/trace``    the job's cross-process span timeline
                            (``?format=text`` renders an ASCII gantt,
                            ``?format=otlp`` an OTLP/JSON export doc;
                            ``docs/observability.md``)
``POST /jobs/{id}/frames``  streaming ingest: one raw ``.npy`` chunk +
                            ``X-Start-Frame`` header (409 on
                            out-of-order/duplicate; docs/streaming.md)
``POST /jobs/{id}/eof``     end of acquisition for a streaming job
``GET /jobs/{id}/frames``   buffered frames from ``?start=`` on — how
                            broker-mode workers pull the stream
``GET /jobs/{id}/preview``  partial reconstruction over the frames
                            ingested so far (before EOF)
``GET /executables``        the broker spool's hottest executable
                            signatures (warm-pool prefetch list;
                            token-authed, broker mode)
``GET /executables/{sig}``  one serialized executable as octet-stream
                            bytes (token-authed, broker mode)
``PUT /executables/{sig}``  worker upload of a serialized executable
                            (``X-Worker-Id``/``X-Worker-Secret``)
``GET /metrics``            Prometheus text exposition of the metrics
                            registry (also JSON under ``/stats``)
``GET /stats``              scheduler + compile-cache + metrics counters
``GET /plugins``            the wire-format plugin registry
``GET /events``             structured event log tail (``?since=``
                            cursor + ``?limit=``; docs/observability.md)
``GET /slo``                SLO rule states + alert lifecycle snapshot
``GET /cluster``            per-worker scoreboard (broker mode: leases,
                            heartbeat staleness, last error, prefetch)
``GET /healthz``            liveness probe; ``?ready=1`` consults the
                            SLO engine (503 while a critical rule fires)
==========================  ==========================================

Results are streamed straight out of the transport's chunk-addressed
files (``ChunkedFile`` — the checkpoint layer's on-disk layout) one
chunk-row slab at a time, so serving a large reconstruction never holds
the dense volume in server RAM; only in-memory/sharded backings are
materialised before the write.
"""
from __future__ import annotations

import hmac
import io
import json
import os
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable
from urllib.parse import parse_qs, unquote, urlparse

import numpy as np

from ..core.process_list import ProcessListError
from ..core.transport import ChunkedFile, Transport
from ..obs.export import trace_to_otlp
from ..obs.log import EventLog
from ..obs.metrics import MetricsRegistry, register_catalogue
from ..obs.slo import SloEngine
from ..obs.trace import Span, TraceSpool, render_gantt
from .checkpoint import CheckpointStore
from .compile_cache import CompileCache
from .job import Job, JobState
from .queue import JobQueue, QueueFull
from .scheduler import (LeaseLost, PipelineScheduler, WorkerAuthError,
                        WorkerBroker)
from .sweep import SweepError, SweepGroup, SweepManager
from .wire import WireError, from_spec, registry_spec
from .workflow import WorkflowError, WorkflowGroup, WorkflowManager

_JOB_RE = re.compile(r"^/jobs/([^/]+)$")
_RESULT_RE = re.compile(r"^/jobs/([^/]+)/result$")
_FRAMES_RE = re.compile(r"^/jobs/([^/]+)/frames$")
_EOF_RE = re.compile(r"^/jobs/([^/]+)/eof$")
_PREVIEW_RE = re.compile(r"^/jobs/([^/]+)/preview$")
_TRACE_RE = re.compile(r"^/jobs/([^/]+)/trace$")
_PROGRESS_RE = re.compile(r"^/jobs/([^/]+)/progress$")
_COMPLETE_RE = re.compile(r"^/jobs/([^/]+)/complete$")
_SWEEP_RE = re.compile(r"^/sweeps/([^/]+)$")
_SWEEP_RESULT_RE = re.compile(r"^/sweeps/([^/]+)/result$")
_WORKFLOW_RE = re.compile(r"^/workflows/([^/]+)$")
_WORKFLOW_TRACE_RE = re.compile(r"^/workflows/([^/]+)/trace$")
#: executable signatures are sha256 hex (compile_cache.executable_signature)
_EXEC_RE = re.compile(r"^/executables/([0-9a-f]{8,128})$")


class PipelineService:
    """A JobQueue + PipelineScheduler pair wrapped for HTTP serving.

    Owns the queue, the scheduler, the shared :class:`CompileCache`, and
    (optionally) a :class:`CheckpointStore`, and knows how to admit a
    wire-format spec envelope and stream results back out.  Use
    :meth:`serve` to bind the HTTP front end, or drive
    :meth:`submit_envelope`/:meth:`cancel` in-process.
    """

    def __init__(self, *,
                 transport_factory: Callable[[Job], Transport] | None = None,
                 n_workers: int = 2,
                 max_pending: int | None = 64,
                 max_history: int | None = 256,
                 checkpoints: CheckpointStore | None = None,
                 batch_identical: bool = False,
                 batch_max: int = 4,
                 fuse: bool = False,
                 compile_cache: CompileCache | None = None,
                 workers_remote: bool = False,
                 lease_ttl: float = 15.0,
                 sweep_interval: float | None = None,
                 results_dir: str | None = None,
                 max_sweep_variants: int = 64,
                 token: str | None = None,
                 trace_spool: TraceSpool | str | None = None,
                 executables_dir: str | None = None,
                 events_max: int = 2048,
                 slo_spec: dict[str, Any] | None = None,
                 slo_interval: float = 1.0):
        """Args mirror :class:`PipelineScheduler`; ``max_pending``
        bounds admission (HTTP 429 past it) and ``max_history`` bounds
        retained terminal jobs (a pruned job's result is gone — 404).

        ``token`` (satellite: auth hardening) arms shared-secret bearer
        auth: every MUTATING verb (POST/PUT/DELETE — including the
        worker protocol and frame ingest) is rejected 401 unless it
        carries ``Authorization: Bearer <token>``; reads stay open.
        ``trace_spool`` (a :class:`TraceSpool` or a directory path)
        retains terminal-job traces past ``max_history`` eviction —
        ``GET /jobs/{id}/trace`` falls back to it.
        ``executables_dir`` roots the persistent executable tier: in
        broker mode it is the broker's upload/prefetch spool
        (``GET/PUT /executables/{sig}``, default a temp dir); in
        scheduler mode it becomes the service CompileCache's disk store
        so compiled programs survive restarts.

        ``workers_remote=True`` is **broker mode**: instead of
        in-process scheduler threads, detached :class:`PipelineWorker`
        processes register over HTTP and pull jobs via leases
        (``lease_ttl``/``sweep_interval``/``results_dir`` configure the
        :class:`WorkerBroker`; ``transport_factory``/``n_workers``/
        gang options are worker-side concerns and are ignored here).

        The health plane (docs/observability.md): ``events_max`` bounds
        the structured event-log ring (``GET /events``), ``slo_spec``
        overrides/extends the default SLO rules
        (:func:`repro.obs.slo.rules_from_spec`), and ``slo_interval``
        paces the background evaluator that walks alerts through
        pending → firing → resolved.
        """
        # explicit None-check: an EMPTY CompileCache is falsy (__len__)
        if compile_cache is None:
            # scheduler mode gets the persistent tier on the service's
            # own cache; broker mode roots its upload spool there
            # instead (workers own their caches)
            compile_cache = CompileCache(
                store=None if workers_remote else executables_dir)
        self.compile_cache = compile_cache
        self.queue = JobQueue(max_pending=max_pending,
                              max_history=max_history)
        # one registry per service (docs/observability.md); the full
        # catalogue is pre-registered so /metrics is complete from the
        # first scrape
        self.metrics = MetricsRegistry()
        register_catalogue(self.metrics)
        # the structured event log: every queue/scheduler/broker state
        # transition lands here as one bounded JSON record
        self.events = EventLog(max_events=events_max)
        self.queue.events = self.events
        self.slo = SloEngine(self.metrics, self.events, spec=slo_spec)
        self.slo_interval = max(0.05, float(slo_interval))
        self.scheduler: PipelineScheduler | None = None
        self.broker: WorkerBroker | None = None
        if workers_remote:
            self.broker = WorkerBroker(
                self.queue, lease_ttl=lease_ttl,
                sweep_interval=sweep_interval, results_dir=results_dir,
                metrics=self.metrics, events=self.events,
                executables_dir=executables_dir)
        else:
            self.scheduler = PipelineScheduler(
                self.queue, transport_factory=transport_factory,
                n_workers=n_workers, checkpoints=checkpoints,
                batch_identical=batch_identical, batch_max=batch_max,
                fuse=fuse, compile_cache=self.compile_cache,
                metrics=self.metrics, events=self.events)
        self.sweeps = SweepManager(self.queue, fetch=self._variant_array,
                                   max_variants=max_sweep_variants)
        self.workflows = WorkflowManager(self.queue)
        self.token = token
        self.trace_spool = (TraceSpool(trace_spool)
                            if isinstance(trace_spool, str) else trace_spool)
        if self.trace_spool is not None:
            spool = self.trace_spool
            self.queue.add_evict_hook(
                lambda job: spool.put(job.job_id, job.trace))
        # eviction backstop: a terminal streaming job's retained frame
        # chunks must not outlive the job record
        self.queue.add_evict_hook(
            lambda job: job.stream.drop_buffers() if job.stream else None)
        self._wire_gauges()
        self._httpd: ThreadingHTTPServer | None = None
        self._http_thread: threading.Thread | None = None
        self._slo_thread: threading.Thread | None = None
        self._slo_stop = threading.Event()

    def _wire_gauges(self) -> None:
        """Bind the callback gauges: these read live state at scrape
        time rather than being pushed on every event."""
        m = self.metrics
        m.gauge("queue.depth").set_function(self.queue.pending)
        m.gauge("queue.oldest_age_s").set_function(
            lambda: self.queue.queue_info()["oldest_pending_age"] or 0.0)
        m.gauge("compile.cache.hits").set_function(
            lambda: self.compile_cache.hits)
        m.gauge("compile.cache.misses").set_function(
            lambda: self.compile_cache.misses)
        m.gauge("compile.cache.disk.hits").set_function(
            lambda: self.compile_cache.disk_hits)
        m.gauge("compile.cache.disk.misses").set_function(
            lambda: self.compile_cache.disk_misses)
        broker = self.broker
        m.gauge("executables.spool.bytes").set_function(
            broker.executables.total_bytes if broker is not None
            else lambda: (self.compile_cache.store.total_bytes()
                          if self.compile_cache.store is not None else 0))
        m.gauge("leases.active").set_function(
            broker.n_active_leases if broker is not None else lambda: 0)
        m.gauge("workers.registered").set_function(
            broker.n_workers if broker is not None else lambda: 0)
        m.gauge("slo.firing").set_function(
            lambda: float(self.slo.n_firing()))
        m.gauge("events.head").set_function(
            lambda: float(self.events.head))

    # -- service operations (HTTP-independent) -------------------------
    def submit_envelope(self, envelope: dict[str, Any]) -> Job:
        """Admit one submission envelope::

            {"process_list": <spec v1>,   # required
             "priority": 0, "job_id": null, "metadata": {},
             "trace_id": null}            # correlate with external traces

        Deserialises the spec (:func:`~repro.service.wire.from_spec`),
        runs the pre-flight ``ProcessList.check()`` so structurally
        broken chains are rejected before admission, then enqueues.

        Returns: the queued :class:`Job`.
        Raises:
            WireError / ProcessListError: invalid spec (HTTP 400).
            ValueError: duplicate active job id (HTTP 409).
            QueueFull: admission control rejected (HTTP 429).
        """
        if not isinstance(envelope, dict) or \
                "process_list" not in envelope:
            raise WireError('body must be an object with a '
                            '"process_list" spec')
        priority = envelope.get("priority", 0)
        if not isinstance(priority, int) or isinstance(priority, bool):
            raise WireError(f"priority must be an integer, got "
                            f"{priority!r}")
        job_id = envelope.get("job_id")
        if job_id is not None and not isinstance(job_id, str):
            raise WireError(f"job_id must be a string, got {job_id!r}")
        metadata = envelope.get("metadata") or {}
        if not isinstance(metadata, dict):
            raise WireError("metadata must be an object")
        trace_id = envelope.get("trace_id")
        if trace_id is not None and not isinstance(trace_id, str):
            raise WireError(f"trace_id must be a string, got "
                            f"{trace_id!r}")
        pl = from_spec(envelope["process_list"])
        pl.check()
        job = self.queue.submit(pl, priority=priority, job_id=job_id,
                                metadata=metadata, trace_id=trace_id)
        self.metrics.counter("jobs.submitted").inc()
        return job

    def cancel(self, job_id: str) -> dict[str, Any]:
        """Cancel ``job_id`` if still queued — or, in broker mode, flag
        a LEASED job so its worker's next heartbeat gets a ``cancelled``
        verdict.  Returns ``{"job_id", "cancelled", "state"}`` (plus
        ``"pending": True`` for the leased case, where the terminal
        state lands at the next heartbeat); ``cancelled`` is False for a
        job already terminal.  Raises KeyError if unknown."""
        cancelled = self.queue.cancel(job_id)
        job = self.queue.job(job_id)
        out = {"job_id": job_id, "cancelled": cancelled,
               "state": job.state.value}
        # a queue-side cancel (and any dependency cascade it triggers)
        # is observed by the queue's terminal hooks — registered by both
        # scheduler and broker — so outcome metrics stay exactly-once
        if not cancelled and self.broker is not None \
                and self.broker.request_cancel(job_id):
            out.update(cancelled=True, pending=True)
        return out

    # -- streaming ingest (docs/streaming.md) ---------------------------
    def _streaming_job(self, job_id: str) -> Job:
        """The job, checked to be a live streaming one.  Raises KeyError
        (404) if unknown, RuntimeError (409) otherwise."""
        job = self.queue.job(job_id)
        if not job.streaming:
            raise RuntimeError(f"job {job_id!r} is not a streaming job "
                               f'(submit with spec v2 "streaming": true)')
        return job

    def ingest_frames(self, job_id: str, frames: np.ndarray,
                      start: int) -> dict[str, Any]:
        """Accept one contiguous frame chunk (``POST /jobs/{id}/frames``).

        ``start`` must equal the current ingest watermark — out-of-order
        and duplicate chunks are rejected (RuntimeError → HTTP 409) so
        the on-disk prefix is always exact.  Wakes the queue (a parked
        streaming job becomes leasable again) and any in-process driver
        waiting on the stream condition."""
        job = self._streaming_job(job_id)
        if job.state.terminal():
            raise RuntimeError(f"job {job_id!r} is {job.state.value}; "
                               f"ingest is closed")
        frames = np.ascontiguousarray(frames)
        if frames.ndim < 1 or frames.shape[0] == 0:
            raise RuntimeError("frames chunk must have >= 1 frame on "
                               "axis 0")
        st = job.stream
        with st.lock:
            if st.eof:
                raise RuntimeError(f"job {job_id!r} already got EOF; no "
                                   f"more frames accepted")
            if start != st.watermark:
                raise RuntimeError(
                    f"out-of-order ingest for job {job_id!r}: chunk "
                    f"starts at frame {start} but the watermark is "
                    f"{st.watermark} (duplicate or gap)")
            watermark = st.append(frames, start)
            st.cond.notify_all()
        self.queue.kick()
        self.metrics.counter("stream.frames.ingested").inc(
            int(frames.shape[0]))
        return {"job_id": job_id, "start": int(start),
                "count": int(frames.shape[0]), "watermark": watermark}

    def mark_eof(self, job_id: str) -> dict[str, Any]:
        """End of acquisition (``POST /jobs/{id}/eof``): no more frames
        will arrive.  A second EOF on a live stream is a protocol error
        (409), like a duplicate chunk — but EOF on a stream that already
        ran to completion succeeds: the loader declares its total frame
        count, so a fast executor can finish the moment the last frame
        lands, racing ahead of the producer's EOF."""
        job = self._streaming_job(job_id)
        st = job.stream
        if job.state is JobState.DONE:
            with st.lock:
                st.eof = True
                return {"job_id": job_id, "eof": True,
                        "watermark": st.watermark}
        if job.state.terminal():
            raise RuntimeError(f"job {job_id!r} is {job.state.value}; "
                               f"ingest is closed")
        with st.lock:
            if st.eof:
                raise RuntimeError(f"job {job_id!r} already got EOF")
            st.eof = True
            watermark = st.watermark
            st.cond.notify_all()
        self.queue.kick()
        return {"job_id": job_id, "eof": True, "watermark": watermark}

    def preview(self, job_id: str) -> tuple[np.ndarray, int]:
        """Partial reconstruction over the frames ingested so far
        (``GET /jobs/{id}/preview``) — ``(array, frames_covered)``.

        Scheduler mode computes it on demand from the live runner
        (serialised against the pump loop by ``stream.exec_lock``);
        broker mode serves the newest preview the worker uploaded.
        Raises RuntimeError/ValueError (→ 409) while no preview can be
        produced yet."""
        job = self._streaming_job(job_id)
        if self.broker is not None:
            path = job.remote_results.get("__preview__")
            if path is None or not os.path.exists(path):
                raise RuntimeError(
                    "no preview available yet (the worker has not "
                    "uploaded one)")
            return np.load(path), job.preview_watermark
        runner = job.runner
        if runner is None or not runner.streaming:
            raise RuntimeError(
                "no preview available yet (the job has not started)")
        with job.stream.exec_lock:
            arr, cut = runner.preview()
        job.preview_watermark = max(job.preview_watermark, cut)
        return arr, cut

    # -- parameter sweeps (docs/sweeps.md) ------------------------------
    def submit_sweep(self, envelope: dict[str, Any]) -> SweepGroup:
        """Admit one sweep envelope (``POST /sweeps``): the spec plus a
        ``sweep`` grid block, expanded into variant jobs submitted
        atomically so the gang path batches them.  See
        :meth:`SweepManager.submit` for the error contract."""
        group = self.sweeps.submit(envelope)
        self.metrics.counter("jobs.submitted").inc(group.n_variants)
        return group

    def cancel_sweep(self, sweep_id: str) -> dict[str, Any]:
        """Cancel every live variant of ``sweep_id``
        (``DELETE /sweeps/{id}``) — queued variants cancel immediately,
        leased ones at their worker's next heartbeat.  Raises KeyError
        if unknown."""
        return self.sweeps.cancel(sweep_id, self.cancel)

    # -- workflow DAGs (docs/workflows.md) ------------------------------
    def submit_workflow(self, envelope: dict[str, Any]) -> WorkflowGroup:
        """Admit one spec-v3 workflow envelope (``POST /workflows``): a
        DAG of process lists validated (cycles, dangling refs → 400)
        and admitted atomically.  See :meth:`WorkflowManager.submit`
        for the error contract."""
        group = self.workflows.submit(envelope)
        self.metrics.counter("jobs.submitted").inc(group.n_nodes)
        return group

    def cancel_workflow(self, workflow_id: str) -> dict[str, Any]:
        """Cancel every live node of ``workflow_id``
        (``DELETE /workflows/{id}``) — queued nodes cancel immediately
        (their downstream cones cascade), leased ones at their worker's
        next heartbeat.  Raises KeyError if unknown."""
        return self.workflows.cancel(workflow_id, self.cancel)

    def workflow_trace(self, workflow_id: str) -> dict[str, Any]:
        """The workflow-level linked trace (``GET
        /workflows/{id}/trace``): per-node span timelines, falling back
        to the trace spool for evicted node jobs."""
        return self.workflows.trace(workflow_id, self._job_trace_doc)

    def _job_trace_doc(self, job_id: str) -> dict[str, Any]:
        """One job's trace as a wire document — live trace when the job
        record survives, trace-spool fallback after eviction.  Raises
        KeyError when neither has it."""
        try:
            job = self.queue.job(job_id)
        except KeyError:
            rec = (self.trace_spool.get(job_id)
                   if self.trace_spool is not None else None)
            if rec is None:
                raise
            return rec
        return {"job_id": job_id, **job.trace.to_wire()}

    def _variant_array(self, job_id: str, dataset: str | None = None
                       ) -> np.ndarray:
        """One DONE variant's result as a host array — covers both the
        in-process runner path and the broker-mode ``.npy`` spool (the
        SweepManager's ``fetch`` hook, O(variant) RAM)."""
        remote = self.result_file(job_id, dataset)
        if remote is not None:
            return np.load(remote[1])
        ds, transport = self.result_dataset(job_id, dataset)
        return np.ascontiguousarray(np.asarray(transport.read(ds)))

    # -- health plane (docs/observability.md) ---------------------------
    def readiness(self) -> tuple[int, dict[str, Any]]:
        """The degrade-aware readiness verdict
        (``GET /healthz?ready=1``): evaluate the SLO engine NOW, answer
        ``(503, detail)`` while any critical rule is firing, else
        ``(200, ok)``.  Liveness (plain ``/healthz``) never consults
        the engine — a sick-but-alive service must not be restarted by
        its liveness probe."""
        self.slo.evaluate()
        critical = self.slo.critical_firing()
        if critical:
            return 503, {"ok": False, "ready": False,
                         "error": "critical SLO rule firing",
                         "firing": [r["name"] for r in critical],
                         "detail": critical,
                         "pending": self.queue.pending()}
        return 200, {"ok": True, "ready": True,
                     "pending": self.queue.pending()}

    def slo_snapshot(self) -> dict[str, Any]:
        """Fresh ``GET /slo`` payload (evaluates first, so a scrape
        never reports stale lifecycle states)."""
        self.slo.evaluate()
        return self.slo.snapshot()

    def _slo_loop(self, stop: threading.Event) -> None:
        while not stop.wait(self.slo_interval):
            self.slo.evaluate()

    def stats(self) -> dict[str, Any]:
        """Scheduler (or broker) counters + compile-cache hit rates +
        sweep-group counters + the metrics-registry snapshot
        (``GET /stats``)."""
        out = (self.broker.stats() if self.broker is not None
               else self.scheduler.stats())
        out["sweeps"] = self.sweeps.stats()
        out["workflows"] = self.workflows.stats()
        out["metrics"] = self.metrics.snapshot()
        return out

    def result_dataset(self, job_id: str, dataset: str | None = None):
        """Resolve a finished job's output dataset + its transport.

        Args:
            job_id: a DONE job still within ``max_history``.
            dataset: dataset name; default = the chain's first saver
                output (:meth:`PluginRunner.result_names`).

        Returns: ``(DataSet, Transport)``.
        Raises:
            KeyError: unknown job or unknown dataset name.
            RuntimeError: job not DONE yet, or its runner was pruned.
        """
        job = self.queue.job(job_id)
        if job.state is not JobState.DONE:
            raise RuntimeError(f"job {job_id!r} is {job.status!r}, "
                               f"not done")
        runner = job.runner
        if runner is None and job.remote_results:
            raise RuntimeError(          # broker-mode: served from files
                f"job {job_id!r} ran on a remote worker; its results "
                f"are .npy files, not live datasets")
        if runner is None:
            raise RuntimeError(f"job {job_id!r} result was evicted "
                               f"(max_history)")
        name = dataset or (runner.result_names() or [None])[0]
        if name is None or name not in runner.datasets:
            raise KeyError(
                f"job {job_id!r} has no dataset {name!r} "
                f"(available: {sorted(runner.datasets)})")
        return runner.datasets[name], runner.transport

    def result_file(self, job_id: str, dataset: str | None = None
                    ) -> tuple[str, str] | None:
        """Broker-mode result lookup: ``(name, path)`` of the ``.npy`` a
        remote worker handed over for ``dataset`` (default: the first
        reported), or None when this job has no remote results
        (in-process path).

        Raises:
            KeyError: unknown job, or remote results exist but not for
                ``dataset``.
            RuntimeError: job not DONE yet.
        """
        job = self.queue.job(job_id)
        if not job.remote_results:
            return None
        if job.state is not JobState.DONE:
            raise RuntimeError(f"job {job_id!r} is {job.status!r}, "
                               f"not done")
        # dunder names (the streaming "__preview__" upload) are service
        # plumbing, never a default result
        name = dataset or next(
            (k for k in job.remote_results if not k.startswith("__")),
            next(iter(job.remote_results)))
        path = job.remote_results.get(name)
        if path is None or not os.path.exists(path):
            raise KeyError(
                f"job {job_id!r} has no result dataset {name!r} "
                f"(available: {sorted(job.remote_results)})")
        return name, path

    # -- lifecycle ------------------------------------------------------
    def serve(self, host: str = "127.0.0.1", port: int = 8080,
              block: bool = False) -> tuple[str, int]:
        """Start the scheduler workers and the HTTP front end.

        Args:
            host/port: bind address (``port=0`` picks an ephemeral port).
            block: run ``serve_forever`` on the calling thread (CLI
                mode) instead of a daemon thread.

        Returns: the bound ``(host, port)``.
        """
        if self.broker is not None:
            self.broker.start()
        else:
            self.scheduler.start()
        if self._slo_thread is None:
            self._slo_stop = threading.Event()
            self._slo_thread = threading.Thread(
                target=self._slo_loop, args=(self._slo_stop,),
                name="slo-eval", daemon=True)
            self._slo_thread.start()
        service = self

        class Handler(_PipelineHandler):
            pass

        Handler.service = service
        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        addr = self._httpd.server_address[:2]
        if block:
            try:
                self._httpd.serve_forever()
            finally:
                self.stop()
        else:
            self._http_thread = threading.Thread(
                target=self._httpd.serve_forever, name="pipeline-http",
                daemon=True)
            self._http_thread.start()
        return addr

    def stop(self) -> None:
        """Shut down the HTTP server (if serving) and the scheduler
        workers / broker sweep thread."""
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._http_thread is not None:
            self._http_thread.join(timeout=10)
            self._http_thread = None
        if self._slo_thread is not None:
            self._slo_stop.set()
            self._slo_thread.join(timeout=10)
            self._slo_thread = None
        if self.broker is not None:
            self.broker.shutdown()
        if self.scheduler is not None:
            self.scheduler.shutdown()


# ----------------------------------------------------------------------
def _npy_header(shape: tuple[int, ...], dtype) -> bytes:
    """The ``.npy`` v1 magic + header for a C-ordered array, so a result
    body can be streamed without building the array in RAM."""
    from numpy.lib import format as npy
    buf = io.BytesIO()
    npy.write_array_header_1_0(
        buf, {"descr": npy.dtype_to_descr(np.dtype(dtype)),
              "fortran_order": False, "shape": tuple(shape)})
    return buf.getvalue()     # write_array_header_1_0 includes the magic


class _PipelineHandler(BaseHTTPRequestHandler):
    """Routes HTTP verbs to the bound :class:`PipelineService`."""

    service: PipelineService = None   # bound per-server in serve()
    server_version = "SavuPipeline/1"
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):   # quiet by default (tests)
        pass

    # -- helpers --------------------------------------------------------
    def _json(self, code: int, obj: Any) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str, **extra) -> None:
        self._json(code, {"error": message, **extra})

    def _text(self, code: int, text: str,
              content_type: str = "text/plain; charset=utf-8") -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> Any:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b""
        if not raw:
            raise WireError("empty request body")
        try:
            return json.loads(raw)
        except json.JSONDecodeError as e:
            raise WireError(f"request body is not valid JSON: {e}")

    def _drain_body(self) -> None:
        """Consume an unread request body before replying — a keep-alive
        connection would otherwise parse the leftover bytes as the next
        request line."""
        length = int(self.headers.get("Content-Length") or 0)
        if length:
            self.rfile.read(length)

    def _authorised(self) -> bool:
        """Shared-secret bearer check for mutating verbs.  No token
        configured = open service (the pre-auth behaviour)."""
        token = self.service.token
        if token is None:
            return True
        got = self.headers.get("Authorization") or ""
        return hmac.compare_digest(got, f"Bearer {token}")

    def _reject_unauthorised(self) -> bool:
        if self._authorised():
            return False
        self._drain_body()
        self._error(401, "missing or invalid bearer token "
                         "(Authorization: Bearer <token>)")
        return True

    def _send_array(self, arr: np.ndarray,
                    extra: dict[str, str] | None = None) -> None:
        """One in-RAM array as ``.npy`` bytes (previews, frame fetches —
        small by construction, unlike full results)."""
        buf = io.BytesIO()
        np.save(buf, np.ascontiguousarray(arr))
        body = buf.getvalue()
        self.send_response(200)
        self.send_header("Content-Type", "application/x-npy")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    # -- verbs ----------------------------------------------------------
    def do_GET(self) -> None:
        url = urlparse(self.path)
        path, query = url.path.rstrip("/") or "/", parse_qs(url.query)
        svc = self.service
        if path == "/healthz":
            # plain = cheap liveness; ?ready=1 = degrade-aware
            # readiness via the SLO engine (503 + machine-readable
            # detail while a critical rule fires)
            if (query.get("ready") or ["0"])[0] in ("1", "true"):
                return self._json(*svc.readiness())
            return self._json(200, {"ok": True,
                                    "pending": svc.queue.pending()})
        if path == "/slo":
            return self._json(200, svc.slo_snapshot())
        if path == "/events":
            try:
                since = int((query.get("since") or ["0"])[0])
                raw_limit = (query.get("limit") or [None])[0]
                limit = None if raw_limit is None else int(raw_limit)
            except ValueError:
                return self._error(400, "since/limit must be integers")
            return self._json(200, svc.events.since(since, limit=limit))
        if path == "/cluster":
            if svc.broker is None:
                return self._error(409, "not serving in broker mode")
            return self._json(200, svc.broker.cluster())
        if path == "/stats":
            return self._json(200, svc.stats())
        if path == "/metrics":
            return self._text(200, svc.metrics.render_prometheus(),
                              content_type=MetricsRegistry.CONTENT_TYPE)
        if path == "/plugins":
            return self._json(200, registry_spec())
        if path == "/jobs":
            return self._json(200, {"jobs": svc.queue.snapshot()})
        if path == "/sweeps":
            return self._json(200, {"sweeps": svc.sweeps.snapshot_all()})
        if path == "/workflows":
            return self._json(
                200, {"workflows": svc.workflows.snapshot_all()})
        # trace regex first — _WORKFLOW_RE would also match ".../trace"
        m = _WORKFLOW_TRACE_RE.match(path)
        if m:
            workflow_id = unquote(m.group(1))
            try:
                return self._json(200, svc.workflow_trace(workflow_id))
            except KeyError:
                return self._error(
                    404, f"unknown workflow {workflow_id!r}")
        m = _WORKFLOW_RE.match(path)
        if m:
            workflow_id = unquote(m.group(1))
            try:
                return self._json(200, svc.workflows.status(workflow_id))
            except KeyError:
                return self._error(
                    404, f"unknown workflow {workflow_id!r}")
        m = _SWEEP_RESULT_RE.match(path)
        if m:
            return self._send_sweep_result(
                unquote(m.group(1)), (query.get("dataset") or [None])[0])
        m = _SWEEP_RE.match(path)
        if m:
            sweep_id = unquote(m.group(1))
            try:
                return self._json(200, svc.sweeps.status(sweep_id))
            except KeyError:
                return self._error(404, f"unknown sweep {sweep_id!r}")
        if path == "/workers":
            if svc.broker is None:
                return self._error(409, "not serving in broker mode")
            return self._json(200, svc.broker.stats()["workers"])
        if path == "/executables":
            # token-authed even though it is a read: the hot list and
            # the payloads below are worker-protocol surface, not a
            # public monitoring endpoint
            if self._reject_unauthorised():
                return
            if svc.broker is None:
                return self._error(409, "not serving in broker mode")
            return self._json(200, {"hot": svc.broker.hot_executables()})
        m = _EXEC_RE.match(path)
        if m:
            if self._reject_unauthorised():
                return
            if svc.broker is None:
                return self._error(409, "not serving in broker mode")
            sig = m.group(1)
            try:
                payload = svc.broker.get_executable(sig)
            except KeyError:
                return self._error(404, f"unknown executable {sig!r}")
            self.send_response(200)
            self.send_header("Content-Type", "application/octet-stream")
            self.send_header("Content-Length", str(len(payload)))
            self.send_header("X-Executable-Sig", sig)
            self.end_headers()
            # stream in blocks: payloads can be tens of MB
            for i in range(0, len(payload), 1 << 20):
                self.wfile.write(payload[i:i + (1 << 20)])
            return
        m = _TRACE_RE.match(path)
        if m:
            job_id = unquote(m.group(1))
            fmt = (query.get("format") or [None])[0]
            as_text, as_otlp = fmt == "text", fmt == "otlp"
            try:
                job = svc.queue.job(job_id)
            except KeyError:
                # evicted by max_history?  the trace spool keeps the
                # timeline after the job record is gone
                rec = (svc.trace_spool.get(job_id)
                       if svc.trace_spool is not None else None)
                if rec is None:
                    return self._error(404, f"unknown job {job_id!r}")
                if as_text:
                    spans = []
                    for d in rec.get("spans", ()):
                        try:
                            spans.append(Span.from_wire(d))
                        except (KeyError, TypeError, ValueError):
                            continue
                    return self._text(200, render_gantt(spans) + "\n")
                if as_otlp:
                    return self._json(
                        200, trace_to_otlp(rec, {"job.id": job_id}))
                return self._json(200, rec)
            if as_text:
                return self._text(
                    200, render_gantt(job.trace.spans()) + "\n")
            if as_otlp:
                return self._json(
                    200, trace_to_otlp(job.trace, {"job.id": job_id}))
            return self._json(200, {"job_id": job_id,
                                    **job.trace.to_wire()})
        m = _PREVIEW_RE.match(path)
        if m:
            job_id = unquote(m.group(1))
            try:
                arr, covered = svc.preview(job_id)
            except KeyError:
                return self._error(404, f"unknown job {job_id!r}")
            except (RuntimeError, ValueError) as e:
                return self._error(409, str(e))
            return self._send_array(arr,
                                    extra={"X-Watermark": str(covered)})
        m = _FRAMES_RE.match(path)
        if m:
            return self._fetch_frames(unquote(m.group(1)), query)
        m = _JOB_RE.match(path)
        if m:
            job_id = unquote(m.group(1))
            try:
                return self._json(200, svc.queue.job(job_id).snapshot())
            except KeyError:
                return self._error(404, f"unknown job {job_id!r}")
        m = _RESULT_RE.match(path)
        if m:
            return self._send_result(
                unquote(m.group(1)), (query.get("dataset") or [None])[0])
        self._error(404, f"no route for GET {path}")

    def do_POST(self) -> None:
        if self._reject_unauthorised():
            return
        path = urlparse(self.path).path.rstrip("/")
        m = _FRAMES_RE.match(path)
        if m:
            return self._ingest_frames(unquote(m.group(1)))
        m = _EOF_RE.match(path)
        if m:
            job_id = unquote(m.group(1))
            self._drain_body()            # EOF needs no body
            try:
                return self._json(200, self.service.mark_eof(job_id))
            except KeyError:
                return self._error(404, f"unknown job {job_id!r}")
            except RuntimeError as e:
                return self._error(409, str(e))
        if path == "/jobs":
            return self._submit()
        if path == "/sweeps":
            return self._submit_sweep()
        if path == "/workflows":
            return self._submit_workflow()
        if path == "/workers":
            return self._broker_call(
                lambda b, body: (201, b.register(body)))
        if path == "/jobs/lease":
            return self._broker_call(self._lease)
        m = _PROGRESS_RE.match(path)
        if m:
            job_id = unquote(m.group(1))
            return self._broker_call(
                lambda b, body: (200, b.progress(
                    job_id, self._worker_of(body), body)))
        m = _COMPLETE_RE.match(path)
        if m:
            job_id = unquote(m.group(1))
            return self._broker_call(
                lambda b, body: (200, b.complete(
                    job_id, self._worker_of(body), body)))
        self._drain_body()
        self._error(404, f"no route for POST {self.path}")

    def _submit(self) -> None:
        try:
            envelope = self._read_body()
            job = self.service.submit_envelope(envelope)
        except (WireError, ProcessListError) as e:
            return self._error(400, str(e))
        except QueueFull as e:
            return self._error(429, str(e))
        except ValueError as e:           # duplicate active job id
            return self._error(409, str(e))
        self._json(201, {"job_id": job.job_id, "state": job.state.value,
                         "priority": job.priority})

    def _submit_sweep(self) -> None:
        # NB: SweepError/WireError are ValueError subclasses — they must
        # be caught before the duplicate-id ValueError below
        try:
            envelope = self._read_body()
            group = self.service.submit_sweep(envelope)
        except (SweepError, WireError, ProcessListError) as e:
            return self._error(400, str(e))
        except QueueFull as e:
            return self._error(429, str(e))
        except ValueError as e:           # duplicate active sweep/job id
            return self._error(409, str(e))
        self._json(201, {
            "sweep_id": group.sweep_id, "state": group.state(),
            "n_variants": group.n_variants, "shape": list(group.shape),
            "axes": [a.spec() for a in group.axes],
            "job_ids": [j.job_id for j in group.jobs]})

    def _submit_workflow(self) -> None:
        # NB: WorkflowError/WireError are ValueError subclasses — they
        # must be caught before the duplicate-id ValueError below
        try:
            envelope = self._read_body()
            group = self.service.submit_workflow(envelope)
        except (WorkflowError, WireError, ProcessListError) as e:
            return self._error(400, str(e))
        except QueueFull as e:
            return self._error(429, str(e))
        except ValueError as e:       # duplicate active workflow/job id
            return self._error(409, str(e))
        self._json(201, {
            "workflow_id": group.workflow_id, "state": group.state(),
            "n_nodes": group.n_nodes, "nodes": list(group.nodes),
            "job_ids": [j.job_id for j in group.jobs]})

    # -- streaming ingest (docs/streaming.md) ---------------------------
    def _ingest_frames(self, job_id: str) -> None:
        """POST /jobs/{id}/frames: raw ``.npy`` body + ``X-Start-Frame``
        header → appended to the job's stream buffer."""
        try:
            start = int(self.headers.get("X-Start-Frame", ""))
        except (TypeError, ValueError):
            self._drain_body()
            return self._error(
                400, "POST frames needs an integer X-Start-Frame header")
        length = int(self.headers.get("Content-Length") or 0)
        payload = self.rfile.read(length) if length else b""
        if not payload:
            return self._error(
                400, "empty frames body (raw .npy bytes expected)")
        try:
            frames = np.load(io.BytesIO(payload), allow_pickle=False)
        except ValueError as e:
            return self._error(400, f"frames body is not a valid .npy: "
                                    f"{e}")
        try:
            out = self.service.ingest_frames(job_id, frames, start)
        except KeyError:
            return self._error(404, f"unknown job {job_id!r}")
        except RuntimeError as e:
            return self._error(409, str(e))
        self._json(200, out)

    def _fetch_frames(self, job_id: str, query: dict) -> None:
        """GET /jobs/{id}/frames?start=&max=: how a broker-mode worker
        pulls the buffered stream.  204 (with ``X-EOF``/``X-Watermark``
        headers) when nothing at-or-after ``start`` has arrived yet."""
        svc = self.service
        try:
            job = svc.queue.job(job_id)
        except KeyError:
            return self._error(404, f"unknown job {job_id!r}")
        if not job.streaming:
            return self._error(409, f"job {job_id!r} is not a "
                                    f"streaming job")
        try:
            start = int((query.get("start") or ["0"])[0])
            raw_max = (query.get("max") or [None])[0]
            max_frames = None if raw_max is None else int(raw_max)
        except ValueError:
            return self._error(400, "start/max must be integers")
        st = job.stream
        with st.lock:
            arr, _ = st.fetch(start, max_frames)
            eof, watermark = st.eof, st.watermark
        headers = {"X-Start": str(start),
                   "X-EOF": "1" if eof else "0",
                   "X-Watermark": str(watermark)}
        if arr is None:
            self.send_response(204)
            for k, v in {**headers, "X-Count": "0"}.items():
                self.send_header(k, v)
            self.end_headers()
            return
        self._send_array(arr, extra={**headers,
                                     "X-Count": str(arr.shape[0])})

    # -- worker-pull protocol (broker mode) -----------------------------
    @staticmethod
    def _worker_of(body: Any) -> str:
        wid = body.get("worker_id") if isinstance(body, dict) else None
        if not isinstance(wid, str):
            raise WireError('body must carry a string "worker_id"')
        return wid

    @staticmethod
    def _lease(broker, body: Any) -> tuple[int, Any]:
        wid = _PipelineHandler._worker_of(body)
        max_jobs = body.get("max_jobs", 1)
        if not isinstance(max_jobs, int) or max_jobs < 1:
            raise WireError(f"max_jobs must be a positive int, got "
                            f"{max_jobs!r}")
        timeout = body.get("timeout", 0.0)
        if not isinstance(timeout, (int, float)) or timeout < 0 \
                or timeout > 30:
            raise WireError(f"timeout must be 0..30s, got {timeout!r}")
        prefetched = body.get("prefetched")
        if prefetched is not None and (
                not isinstance(prefetched, int) or prefetched < 0
                or isinstance(prefetched, bool)):
            raise WireError(f"prefetched must be a non-negative int, "
                            f"got {prefetched!r}")
        return 200, {"jobs": broker.lease(
            wid, max_jobs=max_jobs, timeout=float(timeout),
            secret=body.get("worker_secret"), prefetched=prefetched)}

    def _broker_call(self, fn) -> None:
        """Run one worker-protocol operation: parse the JSON body, hand
        it to ``fn(broker, body) -> (status, payload)``, map the shared
        error contract (409 no-broker/lease-lost, 404 unknown, 403 bad
        worker secret, 400 malformed)."""
        if self.service.broker is None:
            self._drain_body()
            return self._error(
                409, "not serving in broker mode (start the service "
                     "with workers_remote=True / --workers-remote)")
        try:
            body = self._read_body()
            code, payload = fn(self.service.broker, body)
        except WireError as e:
            return self._error(400, str(e))
        except WorkerAuthError as e:
            return self._error(403, str(e))
        except LeaseLost as e:
            return self._error(409, str(e))
        except KeyError as e:
            return self._error(404, f"unknown {e}")
        self._json(code, payload)

    def do_PUT(self) -> None:
        """Uploads from a leased worker: raw ``.npy`` result bytes to
        ``/jobs/{id}/result?dataset=name``, or a serialized executable
        to ``/executables/{sig}`` — both identified by ``X-Worker-Id``
        + ``X-Worker-Secret`` headers."""
        if self._reject_unauthorised():
            return
        url = urlparse(self.path)
        m = _EXEC_RE.match(url.path.rstrip("/"))
        if m:
            return self._put_executable(m.group(1))
        m = _RESULT_RE.match(url.path.rstrip("/"))
        if not m:
            self._drain_body()
            return self._error(404, f"no route for PUT {self.path}")
        if self.service.broker is None:
            self._drain_body()
            return self._error(409, "not serving in broker mode")
        job_id = unquote(m.group(1))
        query = parse_qs(url.query)
        dataset = (query.get("dataset") or [None])[0]
        worker_id = self.headers.get("X-Worker-Id")
        if not dataset or not worker_id:
            self._drain_body()
            return self._error(
                400, "PUT result needs ?dataset= and an X-Worker-Id "
                     "header")
        length = int(self.headers.get("Content-Length") or 0)
        payload = self.rfile.read(length) if length else b""
        if not payload:
            return self._error(400, "empty result body")
        try:
            self.service.broker.store_result(
                job_id, worker_id, dataset, payload,
                secret=self.headers.get("X-Worker-Secret"))
        except WireError as e:            # e.g. unsafe dataset name
            return self._error(400, str(e))
        except WorkerAuthError as e:
            return self._error(403, str(e))
        except LeaseLost as e:
            return self._error(409, str(e))
        except KeyError:
            return self._error(404, f"unknown job {job_id!r}")
        self._json(200, {"job_id": job_id, "dataset": dataset,
                         "bytes": len(payload)})

    def _put_executable(self, sig: str) -> None:
        """PUT /executables/{sig}: a worker hands over one serialized
        executable it just compiled (docs/worker-protocol.md)."""
        if self.service.broker is None:
            self._drain_body()
            return self._error(409, "not serving in broker mode")
        worker_id = self.headers.get("X-Worker-Id")
        if not worker_id:
            self._drain_body()
            return self._error(
                400, "PUT executable needs an X-Worker-Id header")
        length = int(self.headers.get("Content-Length") or 0)
        payload = self.rfile.read(length) if length else b""
        if not payload:
            return self._error(400, "empty executable body")
        try:
            out = self.service.broker.put_executable(
                worker_id, self.headers.get("X-Worker-Secret"), sig,
                payload)
        except WireError as e:
            return self._error(400, str(e))
        except WorkerAuthError as e:
            return self._error(403, str(e))
        except KeyError:
            return self._error(404, f"unknown worker {worker_id!r}")
        self._json(200, {**out, "bytes": len(payload)})

    def do_DELETE(self) -> None:
        if self._reject_unauthorised():
            return
        self._drain_body()              # DELETEs may carry a body
        path = urlparse(self.path).path.rstrip("/")
        m = _SWEEP_RE.match(path)
        if m:
            sweep_id = unquote(m.group(1))
            try:
                return self._json(200, self.service.cancel_sweep(sweep_id))
            except KeyError:
                return self._error(404, f"unknown sweep {sweep_id!r}")
        m = _WORKFLOW_RE.match(path)
        if m:
            workflow_id = unquote(m.group(1))
            try:
                return self._json(
                    200, self.service.cancel_workflow(workflow_id))
            except KeyError:
                return self._error(
                    404, f"unknown workflow {workflow_id!r}")
        m = _JOB_RE.match(path)
        if not m:
            return self._error(404, f"no route for DELETE {self.path}")
        job_id = unquote(m.group(1))
        try:
            out = self.service.cancel(job_id)
        except KeyError:
            return self._error(404, f"unknown job {job_id!r}")
        if not out["cancelled"]:
            # dispatched or already terminal: rejected, consistently
            return self._json(409, {**out, "error":
                                    f"job is {out['state']}, not queued"})
        self._json(200, out)

    # -- result streaming -----------------------------------------------
    def _send_result(self, job_id: str, dataset: str | None) -> None:
        try:
            remote = self.service.result_file(job_id, dataset)
            if remote is not None:        # broker mode: stream the file
                return self._send_result_file(remote[1], remote[0])
            ds, transport = self.service.result_dataset(job_id, dataset)
        except KeyError as e:
            return self._error(404, str(e))
        except RuntimeError as e:
            return self._error(409, str(e))
        header = _npy_header(ds.shape, ds.dtype)
        self.send_response(200)
        self.send_header("Content-Type", "application/x-npy")
        self.send_header("Content-Length", str(len(header) + ds.nbytes))
        self.send_header("X-Dataset", ds.name)
        self.end_headers()
        self.wfile.write(header)
        backing = ds.backing
        if isinstance(backing, ChunkedFile):
            # chunk-row slabs straight off the checkpoint-layer file
            # format: O(slab) RAM however big the volume is
            backing.flush()
            step = backing.chunks[0]
            rest = tuple(slice(0, s) for s in ds.shape[1:])
            for i in range(0, ds.shape[0], step):
                slab = backing.read(
                    (slice(i, min(i + step, ds.shape[0])),) + rest)
                self.wfile.write(np.ascontiguousarray(slab).tobytes())
        else:
            arr = np.ascontiguousarray(np.asarray(transport.read(ds)))
            self.wfile.write(arr.tobytes())

    def _send_sweep_result(self, sweep_id: str,
                           dataset: str | None) -> None:
        """Stream the STACKED sweep result as one ``.npy``: shape
        ``(*grid_shape, *variant_shape)`` — the swept parameter axes are
        the new leading dimension(s) (Savu's tuning dimension), variants
        in C grid order.  One variant is materialised at a time, so RAM
        stays O(variant) however wide the grid is."""
        svc = self.service
        try:
            group, shape, dtype, first = svc.sweeps.result_plan(
                sweep_id, dataset)
        except KeyError as e:
            return self._error(404, str(e))
        except RuntimeError as e:
            return self._error(409, str(e))
        header = _npy_header(shape, dtype)
        body = int(np.prod(shape)) * np.dtype(dtype).itemsize
        self.send_response(200)
        self.send_header("Content-Type", "application/x-npy")
        self.send_header("Content-Length", str(len(header) + body))
        self.send_header("X-Sweep-Id", group.sweep_id)
        self.end_headers()
        self.wfile.write(header)
        self.wfile.write(np.ascontiguousarray(first).tobytes())
        for job in group.jobs[1:]:
            arr = np.ascontiguousarray(svc._variant_array(job.job_id,
                                                          dataset))
            if arr.shape != first.shape or arr.dtype != first.dtype:
                # headers are gone — abort the stream rather than ship
                # a silently corrupt stack (identical chains make this
                # unreachable in practice)
                raise RuntimeError(
                    f"sweep {sweep_id!r}: variant {job.job_id!r} shape/"
                    f"dtype {arr.shape}/{arr.dtype} != "
                    f"{first.shape}/{first.dtype}")
            self.wfile.write(arr.tobytes())

    def _send_result_file(self, path: str, dataset: str | None) -> None:
        """Stream a worker-delivered ``.npy`` file block-wise (broker
        mode) — O(block) RAM, same contract as the chunk-slab path."""
        size = os.path.getsize(path)
        self.send_response(200)
        self.send_header("Content-Type", "application/x-npy")
        self.send_header("Content-Length", str(size))
        if dataset:
            self.send_header("X-Dataset", dataset)
        self.end_headers()
        with open(path, "rb") as fh:
            while True:
                block = fh.read(1 << 20)
                if not block:
                    break
                self.wfile.write(block)
