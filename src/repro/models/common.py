"""Model configuration + parameter-initialisation utilities."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # rope
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0     # chatglm-style partial rotary
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 0             # every k-th layer is MoE (0 = none)
    #: GShard-style grouped dispatch: tokens are grouped by DP shard and
    #: scattered into group-LOCAL capacity buffers — the expert
    #: scatter/gather stops crossing shards (§Perf thread A).  False =
    #: flat global-capacity dispatch (the paper-era baseline).
    moe_grouped: bool = False
    # ssm / recurrent
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    slstm_every: int = 0           # xlstm: every k-th layer is sLSTM
    attn_every: int = 0            # zamba: shared attn after every k layers
    # enc-dec
    n_enc_layers: int = 0
    # vlm / audio stubs
    frontend: str = ""             # 'patch' | 'mel' | ''
    max_frames: int = 0
    # numerics / execution
    dtype: Any = jnp.bfloat16      # activation/weight compute dtype
    param_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "dots"     # 'dots' | 'nothing' (memory-tightest)
    #: when n_heads doesn't divide the model axis (llava's 56H on a
    #: 16-way TP), shard attention activations over SEQ instead of
    #: replicating every head on every device (context-parallel
    #: attention; §Perf iteration C).
    seq_shard_fallback: bool = False
    use_flash: bool = False        # pallas attention (TPU target)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters (for 6ND model-flops accounting)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
            self.n_heads * hd * d
        if self.is_moe:
            every = max(1, self.moe_every)
            n_moe = self.n_layers // every
            n_dense = self.n_layers - n_moe
            moe_ffw = (self.n_experts * 3 * d * self.moe_d_ff +
                       self.n_experts * d +
                       self.n_shared_experts * 3 * d * self.moe_d_ff)
            ffw_total = n_moe * moe_ffw + n_dense * 3 * d * self.d_ff
        else:
            ffw_total = self.n_layers * 3 * d * self.d_ff
        norm = 2 * d
        per_layer = attn + norm
        total = emb + self.n_layers * per_layer + ffw_total
        if self.family == "encdec":
            total += self.n_enc_layers * per_layer + self.n_layers * \
                (d * hd * (self.n_heads + 2 * self.n_kv_heads) +
                 self.n_heads * hd * d)  # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        every = max(1, self.moe_every)
        n_moe = self.n_layers // every
        all_expert = n_moe * self.n_experts * 3 * d * self.moe_d_ff
        active_expert = n_moe * max(1, self.top_k) * 3 * d * self.moe_d_ff
        return int(self.param_count() - all_expert + active_expert)


def truncated_normal(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, in_dim: int, out_shape: tuple[int, ...], dtype):
    std = 1.0 / math.sqrt(in_dim)
    return truncated_normal(key, out_shape, std, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
