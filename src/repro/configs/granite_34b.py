"""granite-34b [dense] — llama-arch code model (arXiv:2405.04324; hf).

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
kv=1 is MQA: KV projections replicate across the model axis (the
standard MQA TP fallback; see models/sharding.py).
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "granite-34b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    rope_theta=10_000.0, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=1,
    d_ff=192, vocab=257, head_dim=16,
    dtype=jnp.float32, remat=False)
