"""Tomography processing plugins — the paper's standard full-field chain
(§II.A): correction/linearisation → (ring removal | Paganin phase
retrieval) → sinogram filtering → FBP reconstruction.

Every plugin is a thin Savu-style shell over a kernels/ op (Pallas on
TPU, interpret-validated here) or a jnp routine; the framework owns the
slicing/sharding per the declared pattern.
"""
from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp

from ..core.dataset import DataSet
from ..core.patterns import PROJECTION, SINOGRAM, TIMESERIES, VOLUME_XZ
from ..core.plugin import BaseFilter, BaseLoader, BasePlugin, BaseRecon, BaseSaver
from ..kernels.backproject.ops import backproject
from ..kernels.correction.ops import correct
from ..kernels.sino_filter.ops import filter_sino
from ..kernels.sino_filter.ref import make_filter
from .geometry import ParallelGeometry
from .phantom import simulate_raw_scan


# ----------------------------------------------------------------------
class SyntheticTomoLoader(BaseLoader):
    """Creates a raw full-field scan (θ, y, x) from a phantom — the
    nx_tomo_loader analogue, with dark/flat fields in metadata."""

    name = "synthetic_tomo_loader"
    parameters = {"n_det": 64, "n_angles": 64, "n_rows": 4, "noise": 0.0,
                  "seed": 0, "scan": None}
    data_params = ("seed", "scan")      # dataset identity, not pipeline

    def load(self) -> list[DataSet]:
        p = self.params
        scan = p["scan"]
        if scan is None:
            from .phantom import phantom_stack
            geom = ParallelGeometry(p["n_angles"], p["n_det"], p["n_rows"])
            vol = phantom_stack(p["n_det"], p["n_rows"])
            scan = simulate_raw_scan(vol, geom, noise=p["noise"],
                                     seed=p["seed"])
        else:
            geom = ParallelGeometry(scan["data"].shape[0],
                                    scan["data"].shape[2],
                                    scan["data"].shape[1])
        data = scan["data"]
        ds = DataSet(self.out_dataset_names[0], data.shape, data.dtype,
                     ("rotation_angle", "detector_y", "detector_x"),
                     backing=lambda: data)      # lazy (paper §III.F.2)
        ds.add_pattern(PROJECTION, core=("detector_y", "detector_x"),
                       slice_=("rotation_angle",))
        ds.add_pattern(SINOGRAM, core=("rotation_angle", "detector_x"),
                       slice_=("detector_y",))
        ds.metadata.update({
            "dark": scan["dark"], "flat": scan["flat"],
            "mu": scan.get("mu", 1.0), "geometry": geom,
            "truth": scan.get("truth"),
        })
        return [ds]


class DarkFlatCorrection(BaseFilter):
    """(raw−dark)/(flat−dark), clip, −log — fused Pallas kernel."""

    name = "dark_flat_correction"
    pattern_name = PROJECTION
    frames = 1
    parameters = {"use_pallas": True}

    def setup(self, in_datasets):
        (din,) = in_datasets
        self._dark = jnp.asarray(din.metadata["dark"].astype(np.float32))
        self._flat = jnp.asarray(din.metadata["flat"].astype(np.float32))
        dout = din.like(self.out_dataset_names[0], dtype=np.float32)
        dout.metadata = dict(din.metadata)
        self.chunk_frames(self.pattern_name, self.frames)
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, y, x)
        return correct(block, self._dark, self._flat,
                       use_pallas=self.params["use_pallas"])


class PaganinFilter(BaseFilter):
    """Single-distance phase retrieval (Paganin 2002) — the phase-contrast
    method the paper says Savu made routine on I12/I13.  Projection-space
    low-pass:  T = −(1/μ)·ln( F⁻¹[ F[I] / (1 + τ(kx²+ky²)) ] )."""

    name = "paganin_filter"
    pattern_name = PROJECTION
    frames = 1
    parameters = {"tau": 10.0}   # δ·z/μ lumped constant, pixel units
    # tau only shapes self._denom (a jit constant), so it is sweepable:
    # variants with different tau share one compiled program
    tunable_params = ("tau",)

    def setup(self, in_datasets):
        (din,) = in_datasets
        dout = din.like(self.out_dataset_names[0], dtype=np.float32)
        dout.metadata = dict(din.metadata)
        ny, nx = din.shape[1], din.shape[2]
        ky = np.fft.fftfreq(ny)[:, None]
        kx = np.fft.fftfreq(nx)[None, :]
        self._denom = jnp.asarray(
            1.0 / (1.0 + self.params["tau"] * (kx ** 2 + ky ** 2)),
            dtype=jnp.complex64)
        self.chunk_frames(self.pattern_name, self.frames)
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, y, x) — already −log corrected
        intensity = jnp.exp(-block)            # back to transmission
        spec = jnp.fft.fft2(intensity.astype(jnp.complex64), axes=(1, 2))
        filt = jnp.real(jnp.fft.ifft2(spec * self._denom[None], axes=(1, 2)))
        return -jnp.log(jnp.clip(filt, 1e-6, None))


class RingRemoval(BaseFilter):
    """Sinogram-space stripe suppression: subtract the smoothed column
    mean (a standard mean-filter ring-removal; operates per sinogram)."""

    name = "ring_removal"
    pattern_name = SINOGRAM
    frames = 1
    parameters = {"kernel": 9, "strength": 1.0}
    # strength scales the correction as a float jit constant
    # (self._strength below), so it is sweepable; kernel selects shapes
    # and stays a static trace-time value
    tunable_params = ("strength",)

    def setup(self, in_datasets):
        (din,) = in_datasets
        dout = din.like(self.out_dataset_names[0], dtype=np.float32)
        dout.metadata = dict(din.metadata)
        self._strength = float(self.params["strength"])
        self.chunk_frames(self.pattern_name, self.frames)
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, angles, x)
        col_mean = jnp.mean(block, axis=1, keepdims=True)   # (m, 1, x)
        k = int(self.params["kernel"])
        pad = k // 2
        padded = jnp.pad(col_mean, ((0, 0), (0, 0), (pad, pad)), mode="edge")
        kern = jnp.ones((k,), block.dtype) / k
        smooth = jax.vmap(lambda r: jnp.convolve(r, kern, mode="valid"))(
            padded[:, 0, :])[:, None, :]
        stripe = col_mean - smooth
        return block - self._strength * stripe


class SinogramFilter(BaseFilter):
    """Frequency-domain ramp filtering of sinogram rows (FBP step 1)."""

    name = "sinogram_filter"
    pattern_name = SINOGRAM
    frames = 1
    # cutoff: fraction of Nyquist above which the response is zeroed —
    # the classic Savu tuning knob.  It only shapes self._filt (a jit
    # constant), so sweep variants share one compiled program.
    parameters = {"kind": "shepp", "use_pallas": True, "cutoff": 1.0}
    tunable_params = ("cutoff",)

    def setup(self, in_datasets):
        (din,) = in_datasets
        dout = din.like(self.out_dataset_names[0], dtype=np.float32)
        dout.metadata = dict(din.metadata)
        n_det = din.shape[din.label_index("detector_x")]
        filt = make_filter(n_det, self.params["kind"])
        cutoff = float(self.params["cutoff"])
        nyq_frac = np.linspace(0.0, 1.0, filt.shape[0], dtype=np.float32)
        filt = (filt * (nyq_frac <= cutoff)).astype(np.float32)
        self._filt = jnp.asarray(filt)
        self.chunk_frames(self.pattern_name, self.frames)
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, angles, x)
        return filter_sino(block, self._filt,
                           use_pallas=self.params["use_pallas"])


class FBPRecon(BaseRecon):
    """Filtered backprojection — sinogram in, volume slice out (Pallas
    backprojection kernel; the chain's compute hot spot)."""

    name = "fbp_recon"
    n_in_datasets = 1
    n_out_datasets = 1
    out_pattern_name = VOLUME_XZ
    parameters = {"use_pallas": True, "out_size": None}

    def setup(self, in_datasets):
        (din,) = in_datasets
        n_angles = din.shape[din.label_index("rotation_angle")]
        n_det = din.shape[din.label_index("detector_x")]
        n_rows = din.shape[din.label_index("detector_y")]
        out_size = self.params["out_size"] or n_det
        self._out_size = out_size
        geom: ParallelGeometry = din.metadata["geometry"]
        # slice to the input's angle count so a streaming preview (an
        # angle-prefix of the full scan) reconstructs from exactly the
        # acquired angles
        self._angles = jnp.asarray(
            geom.angles.astype(np.float32)[:n_angles])
        self._mu = float(din.metadata.get("mu", 1.0))
        dout = DataSet(self.out_dataset_names[0],
                       (n_rows, out_size, out_size), np.float32,
                       ("voxel_y", "voxel_z", "voxel_x"))
        dout.add_pattern(VOLUME_XZ, core=("voxel_z", "voxel_x"),
                         slice_=("voxel_y",))
        dout.metadata = dict(din.metadata)
        for pd in self.in_data:
            pd.pattern_name = SINOGRAM
            pd.n_frames = 1
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, angles, x)
        img = backproject(block, self._angles, self._out_size,
                          use_pallas=self.params["use_pallas"])
        return img / self._mu      # linearised path -> attenuation units


class UpstreamLoader(BaseLoader):
    """Workflow stage input (docs/workflows.md): loads another job's
    result volume as this chain's starting dataset.

    In a spec the reference is ``{"data": {"from_job": "<node>",
    "dataset": "<name>"}}`` (or the split ``from_job``/``dataset``
    params).  The service resolves it before execution — the scheduler
    injects the array into ``data``, the broker splices a shared-fs
    ``path``, a remote worker fetches over HTTP — so by ``load()`` time
    exactly one of ``data`` (an array) or ``path`` is materialised.
    All four params are ``data_params``: they select WHICH volume, so
    downstream chains of different workflows share one chain signature
    (and compiled programs) and may gang.
    """

    name = "upstream_loader"
    parameters = {"from_job": None, "dataset": None, "data": None,
                  "path": None}
    data_params = ("from_job", "dataset", "data", "path")

    def load(self) -> list[DataSet]:
        p = self.params
        data = p["data"]
        if isinstance(data, dict):
            raise RuntimeError(
                f"upstream_loader: unresolved upstream reference {data!r} "
                f"— submit through the service (POST /workflows) so it "
                f"is resolved at dispatch time")
        if data is None and p["path"]:
            data = np.load(p["path"])
        if data is None:
            raise RuntimeError(
                "upstream_loader: no input — neither a resolved 'data' "
                "array nor a 'path' was provided")
        arr = np.asarray(data)
        if arr.ndim == 2:
            arr = arr[None]
        if arr.ndim != 3:
            raise RuntimeError(
                f"upstream_loader: expected a (y, z, x) volume, got "
                f"shape {arr.shape}")
        ds = DataSet(self.out_dataset_names[0], arr.shape, arr.dtype,
                     ("voxel_y", "voxel_z", "voxel_x"),
                     backing=lambda: arr)
        ds.add_pattern(VOLUME_XZ, core=("voxel_z", "voxel_x"),
                       slice_=("voxel_y",))
        return [ds]


class Downsample(BaseFilter):
    """Block-mean downsampling of a reconstructed volume's in-plane
    dims — the classic post-recon reduction stage (Ot2Rec-style staged
    campaigns run it between reconstruction and quantification)."""

    name = "downsample"
    pattern_name = VOLUME_XZ
    frames = 1
    parameters = {"factor": 2}

    def setup(self, in_datasets):
        (din,) = in_datasets
        f = int(self.params["factor"])
        if f < 1:
            raise ValueError(f"downsample: factor must be >= 1, got {f}")
        y = din.shape[din.label_index("voxel_y")]
        z = din.shape[din.label_index("voxel_z")]
        x = din.shape[din.label_index("voxel_x")]
        if z % f or x % f:
            raise ValueError(
                f"downsample: factor {f} must divide the in-plane dims "
                f"({z}, {x})")
        dout = DataSet(self.out_dataset_names[0], (y, z // f, x // f),
                       np.float32, ("voxel_y", "voxel_z", "voxel_x"))
        dout.add_pattern(VOLUME_XZ, core=("voxel_z", "voxel_x"),
                         slice_=("voxel_y",))
        dout.metadata = dict(din.metadata)
        self.chunk_frames(self.pattern_name, self.frames)
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, z, x)
        f = int(self.params["factor"])
        m, z, x = block.shape
        return jnp.mean(
            block.reshape(m, z // f, f, x // f, f).astype(jnp.float32),
            axis=(2, 4))


class Quantify(BaseFilter):
    """Per-slice summary statistics (mean/std/min/max) of a volume —
    the terminal quantification stage of a recon → downsample →
    quantify workflow."""

    name = "quantify"
    n_in_datasets = 1
    n_out_datasets = 1
    out_pattern_name = TIMESERIES
    parameters: dict = {}

    def setup(self, in_datasets):
        (din,) = in_datasets
        y = din.shape[din.label_index("voxel_y")]
        dout = DataSet(self.out_dataset_names[0], (y, 4), np.float32,
                       ("voxel_y", "stat"))
        dout.add_pattern(TIMESERIES, core=("stat",), slice_=("voxel_y",))
        dout.metadata = dict(din.metadata)
        for pd in self.in_data:
            pd.pattern_name = VOLUME_XZ
            pd.n_frames = 1
        return [dout]

    def process_frames(self, frames):
        (block,) = frames          # (m, z, x)
        flat = block.reshape(block.shape[0], -1).astype(jnp.float32)
        return jnp.stack([jnp.mean(flat, axis=1), jnp.std(flat, axis=1),
                          jnp.min(flat, axis=1), jnp.max(flat, axis=1)],
                         axis=-1)


class HDF5LikeSaver(BaseSaver):
    """Terminal saver: flushes chunked files / materialises arrays and
    records the manifest entry (the NeXus-link analogue)."""

    name = "hdf5_saver"

    def save(self, dataset: DataSet) -> None:
        backing = dataset.backing
        if hasattr(backing, "flush"):
            backing.flush()
        dataset.metadata["saved"] = True
