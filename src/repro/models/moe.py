"""Mixture-of-Experts FFN with capacity-based token dispatch.

Expert parallelism: the stacked expert weights shard over the ``model``
axis; the dispatch scatter / combine gather between token-sharded
activations (``data``) and expert-sharded buffers is the MoE
pattern-transition (TOKENS -> EXPERT), lowered by XLA to the
all-to-all the paper would have done through parallel files.

Dispatch is MaxText-style: top-k routing -> per-expert position via a
cumulative sum over the one-hot choices -> scatter into (E, C, d)
buffers, with tokens beyond expert capacity dropped (standard GShard
semantics; capacity_factor controls the drop rate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, split_keys
from .mlp import init_mlp, mlp_fwd
from .sharding import get_rules


def init_moe(key, cfg: ModelConfig):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], d, (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], d, (e, d, ff), cfg.param_dtype),
        "w_up": dense_init(ks[2], d, (e, d, ff), cfg.param_dtype),
        "w_down": dense_init(ks[3], ff, (e, ff, d), cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               cfg.n_shared_experts * ff, cfg.param_dtype)
    return p


#: token budget per dispatch — longer inputs are processed in sequence
#: chunks (lax.map) so the one-hot position cumsum and the (E, C, d)
#: buffers stay bounded (prefill_32k would otherwise dispatch 1M tokens
#: at once and the positional prefix-sum dominates the step).
DISPATCH_CHUNK_TOKENS = 65_536


def moe_fwd(params, x: jnp.ndarray, cfg: ModelConfig
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    fn = _moe_dispatch_grouped if cfg.moe_grouped else _moe_dispatch
    if t > DISPATCH_CHUNK_TOKENS and \
            t % DISPATCH_CHUNK_TOKENS == 0 and \
            s % (t // DISPATCH_CHUNK_TOKENS) == 0:
        n_chunks = t // DISPATCH_CHUNK_TOKENS
        xc = x.reshape(b, n_chunks, s // n_chunks, d).swapaxes(0, 1)
        outs, auxs = jax.lax.map(
            lambda xi: fn(params, xi, cfg), xc)
        return outs.swapaxes(0, 1).reshape(b, s, d), jnp.mean(auxs)
    return fn(params, x, cfg)


def _dp_extent(r) -> int:
    if r.mesh is None:
        return 1
    sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def _moe_dispatch_grouped(params, x: jnp.ndarray, cfg: ModelConfig
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Grouped (GShard-style) dispatch: one token group per DP shard,
    group-LOCAL capacity buffers.

    The flat dispatch scatters token-sharded activations into an
    expert-sharded global buffer; XLA lowers that cross-shard scatter
    as materialise-replicated + all-reduce — measured at hundreds of
    GB/step on qwen3 (§Perf A).  Here positions are computed within
    each group and the scatter stays inside the shard; the only wire
    traffic left is reading the model-axis expert slice of each group
    buffer inside the expert FFN einsums.  Capacity is per-group
    (GShard semantics — the published formulation)."""
    r = get_rules()
    b, s, d = x.shape
    e, k = cfg.n_experts, max(1, cfg.top_k)
    t = b * s
    g = _dp_extent(r)
    while t % g:
        g //= 2
    tg = t // g
    xt = x.reshape(g, tg, d)
    xt = r.constrain(xt, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (g, tg, k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_ids, e,
                                         dtype=jnp.float32), axis=2),
                  axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    capacity = int(max(1, (tg * k * cfg.capacity_factor) // e))
    flat_ids = expert_ids.reshape(g, tg * k)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)    # (g, tgk, e)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1
    pos = jnp.sum(pos, axis=-1)                              # (g, tgk)
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, 0)

    xt_rep = jnp.repeat(xt, k, axis=1).astype(cfg.dtype)     # (g, tgk, d)
    upd = jnp.where(keep[..., None], xt_rep, 0)

    def scatter_one(ids, ps, up):
        return jnp.zeros((e, capacity, d), cfg.dtype
                         ).at[ids, ps].add(up, mode="drop")

    buf = jax.vmap(scatter_one)(flat_ids, safe_pos, upd)     # (g,e,c,d)
    buf = r.constrain(buf, "batch", None, None, None)

    dt = cfg.dtype
    # ZeRO-3 gather: expert weights are STORED d-sharded over `data`
    # (memory), but contracting over a sharded d would all-reduce the
    # full (g,e,c,f) partials — measured 292s/step on qwen3.  Gather
    # each layer's expert slice once (e stays sharded over model) and
    # contract locally: the AG is |w_expert|/TP per layer instead.
    w_gate = r.constrain(params["w_gate"].astype(dt), "expert", None,
                         None)
    w_up = r.constrain(params["w_up"].astype(dt), "expert", None, None)
    w_down = r.constrain(params["w_down"].astype(dt), "expert", None,
                         None)
    gate = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    up = jnp.einsum("gecd,edf->gecf", buf, w_up)
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    out_buf = jnp.einsum("gecf,efd->gecd", act, w_down)
    out_buf = r.constrain(out_buf, "batch", None, None, None)

    gathered = jax.vmap(lambda ob, ids, ps: ob[ids, ps])(
        out_buf, flat_ids, safe_pos)                         # (g, tgk, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * \
        gate_vals.reshape(g, tg * k, 1)
    out = jnp.sum(weighted.reshape(g, tg, k, d), axis=2).astype(cfg.dtype)

    if "shared" in params:
        out = out + mlp_fwd(params["shared"], xt, dt)

    out = out.reshape(b, s, d)
    return r.constrain(out, "batch", "seq", "embed_act"), aux


def _moe_dispatch(params, x: jnp.ndarray, cfg: ModelConfig
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    r = get_rules()
    b, s, d = x.shape
    e, k = cfg.n_experts, max(1, cfg.top_k)
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # (t, k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing aux loss (Switch):  e * Σ_e fraction_e * prob_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
        axis=0)
    aux = e * jnp.sum(me * ce)

    capacity = int(max(1, (t * k * cfg.capacity_factor) // e))

    # position of each (token, choice) within its expert
    flat_ids = expert_ids.reshape(-1)                      # (t*k,)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (t*k, e)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1          # (t*k, e)
    pos = jnp.sum(pos, axis=-1)                            # (t*k,)
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, 0)

    # scatter tokens into expert buffers (E, C, d)
    buf = jnp.zeros((e, capacity, d), cfg.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    upd = jnp.where(keep[:, None], xt[tok_idx].astype(cfg.dtype), 0)
    buf = buf.at[flat_ids, safe_pos].add(upd, mode="drop")
    buf = r.constrain(buf, "expert_act", None, None)

    # expert FFN over the buffers (weights sharded over `model`)
    dt = cfg.dtype
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    out_buf = jnp.einsum("ecf,efd->ecd", act, params["w_down"].astype(dt))
    out_buf = r.constrain(out_buf, "expert_act", None, None)

    # combine: gather each choice's result, weight by gate, sum over k
    gathered = out_buf[flat_ids, safe_pos]                  # (t*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * \
        gate_vals.reshape(-1)[:, None]
    out = jnp.sum(weighted.reshape(t, k, d), axis=1).astype(cfg.dtype)

    if "shared" in params:
        out = out + mlp_fwd(params["shared"], xt, dt)

    out = out.reshape(b, s, d)
    return r.constrain(out, "batch", "seq", "embed_act"), aux
