"""Remat policy selection (ModelConfig.remat_policy).

'dots'    — save dot outputs without batch dims (recompute elementwise):
            fastest backward, highest activation memory.
'nothing' — save only the scan carries (recompute the whole layer in
            backward): ~1.3x compute for the memory-tightest footprint.
"""
import jax


def _remat_policy(cfg):
    if cfg.remat_policy == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
