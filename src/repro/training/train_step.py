"""Training step factory: loss → grad → clip → AdamW, with optional
microbatched gradient accumulation (scan) and donation-friendly
signature.  The same function lowers on 1 CPU device (smoke tests) and
on the 512-chip production mesh (dry-run) — sharding comes entirely
from the in/out shardings + the pattern constraints inside the model.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.model_zoo import Model
from ..optim import AdamWConfig, adamw_update, init_opt_state


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatch: int | None = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params,
    opt_state, metrics).

    ``microbatch``: split the (global) batch into this many sequential
    accumulation chunks (grad-accumulation scan) — trades step latency
    for activation memory, the standard large-model knob.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if not microbatch or microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % microbatch == 0, (b, microbatch)
            return x.reshape((microbatch, b // microbatch) + x.shape[1:])

        chunks = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

        def body(acc, chunk):
            loss_acc, grad_acc = acc
            l, g = jax.value_and_grad(loss_fn)(params, chunk)
            grad_acc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), grad_acc, g)
            return (loss_acc + l, grad_acc), None

        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero),
                                            chunks)
        inv = 1.0 / microbatch
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def init_training(model: Model, key, *, moments_dtype: str = "fp32"
                  ) -> tuple[Any, dict]:
    params = model.init(key)
    return params, init_opt_state(params, moments_dtype)
