"""Unit tests for the telemetry substrate (``repro.obs``): the trace
model (span identity, parent links, merge dedup, the shipping protocol),
the metrics registry (counters/gauges/reservoir histograms and the
Prometheus exposition), and the span-backed Profiler's back-compat
surface.  Quantile math gets a hypothesis property test when hypothesis
is installed."""
import math
import threading
import time

import pytest

from repro.core.profiler import Profiler
from repro.obs import (CATALOGUE, Counter, Gauge, Histogram,
                       MetricsRegistry, Span, Trace, catalogue_names,
                       current_trace, prometheus_name, register_catalogue,
                       render_gantt, use_trace)


# ============================================================== tracing
def test_span_wire_roundtrip():
    s = Span("plugin.fbp.process", 10.0, 11.5, worker_id="w0",
             parent_id="abc", attrs={"phase": "process", "gang": 2})
    back = Span.from_wire(s.to_wire())
    assert back.name == s.name and back.span_id == s.span_id
    assert back.start == 10.0 and back.end == 11.5
    assert back.worker_id == "w0" and back.parent_id == "abc"
    assert back.attrs == s.attrs


def test_span_context_manager_nests_parent_links():
    tr = Trace("t1", worker_id="w0")
    with tr.span("attempt", attempt=1) as outer:
        with tr.span("plugin.fbp.process") as inner:
            pass
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.end is not None and inner.end is not None
    assert all(s.worker_id == "w0" for s in tr.spans())


def test_span_error_attr_on_exception():
    tr = Trace()
    with pytest.raises(RuntimeError):
        with tr.span("attempt"):
            raise RuntimeError("boom")
    (s,) = tr.spans()
    assert s.attrs["error"] == "RuntimeError" and s.end is not None


def test_record_defaults_parent_to_open_span():
    tr = Trace()
    with tr.span("plugin.fbp.process") as p:
        tr.record("compile", time.time() - 1, time.time())
    compile_span = [s for s in tr.spans() if s.name == "compile"][0]
    assert compile_span.parent_id == p.span_id


def test_merge_dedups_on_span_id_and_returns_only_new():
    tr = Trace("job-1")
    wire = [Span("lease", 1.0, 2.0, span_id="aaa").to_wire(),
            Span("plugin.x.process", 1.2, 1.8, span_id="bbb").to_wire()]
    first = tr.merge(wire)
    assert [s.span_id for s in first] == ["aaa", "bbb"]
    # a redelivered heartbeat adds nothing
    assert tr.merge(wire) == []
    assert len(tr) == 2
    # malformed entries are skipped, not fatal
    assert tr.merge([{"nonsense": True}, None]) == []


def test_ship_unship_protocol():
    tr = Trace()
    tr.record("a", 1.0, 2.0)
    open_span = tr.begin("b")                # unfinished: never shipped
    batch = tr.take_unshipped()
    assert [s.name for s in batch] == ["a"]
    assert tr.take_unshipped() == []         # marked shipped
    tr.unship(batch)                         # failed send: retry later
    assert [s.name for s in tr.take_unshipped()] == ["a"]
    tr.finish(open_span)
    assert [s.name for s in tr.take_unshipped()] == ["b"]


def test_per_thread_parent_stacks_keep_traces_straight():
    tr = Trace()
    seen = {}

    def worker(tag):
        with tr.span(f"outer.{tag}") as o, tr.span(f"inner.{tag}") as i:
            seen[tag] = (o.span_id, i.parent_id)

    ts = [threading.Thread(target=worker, args=(t,)) for t in "ab"]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for tag in "ab":
        outer_id, inner_parent = seen[tag]
        assert inner_parent == outer_id


def test_current_trace_contextvar():
    assert current_trace() is None
    tr = Trace()
    with use_trace(tr):
        assert current_trace() is tr
    assert current_trace() is None


def test_render_gantt_layout():
    spans = [Span("queue.wait", 0.0, 1.0),
             Span("plugin.fbp.process", 1.0, 3.0, worker_id="w1")]
    out = render_gantt(spans, width=40)
    lines = out.splitlines()
    assert "timeline" in lines[0] and "3.000s total" in lines[0]
    assert lines[1].startswith("queue.wait")
    assert "w1" in lines[2] and "#" in lines[2]
    assert render_gantt([]) == "(no spans)"


# ======================================================= profiler bridge
def test_profiler_is_span_backed():
    tr = Trace("job-9", worker_id="w3")
    prof = Profiler(trace=tr)
    prof.record("fbp", "process", 1.0, 3.0, devices=2, flops=1e9)
    with prof.timer("fbp", "post", 1):
        pass
    names = [s.name for s in tr.spans()]
    assert "plugin.fbp.process" in names and "plugin.fbp.post" in names
    evs = prof.events
    assert {e.phase for e in evs} == {"process", "post"}
    proc = [e for e in evs if e.phase == "process"][0]
    assert proc.devices == 2 and proc.flops == 1e9 and proc.wall == 2.0
    assert "profile" in prof.report()


def test_profiler_default_trace_standalone():
    prof = Profiler()                        # no trace given: owns one
    prof.record("x", "process", 0.0, 1.0)
    assert len(prof.events) == 1
    tot = prof.totals()
    assert tot["x"] == pytest.approx(1.0)


# ============================================================== metrics
def test_counter_monotonic():
    c = Counter("jobs.completed")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_callback_and_error_isolation():
    g = Gauge("queue.depth", fn=lambda: 7)
    assert g.value == 7.0
    g2 = Gauge("bad")
    g2.set(3)
    assert g2.value == 3.0
    g2.set_function(lambda: 1 / 0)           # scrape must not raise
    assert math.isnan(g2.value)


def test_histogram_exact_count_sum_and_quantiles():
    h = Histogram("lat", reservoir_size=100)
    for v in range(100):
        h.observe(v)
    assert h.count == 100 and h.sum == pytest.approx(4950.0)
    assert h.quantile(0.0) == 0
    assert h.quantile(1.0) == 99
    assert h.quantile(0.5) == 50
    with pytest.raises(ValueError):
        h.quantile(1.5)
    assert Histogram("empty").quantile(0.5) is None


def test_histogram_reservoir_bounds_memory():
    h = Histogram("lat", reservoir_size=64, seed=1)
    for v in range(10_000):
        h.observe(float(v))
    assert len(h._reservoir) == 64
    assert h.count == 10_000
    # the sample stays representative: median of U[0, 10k) within 25%
    assert 2_500 <= h.quantile(0.5) <= 7_500


def test_histogram_quantile_properties_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                              width=32),
                    min_size=1, max_size=200),
           st.floats(min_value=0.0, max_value=1.0))
    def prop(values, q):
        h = Histogram("x", reservoir_size=1000)
        for v in values:
            h.observe(v)
        got = h.quantile(q)
        # every quantile is an actual observation, bracketed by min/max,
        # and monotone in q
        assert got in [float(v) for v in values]
        assert min(values) <= got <= max(values)
        assert h.quantile(0.0) == min(values)
        assert h.quantile(1.0) == max(values)
        qs = [h.quantile(x) for x in (0.0, 0.25, 0.5, 0.75, 1.0)]
        assert qs == sorted(qs)

    prop()


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    c1 = reg.counter("jobs.completed")
    assert reg.counter("jobs.completed") is c1
    with pytest.raises(ValueError):
        reg.gauge("jobs.completed")
    reg.histogram("job.latency.e2e").observe(1.0)
    snap = reg.snapshot()
    assert snap["jobs.completed"] == 0
    assert snap["job.latency.e2e"]["count"] == 1
    assert snap["job.latency.e2e"]["p50"] == 1.0


def test_prometheus_rendering_format():
    reg = MetricsRegistry()
    reg.counter("jobs.completed", help="done jobs").inc(3)
    reg.gauge("queue.depth").set(2)
    h = reg.histogram("job.latency.e2e")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    text = reg.render_prometheus()
    assert "# HELP jobs_completed done jobs" in text
    assert "# TYPE jobs_completed counter" in text
    assert "jobs_completed 3" in text
    assert "queue_depth 2" in text
    assert "# TYPE job_latency_e2e summary" in text
    assert 'job_latency_e2e{quantile="0.5"} 0.2' in text
    assert "job_latency_e2e_count 3" in text
    assert text.endswith("\n")
    # every line is a comment or `name[{labels}] value`
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name and not name[0].isdigit()
        float(value)


def test_prometheus_name_sanitisation():
    assert prometheus_name("job.latency.e2e") == "job_latency_e2e"
    assert prometheus_name("plugin.wall.fbp-recon") == "plugin_wall_fbp_recon"
    assert prometheus_name("9lives") == "_9lives"


def test_catalogue_registers_every_name():
    reg = MetricsRegistry()
    register_catalogue(reg)
    assert set(catalogue_names()) <= set(reg.names())
    assert len(CATALOGUE) == len(set(catalogue_names()))
    text = reg.render_prometheus()
    for name in catalogue_names():
        assert prometheus_name(name) in text
    register_catalogue(reg)                  # idempotent
