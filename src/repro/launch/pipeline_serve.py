"""Multi-dataset pipeline service driver — the paper's headline claim
("simultaneous processing of multiple ... datasets") as a running
service: submit N tomography jobs, process them over shared workers with
one compiled-plugin cache, report per-job status and aggregate
throughput, and verify every reconstruction against a serial
``PluginRunner`` reference.

Three modes:

* **demo** (default) — submit ``--jobs`` synthetic scans in-process,
  drain, verify::

      PYTHONPATH=src python -m repro.launch.pipeline_serve --jobs 4
      PYTHONPATH=src python -m repro.launch.pipeline_serve --jobs 8 \\
          --workers 4 --batch --transport sharded

* **server** — bind the JSON-over-HTTP front end and serve until
  interrupted (see ``docs/service.md``)::

      PYTHONPATH=src python -m repro.launch.pipeline_serve --serve 8973

* **client** — talk to a running server::

      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          --url http://127.0.0.1:8973 submit --demo-chain --wait

  including parameter sweeps (Savu's parameter tuning — the service
  gang-batches the variants and serves the stacked result; see
  ``docs/sweeps.md``)::

      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          sweep --demo-chain --param sinogram_filter.cutoff=0.4:1.0:7 \\
          --metric sharpness --wait --out sweep.npy

  workflow DAGs — jobs that depend on jobs, one atomic spec-v3
  envelope (``docs/workflows.md``)::

      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          workflow --demo --wait

  and live streaming acquisition (``docs/streaming.md``) — submit a
  v2 streaming job, feed frames as they "arrive", peek at the partial
  reconstruction before EOF::

      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          submit --demo-chain --streaming --job-id scan0
      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          ingest scan0 --synthetic --chunk 8 --rate 4
      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          preview scan0 --out live.npy

  plus the cluster health plane (``docs/observability.md``) — SLO rule
  states, the structured event log (tail with ``--follow``), the
  per-worker scoreboard::

      PYTHONPATH=src python -m repro.launch.pipeline_serve client slo
      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          events --follow --format text
      PYTHONPATH=src python -m repro.launch.pipeline_serve client \\
          cluster --format text

* **multi-host demo** — ``--workers-remote N`` runs the broker and N
  detached worker *subprocesses* pulling jobs from it over HTTP (one
  queue, many worker processes — see ``docs/worker-protocol.md``)::

      PYTHONPATH=src python -m repro.launch.pipeline_serve \\
          --jobs 6 --workers-remote 2 --checkpoint-dir /tmp/ckpts

  ``--serve PORT --workers-remote N`` serves the broker for external
  workers too (N may be 0; start more with
  ``python -m repro.service.worker --url ...``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

import jax
from jax.sharding import Mesh

from ..core import (ChunkedFileTransport, InMemoryTransport, PluginRunner,
                    ShardedTransport)
from ..service import (METRICS, CheckpointStore, CompileCache, JobQueue,
                       PipelineClient, PipelineScheduler, PipelineService,
                       ServiceError, to_spec)
from ..service.worker import spawn_local_workers
from ..tomo import standard_chain

_EPILOG = """\
transport notes:
  --transport chunked   every dataset lives in a chunk-addressed file
                        (RAM is O(frames), never O(dataset)); with
                        --checkpoint-dir the checkpointer HARD-LINKS
                        those chunk files and writes only dirty-chunk
                        increments, so per-step checkpoints are cheap
                        (see docs/checkpoint-format.md)
  --transport sharded   jit-compiled plugins on the device mesh, with
                        the process-level compile cache

scheduling notes:
  --batch gangs queued jobs with identical chain signatures: each
  plugin step runs as ONE compiled call over all gang members, driven
  by the single worker that popped the gang — so for identical-chain
  workloads --workers does NOT multiply gang throughput; extra workers
  only help when distinct chains (or resumed jobs, which always step
  solo) are mixed in.  --batch also disables buffer donation on the
  sharded transport (stacked gang inputs outlive the call).
"""


def _chain(args, seed: int):
    return standard_chain(n_det=args.n_det, n_angles=args.n_angles,
                          n_rows=args.n_rows, seed=seed,
                          use_pallas=args.pallas)


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.pipeline_serve",
        description=__doc__.split("\n\n")[0],
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--jobs", type=int, default=4,
                    help="demo mode: number of synthetic scans to submit")
    ap.add_argument("--workers", type=int, default=2,
                    help="scheduler worker threads (see scheduling notes "
                         "below for the --batch interaction)")
    ap.add_argument("--transport", default="sharded",
                    choices=("sharded", "inmemory", "chunked"),
                    help="execution transport (see transport notes below)")
    ap.add_argument("--batch", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="gang identical chains into one compiled call "
                         "per plugin step (ganged steps run under a "
                         "single worker; see scheduling notes)")
    ap.add_argument("--fuse", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="fuse consecutive linear plugins into one jit")
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="demo mode: compare each job against a serial "
                         "PluginRunner")
    ap.add_argument("--pallas", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--n-det", type=int, default=48)
    ap.add_argument("--n-angles", type=int, default=48)
    ap.add_argument("--n-rows", type=int, default=2)
    ap.add_argument("--max-pending", type=int, default=64,
                    help="admission bound: submissions past this many "
                         "non-terminal jobs get QueueFull / HTTP 429")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist per-plugin checkpoints here; a killed "
                         "job resubmitted with the same id resumes at "
                         "the last finished plugin")
    ap.add_argument("--serve", type=int, metavar="PORT", default=None,
                    help="serve the HTTP front end on PORT instead of "
                         "running the demo (POST /jobs, GET /jobs/{id}, "
                         "GET /jobs/{id}/result, GET /stats, ...)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --serve")
    ap.add_argument("--max-history", type=int, default=256,
                    help="--serve: retained terminal jobs (older results "
                         "are evicted)")
    ap.add_argument("--batch-max", type=int, default=4,
                    help="--batch: gang size bound")
    ap.add_argument("--workers-remote", type=int, default=None,
                    metavar="N",
                    help="broker mode: spawn N worker SUBPROCESSES "
                         "pulling jobs over HTTP (demo), or serve the "
                         "broker for external workers (--serve; N may "
                         "be 0)")
    ap.add_argument("--lease-ttl", type=float, default=15.0,
                    help="broker mode: seconds a lease survives "
                         "without a worker heartbeat before the job is "
                         "requeued")
    ap.add_argument("--shared-fs", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="broker mode: workers write results straight "
                         "into the broker's results_dir instead of "
                         "uploading over HTTP")
    ap.add_argument("--token", default=None,
                    help="--serve: require this bearer token on every "
                         "mutating request (Authorization: Bearer ...); "
                         "spawned workers get it automatically")
    ap.add_argument("--trace-spool", default=None, metavar="DIR",
                    help="--serve: spool evicted terminal-job traces "
                         "to this directory (bounded ring; "
                         "docs/observability.md)")
    ap.add_argument("--cost-analysis",
                    action=argparse.BooleanOptionalAction, default=False,
                    help="sharded transport: attach per-plugin HLO "
                         "FLOPs/bytes-accessed and peak-memory "
                         "profiles to process spans (one extra AOT "
                         "compile per distinct step; "
                         "docs/observability.md)")
    return ap


def _transport_factory(args, cache: CompileCache):
    if args.transport == "sharded":
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        # gang batching stacks job inputs — donation would invalidate
        # buffers the stack still references.  Checkpointing no longer
        # forces donation off: the runner's liveness analysis donates a
        # buffer only at its FINAL use, so every dataset a checkpoint
        # (or a branching chain) still needs stays alive.
        donate = not args.batch
        cost = getattr(args, "cost_analysis", False)
        return lambda job: ShardedTransport(mesh, donate=donate,
                                            compile_cache=cache,
                                            cost_analysis=cost)
    if args.transport == "chunked":
        return lambda job: ChunkedFileTransport()
    return lambda job: InMemoryTransport()


# ----------------------------------------------------------------------
def _serve_main(args) -> None:
    workers = []
    if args.workers_remote is not None:       # broker mode
        service = PipelineService(
            workers_remote=True, max_pending=args.max_pending,
            max_history=args.max_history, lease_ttl=args.lease_ttl,
            token=args.token, trace_spool=args.trace_spool)
        host, port = service.serve(host=args.host, port=args.serve,
                                   block=False)
        workers = spawn_local_workers(
            f"http://{host}:{port}", args.workers_remote,
            transport=args.transport,
            checkpoint_dir=args.checkpoint_dir,
            shared_fs=args.shared_fs, token=args.token,
            cost_analysis=args.cost_analysis)
        print(f"pipeline broker listening on http://{host}:{port}  "
              f"({len(workers)} local worker processes, lease_ttl="
              f"{args.lease_ttl}s; attach more with `python -m "
              f"repro.service.worker --url http://{host}:{port}`)",
              flush=True)
    else:
        cache = CompileCache()
        checkpoints = (CheckpointStore(args.checkpoint_dir)
                       if args.checkpoint_dir else None)
        service = PipelineService(
            transport_factory=_transport_factory(args, cache),
            n_workers=args.workers, max_pending=args.max_pending,
            max_history=args.max_history, checkpoints=checkpoints,
            batch_identical=args.batch, batch_max=args.batch_max,
            fuse=args.fuse, compile_cache=cache,
            token=args.token, trace_spool=args.trace_spool)
        host, port = service.serve(host=args.host, port=args.serve,
                                   block=False)
        print(f"pipeline service listening on http://{host}:{port}  "
              f"({args.workers} workers, transport={args.transport}"
              f"{', gang-batched' if args.batch else ''}"
              f"{', checkpointed' if checkpoints else ''})", flush=True)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        print("shutting down")
    finally:
        for p in workers:
            p.terminate()
        service.stop()


# ----------------------------------------------------------------------
def _remote_demo(args) -> None:
    """The multi-host demo: one queue, N worker processes.  Submit
    ``--jobs`` scans over HTTP, let the worker subprocesses pull them,
    verify every reconstruction against a serial PluginRunner."""
    service = PipelineService(
        workers_remote=True, max_pending=max(args.max_pending, args.jobs),
        lease_ttl=args.lease_ttl)
    host, port = service.serve(port=0)
    url = f"http://{host}:{port}"
    workers = spawn_local_workers(
        url, args.workers_remote, transport=args.transport,
        checkpoint_dir=args.checkpoint_dir, shared_fs=args.shared_fs,
        cost_analysis=args.cost_analysis)
    client = PipelineClient(url)
    try:
        t0 = time.time()
        ids = [client.submit(_chain(args, seed=i), job_id=f"tomo-{i:03d}",
                             metadata={"seed": i})
               for i in range(args.jobs)]
        snaps = [client.wait(jid, timeout=600) for jid in ids]
        wall = time.time() - t0
        for s in snaps:
            extra = (f" (resumed at plugin {s['resumed_from']})"
                     if s["resumed_from"] else "")
            print(f"  {s['job_id']}: {s['status']:>10s}  "
                  f"worker={s['worker_id']}  wall={s['wall']:.2f}s{extra}")
        failed = [s for s in snaps if s["state"] != "done"]
        if failed:
            for s in failed:
                print(s["error"])
            raise SystemExit(f"{len(failed)}/{len(snaps)} jobs failed")
        if args.verify:
            worst = 0.0
            for s in snaps:
                got = client.result(s["job_id"])
                ref = PluginRunner(
                    _chain(args, seed=s["metadata"]["seed"])).run()
                want = np.asarray(ref["recon"].materialise())
                np.testing.assert_allclose(got, want, rtol=1e-3,
                                           atol=1e-4)
                worst = max(worst, float(np.max(np.abs(got - want))))
            print(f"verified {len(snaps)} reconstructions against "
                  f"serial PluginRunner (max |Δ|={worst:.2e})")
        st = client.stats()
        per_worker = {w: s["jobs_done"]
                      for w, s in st["workers"].items()}
        print(f"{args.jobs} jobs in {wall:.2f}s -> "
              f"{args.jobs / wall:.2f} jobs/s  "
              f"({args.workers_remote} worker processes, "
              f"transport={args.transport})")
        print(f"per-worker jobs done: {per_worker}  "
              f"requeues: {st['jobs_requeued']}")
    finally:
        for p in workers:
            p.terminate()
        for p in workers:
            p.wait(timeout=10)
        service.stop()


# ----------------------------------------------------------------------
def _demo_main(args) -> None:
    cache = CompileCache()
    factory = _transport_factory(args, cache)
    queue = JobQueue(max_pending=args.max_pending)
    checkpoints = (CheckpointStore(args.checkpoint_dir)
                   if args.checkpoint_dir else None)
    sched = PipelineScheduler(
        queue, transport_factory=factory, n_workers=args.workers,
        checkpoints=checkpoints, batch_identical=args.batch,
        batch_max=max(args.batch_max, args.jobs), fuse=args.fuse,
        compile_cache=cache)

    jobs = [queue.submit(_chain(args, seed=i), priority=0,
                         job_id=f"tomo-{i:03d}", metadata={"seed": i})
            for i in range(args.jobs)]
    t0 = time.time()
    sched.start()
    ok = sched.drain(timeout=600)
    wall = time.time() - t0
    sched.shutdown()
    if not ok:
        raise SystemExit("timed out waiting for jobs")

    failed = [j for j in jobs if j.state.value != "done"]
    for j in jobs:
        extra = (f" (resumed at plugin {j.resumed_from})"
                 if j.resumed_from else "")
        print(f"  {j.job_id}: {j.status:>10s}  wall={j.wall:.2f}s{extra}")
    if failed:
        for j in failed:
            print(j.metadata.get("traceback", j.error))
        raise SystemExit(f"{len(failed)}/{len(jobs)} jobs failed")

    if args.verify:
        worst = 0.0
        for j in jobs:
            ref = PluginRunner(_chain(args, seed=j.metadata["seed"])).run()
            got = j.runner.transport.read(j.runner.datasets["recon"])
            want = np.asarray(ref["recon"].materialise())
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
            worst = max(worst, float(np.max(np.abs(got - want))))
        print(f"verified {len(jobs)} reconstructions against serial "
              f"PluginRunner (max |Δ|={worst:.2e})")

    st = sched.stats()
    print(f"{len(jobs)} jobs in {wall:.2f}s -> {len(jobs) / wall:.2f} "
          f"jobs/s  ({args.workers} workers, transport={args.transport}"
          f"{', gang-batched' if args.batch else ''})")
    print(f"compile cache: {cache.stats()}")
    if st.get("gangs_run"):
        print(f"gangs executed: {st['gangs_run']}")


# ----------------------------------------------------------------------
def _client_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.pipeline_serve client",
        description="Talk to a running pipeline service over HTTP.")
    ap.add_argument("--url", default="http://127.0.0.1:8973",
                    help="service base URL")
    ap.add_argument("--token", default=None,
                    help="bearer token for a token-armed service")
    sub = ap.add_subparsers(dest="action", required=True)

    s = sub.add_parser("submit", help="POST a process list")
    s.add_argument("--spec", metavar="FILE", default=None,
                   help="spec v1 JSON file (see docs/plugin-spec.md)")
    s.add_argument("--demo-chain", action="store_true",
                   help="submit the standard synthetic chain instead of "
                        "a spec file")
    s.add_argument("--streaming", action="store_true",
                   help="submit as a v2 STREAMING job: the loader's "
                        "frames arrive over `client ingest`, not from "
                        "the spec (docs/streaming.md)")
    s.add_argument("--n-det", type=int, default=48)
    s.add_argument("--n-angles", type=int, default=48)
    s.add_argument("--n-rows", type=int, default=2)
    s.add_argument("--seed", type=int, default=0)
    s.add_argument("--priority", type=int, default=0)
    s.add_argument("--job-id", default=None)
    s.add_argument("--wait", action="store_true",
                   help="poll until the job is terminal")

    ing = sub.add_parser(
        "ingest", help="stream frames into a streaming job "
                       "(docs/streaming.md)",
        description="POST frame slabs to a v2 streaming job in arrival "
                    "order, optionally rate-limited, then mark EOF.")
    ing.add_argument("job_id")
    ing.add_argument("--npy", metavar="FILE", default=None,
                     help=".npy frame stack (axis 0 = arrival axis)")
    ing.add_argument("--synthetic", action="store_true",
                     help="generate the standard synthetic scan's raw "
                          "frames (must match the submitted chain's "
                          "--n-det/--n-angles/--n-rows/--seed)")
    ing.add_argument("--n-det", type=int, default=48)
    ing.add_argument("--n-angles", type=int, default=48)
    ing.add_argument("--n-rows", type=int, default=2)
    ing.add_argument("--seed", type=int, default=0)
    ing.add_argument("--chunk", type=int, default=8,
                     help="frames per POST")
    ing.add_argument("--rate", type=float, default=0.0, metavar="HZ",
                     help="chunk posts per second (0 = full speed)")
    ing.add_argument("--start", type=int, default=0,
                     help="index of the first frame being sent (resume "
                          "an interrupted feed from the watermark)")
    ing.add_argument("--eof", action=argparse.BooleanOptionalAction,
                     default=True,
                     help="post EOF after the last chunk (--no-eof to "
                          "keep the stream open)")

    pv = sub.add_parser(
        "preview", help="download the current partial reconstruction")
    pv.add_argument("job_id")
    pv.add_argument("--out", metavar="FILE", default=None,
                    help="write the npy here (default: "
                         "<job_id>-preview.npy)")

    sw = sub.add_parser(
        "sweep", help="POST a parameter sweep (docs/sweeps.md)",
        description="Expand a process list over a ≤2-param grid of "
                    "sweepable values; the service gang-batches the "
                    "variants and serves the stacked result.")
    sw.add_argument("--spec", metavar="FILE", default=None,
                    help="spec v1 JSON file (see docs/plugin-spec.md)")
    sw.add_argument("--demo-chain", action="store_true",
                    help="sweep the standard synthetic chain")
    sw.add_argument("--n-det", type=int, default=48)
    sw.add_argument("--n-angles", type=int, default=48)
    sw.add_argument("--n-rows", type=int, default=2)
    sw.add_argument("--seed", type=int, default=0)
    sw.add_argument("--param", action="append", required=True,
                    metavar="PLUGIN.PARAM=SPEC", dest="params",
                    help="one sweep axis (repeatable, ≤2): SPEC is "
                         "START:STOP:N (inclusive linspace, e.g. "
                         "sinogram_filter.cutoff=0.4:1.0:7) or a "
                         "comma list of JSON values (e.g. "
                         "ring_removal.strength=0.5,1.0,1.5); PLUGIN "
                         "is a wire name or an entry index")
    sw.add_argument("--metric", default=None, choices=sorted(METRICS),
                    help="score each variant and report best_variant")
    sw.add_argument("--priority", type=int, default=0)
    sw.add_argument("--sweep-id", default=None)
    sw.add_argument("--wait", action="store_true",
                    help="poll until every variant is terminal")
    sw.add_argument("--out", metavar="FILE", default=None,
                    help="download the stacked npy here when done "
                         "(implies --wait)")

    wf = sub.add_parser(
        "workflow", help="POST a workflow DAG (docs/workflows.md)",
        description="Submit a DAG of process lists as ONE spec-v3 "
                    "envelope: nodes depend on nodes (`after` + "
                    "upstream-output references), admitted atomically "
                    "— a cycle or dangling reference rejects the whole "
                    "request with nothing enqueued.")
    wf.add_argument("--envelope", metavar="FILE", default=None,
                    help="JSON file: a full v3 envelope or a bare "
                         "{node: {process_list, after}} mapping")
    wf.add_argument("--demo", action="store_true",
                    help="submit the 3-stage demo DAG instead: "
                         "recon -> downsample -> quantify")
    wf.add_argument("--n-det", type=int, default=48)
    wf.add_argument("--n-angles", type=int, default=48)
    wf.add_argument("--n-rows", type=int, default=2)
    wf.add_argument("--seed", type=int, default=0)
    wf.add_argument("--priority", type=int, default=0)
    wf.add_argument("--workflow-id", default=None)
    wf.add_argument("--wait", action="store_true",
                    help="poll until every node is terminal")
    wfs = sub.add_parser("workflow-status",
                         help="GET one workflow's per-node snapshot")
    wfs.add_argument("workflow_id")
    wft = sub.add_parser(
        "workflow-trace",
        help="GET the workflow-level linked trace (per-node spans + "
             "DAG edges)")
    wft.add_argument("workflow_id")
    wfc = sub.add_parser("workflow-cancel",
                         help="DELETE a workflow (cancel live nodes; "
                              "downstream cones cascade)")
    wfc.add_argument("workflow_id")
    sub.add_parser("workflows", help="GET every workflow's summary")

    sws = sub.add_parser("sweep-status", help="GET one sweep's snapshot")
    sws.add_argument("sweep_id")
    swr = sub.add_parser("sweep-result",
                         help="download the stacked result (.npy)")
    swr.add_argument("sweep_id")
    swr.add_argument("--dataset", default=None)
    swr.add_argument("--out", metavar="FILE", default=None,
                     help="write the npy here (default: <sweep_id>.npy)")
    swc = sub.add_parser("sweep-cancel",
                         help="DELETE a sweep (cancel live variants)")
    swc.add_argument("sweep_id")
    sub.add_parser("sweeps", help="GET every sweep group's summary")

    st = sub.add_parser("status", help="GET one job's snapshot")
    st.add_argument("job_id")
    w = sub.add_parser("wait", help="poll a job to completion")
    w.add_argument("job_id")
    w.add_argument("--timeout", type=float, default=600.0)
    r = sub.add_parser("result", help="download an output dataset (.npy)")
    r.add_argument("job_id")
    r.add_argument("--dataset", default=None)
    r.add_argument("--out", metavar="FILE", default=None,
                   help="write the npy here (default: <job_id>.npy)")
    cx = sub.add_parser("cancel", help="DELETE a queued job")
    cx.add_argument("job_id")
    tr = sub.add_parser(
        "trace", help="GET a job's cross-process span timeline",
        description="Print the job's distributed trace "
                    "(docs/observability.md) — by default as an ASCII "
                    "gantt over every span the broker/scheduler and "
                    "workers recorded.")
    tr.add_argument("job_id")
    tr.add_argument("--json", action="store_true",
                    help="print the raw span list instead of the gantt")
    tr.add_argument("--otlp", action="store_true",
                    help="print the OTLP-shaped JSON export instead "
                         "(?format=otlp; docs/observability.md)")
    slo = sub.add_parser(
        "slo", help="GET the SLO rule states (/slo)",
        description="Every SLO rule's definition, current reading and "
                    "alert lifecycle state (docs/observability.md).")
    slo.add_argument("--format", choices=("json", "text"),
                     default="json")
    ev = sub.add_parser(
        "events", help="GET the structured event log (/events)",
        description="Page — or --follow tail — the bounded structured "
                    "event log: one record per job state transition "
                    "and alert edge, each carrying trace_id / job_id "
                    "/ worker_id (docs/observability.md).")
    ev.add_argument("--since", type=int, default=0,
                    help="resume cursor: only records with seq > N")
    ev.add_argument("--limit", type=int, default=None,
                    help="page size bound")
    ev.add_argument("--follow", action="store_true",
                    help="poll forever, printing records as they land "
                         "(one line each)")
    ev.add_argument("--interval", type=float, default=1.0,
                    help="--follow poll period in seconds")
    ev.add_argument("--format", choices=("json", "text"),
                    default="json")
    cl = sub.add_parser(
        "cluster", help="GET the per-worker scoreboard (/cluster)",
        description="Broker mode: every registered worker's heartbeat "
                    "staleness, active leases with time-to-expiry, "
                    "last error and warm-pool prefetch count.")
    cl.add_argument("--format", choices=("json", "text"),
                    default="json")
    sub.add_parser("jobs", help="GET every job's snapshot")
    sub.add_parser("stats", help="GET scheduler + compile-cache stats")
    sub.add_parser("metrics",
                   help="GET the Prometheus text exposition (/metrics)")
    sub.add_parser("plugins", help="GET the wire-format plugin registry")
    return ap


def _parse_sweep_axis(s: str) -> dict:
    """``PLUGIN.PARAM=START:STOP:N`` (inclusive linspace) or
    ``PLUGIN.PARAM=v1,v2,...`` (JSON values) -> one sweep-axis object."""
    target, eq, spec = s.partition("=")
    plugin, dot, param = target.rpartition(".")
    if not (eq and dot and plugin and param and spec):
        raise SystemExit(f"--param wants PLUGIN.PARAM=SPEC, got {s!r}")
    if ":" in spec and "," not in spec:
        parts = spec.split(":")
        try:
            start, stop, n = (float(parts[0]), float(parts[1]),
                              int(parts[2]))
        except (IndexError, ValueError):
            # a typo like 0.4:1.0 must die here, not as N failed jobs
            raise SystemExit(f"--param range must be START:STOP:N, "
                             f"got {spec!r}") from None
        if len(parts) != 3:
            raise SystemExit(f"--param range must be START:STOP:N, "
                             f"got {spec!r}")
        values = [float(v) for v in np.linspace(start, stop, n)]
    else:
        values = []
        for v in spec.split(","):
            try:
                values.append(json.loads(v))
            except json.JSONDecodeError:
                values.append(v)           # bare string value
    axis: dict = {"param": param, "values": values}
    if plugin.isdigit():
        axis["plugin_index"] = int(plugin)
    else:
        axis["plugin"] = plugin
    return axis


def _demo_workflow(args) -> dict:
    """The 3-stage demo DAG — recon -> downsample -> quantify, the
    downstream nodes fed by upstream outputs (docs/workflows.md)."""
    from ..core.process_list import ProcessList
    from ..tomo import Downsample, HDF5LikeSaver, Quantify, UpstreamLoader
    down = ProcessList()
    down.add(UpstreamLoader,
             params={"data": {"from_job": "recon", "dataset": "recon"}},
             out_datasets=("vol",))
    down.add(Downsample, params={"factor": 2},
             in_datasets=("vol",), out_datasets=("small",))
    down.add(HDF5LikeSaver, in_datasets=("small",))
    quant = ProcessList()
    quant.add(UpstreamLoader,
              params={"data": {"from_job": "downsample",
                               "dataset": "small"}},
              out_datasets=("vol",))
    quant.add(Quantify, in_datasets=("vol",), out_datasets=("stats",))
    quant.add(HDF5LikeSaver, in_datasets=("stats",))
    return {
        "recon": {"process_list": to_spec(standard_chain(
            n_det=args.n_det, n_angles=args.n_angles,
            n_rows=args.n_rows, seed=args.seed))},
        "downsample": {"process_list": to_spec(down)},
        # the upstream reference already implies this edge; the
        # explicit `after` just demonstrates the envelope field
        "quantify": {"process_list": to_spec(quant),
                     "after": ["downsample"]},
    }


def _workflow_main(client: PipelineClient, args) -> None:
    if args.envelope:
        with open(args.envelope) as fh:
            doc = json.load(fh)
        # accept a full v3 envelope or a bare node mapping
        nodes = doc.get("workflow", doc) if isinstance(doc, dict) else doc
    elif args.demo:
        nodes = _demo_workflow(args)
    else:
        raise SystemExit("workflow needs --envelope FILE or --demo")
    reply = client.workflow(nodes, workflow_id=args.workflow_id,
                            priority=args.priority)
    print(json.dumps(reply, indent=2))
    if args.wait:
        snap = client.wait_workflow(reply["workflow_id"])
        print(json.dumps(snap, indent=2))


def _ingest_main(client: PipelineClient, args) -> None:
    """Feed a frame stack into a streaming job chunk by chunk."""
    if args.npy:
        frames = np.load(args.npy)
    elif args.synthetic:
        # materialise exactly what the submitted chain's loader
        # declares, so the streamed run is bit-identical to batch
        pl = standard_chain(n_det=args.n_det, n_angles=args.n_angles,
                            n_rows=args.n_rows, seed=args.seed)
        entry = pl.entries[0]
        loader = entry.cls(**entry.params,
                           in_datasets=list(entry.in_datasets),
                           out_datasets=list(entry.out_datasets))
        frames = np.asarray(loader.load()[0].materialise())
    else:
        raise SystemExit("ingest needs --npy FILE or --synthetic")
    start = args.start
    for lo in range(0, frames.shape[0], args.chunk):
        chunk = frames[lo:lo + args.chunk]
        reply = client.ingest(args.job_id, chunk, start)
        start = reply["watermark"]
        print(f"  fed frames [{reply['start']}, "
              f"{reply['start'] + reply['count']}) -> watermark "
              f"{start}", flush=True)
        if args.rate > 0:
            time.sleep(1.0 / args.rate)
    if args.eof:
        print(json.dumps(client.eof(args.job_id), indent=2))


def _table(rows: list[tuple]) -> str:
    """Plain-text column alignment for the --format text views."""
    widths = [max(len(str(r[i])) for r in rows)
              for i in range(len(rows[0]))]
    return "\n".join(
        "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
        for r in rows)


def _slo_text(snap: dict) -> str:
    rows = [("RULE", "STATE", "VALUE", "THRESHOLD", "FIRED",
             "RESOLVED", "METRIC")]
    for r in snap["rules"]:
        value = "-" if r["value"] is None else f"{r['value']:.3f}"
        rows.append((("*" if r["critical"] else " ") + r["name"],
                     r["state"], value,
                     f"{r['op']} {r['threshold']:g}",
                     r["fired"], r["resolved"], r["metric"]))
    firing = ", ".join(snap["firing"]) or "none"
    return (_table(rows)
            + f"\nfiring: {firing}   (* = critical rule)")


def _event_line(rec: dict) -> str:
    attrs = " ".join(f"{k}={v}"
                     for k, v in sorted(rec["attrs"].items()))
    return (f"{rec['seq']:>6d}  {rec['ts']:.3f}  {rec['event']:<14s} "
            f"trace={rec['trace_id'] or '-'} "
            f"job={rec['job_id'] or '-'} "
            f"worker={rec['worker_id'] or '-'}"
            + (f"  {attrs}" if attrs else ""))


def _cluster_text(doc: dict) -> str:
    rows = [("WORKER", "LEASES", "STALE_S", "DONE", "FAILED",
             "PREFETCHED", "LAST_ERROR")]
    for w in doc["workers"]:
        leases = ",".join(ls["job_id"] for ls in w["leases"]) or "-"
        err = w.get("last_error") or "-"
        if len(err) > 40:
            err = err[:37] + "..."
        rows.append((w["worker_id"], leases,
                     f"{w['heartbeat_staleness_s']:.1f}",
                     w["jobs_done"], w["jobs_failed"],
                     w["prefetched"], err))
    return (_table(rows)
            + f"\nactive_leases={doc['active_leases']}  "
              f"leases_expired={doc['leases_expired']}  "
              f"jobs_requeued={doc['jobs_requeued']}  "
              f"lease_ttl={doc['lease_ttl']}")


def _events_main(client: PipelineClient, args) -> None:
    """One page of the event log, or --follow: tail it forever."""
    if not args.follow:
        page = client.events(since=args.since, limit=args.limit)
        if args.format == "text":
            for rec in page["events"]:
                print(_event_line(rec))
            tail = f"# cursor {page['cursor']}"
            if page["dropped"]:
                tail += f"  ({page['dropped']} dropped before cursor)"
            print(tail)
        else:
            print(json.dumps(page, indent=2))
        return
    cursor = args.since
    try:
        while True:
            page = client.events(since=cursor, limit=args.limit)
            for rec in page["events"]:
                print(_event_line(rec) if args.format == "text"
                      else json.dumps(rec), flush=True)
            cursor = page["cursor"]
            if not page["events"]:
                time.sleep(max(args.interval, 0.05))
    except KeyboardInterrupt:
        pass


def _client_main(argv: list[str]) -> None:
    args = _client_parser().parse_args(argv)
    client = PipelineClient(args.url, token=args.token)
    try:
        if args.action == "sweep":
            if args.spec:
                with open(args.spec) as fh:
                    spec = json.load(fh)
            elif args.demo_chain:
                spec = to_spec(standard_chain(
                    n_det=args.n_det, n_angles=args.n_angles,
                    n_rows=args.n_rows, seed=args.seed))
            else:
                raise SystemExit("sweep needs --spec FILE or --demo-chain")
            reply = client.sweep(
                spec, [_parse_sweep_axis(p) for p in args.params],
                metric=args.metric, priority=args.priority,
                sweep_id=args.sweep_id)
            print(json.dumps(reply, indent=2))
            if args.wait or args.out:
                snap = client.wait_sweep(reply["sweep_id"])
                print(json.dumps(snap, indent=2))
                if args.out and snap["state"] == "done":
                    arr = client.sweep_result(reply["sweep_id"])
                    np.save(args.out, arr)
                    print(f"{args.out}: shape={arr.shape} "
                          f"dtype={arr.dtype}")
        elif args.action == "sweep-status":
            print(json.dumps(client.sweep_status(args.sweep_id),
                             indent=2))
        elif args.action == "sweep-result":
            arr = client.sweep_result(args.sweep_id,
                                      dataset=args.dataset)
            out = args.out or f"{args.sweep_id}.npy"
            np.save(out, arr)
            print(f"{out}: shape={arr.shape} dtype={arr.dtype}")
        elif args.action == "sweep-cancel":
            print(json.dumps(client.cancel_sweep(args.sweep_id),
                             indent=2))
        elif args.action == "sweeps":
            print(json.dumps(client.sweeps(), indent=2))
        elif args.action == "workflow":
            _workflow_main(client, args)
        elif args.action == "workflow-status":
            print(json.dumps(client.workflow_status(args.workflow_id),
                             indent=2))
        elif args.action == "workflow-trace":
            print(json.dumps(client.workflow_trace(args.workflow_id),
                             indent=2))
        elif args.action == "workflow-cancel":
            print(json.dumps(client.cancel_workflow(args.workflow_id),
                             indent=2))
        elif args.action == "workflows":
            print(json.dumps(client.workflows(), indent=2))
        elif args.action == "submit":
            if args.spec:
                with open(args.spec) as fh:
                    spec = json.load(fh)
            elif args.demo_chain:
                spec = to_spec(standard_chain(
                    n_det=args.n_det, n_angles=args.n_angles,
                    n_rows=args.n_rows, seed=args.seed))
            else:
                raise SystemExit("submit needs --spec FILE or --demo-chain")
            if args.streaming:
                spec = {**spec, "version": 2, "streaming": True}
            job_id = client.submit(spec, priority=args.priority,
                                   job_id=args.job_id)
            print(job_id)
            if args.wait:
                print(json.dumps(client.wait(job_id), indent=2))
        elif args.action == "ingest":
            _ingest_main(client, args)
        elif args.action == "preview":
            arr, cut = client.preview(args.job_id)
            out = args.out or f"{args.job_id}-preview.npy"
            np.save(out, arr)
            print(f"{out}: shape={arr.shape} dtype={arr.dtype} "
                  f"(first {cut} frames folded in)")
        elif args.action == "status":
            print(json.dumps(client.status(args.job_id), indent=2))
        elif args.action == "wait":
            print(json.dumps(client.wait(args.job_id,
                                         timeout=args.timeout), indent=2))
        elif args.action == "result":
            arr = client.result(args.job_id, dataset=args.dataset)
            out = args.out or f"{args.job_id}.npy"
            np.save(out, arr)
            print(f"{out}: shape={arr.shape} dtype={arr.dtype}")
        elif args.action == "cancel":
            print(json.dumps(client.cancel(args.job_id), indent=2))
        elif args.action == "trace":
            if args.otlp:
                print(json.dumps(client.trace(args.job_id, otlp=True),
                                 indent=2))
            elif args.json:
                print(json.dumps(client.trace(args.job_id), indent=2))
            else:
                print(client.trace(args.job_id, text=True), end="")
        elif args.action == "slo":
            snap = client.slo()
            print(_slo_text(snap) if args.format == "text"
                  else json.dumps(snap, indent=2))
        elif args.action == "events":
            _events_main(client, args)
        elif args.action == "cluster":
            doc = client.cluster()
            print(_cluster_text(doc) if args.format == "text"
                  else json.dumps(doc, indent=2))
        elif args.action == "jobs":
            print(json.dumps(client.jobs(), indent=2))
        elif args.action == "stats":
            print(json.dumps(client.stats(), indent=2))
        elif args.action == "metrics":
            print(client.metrics(), end="")
        elif args.action == "plugins":
            print(json.dumps(client.plugins(), indent=2))
    except ServiceError as e:
        raise SystemExit(f"error: {e}")


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if argv[:1] == ["client"]:
        return _client_main(argv[1:])
    args = _build_parser().parse_args(argv)
    if args.serve is not None:
        return _serve_main(args)
    if args.workers_remote is not None:
        return _remote_demo(args)
    return _demo_main(args)


if __name__ == "__main__":
    main()
