"""Serving: prefill + decode step factories, a greedy generate loop and
a minimal continuous-batching scheduler (slot-based, host-driven).

``serve_step`` — the function the decode_* dry-run cells lower — is one
batched single-token decode against a full KV/state cache.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from ..models.model_zoo import Model


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, token (B,1) int32, cache) -> (token', cache)."""

    def serve_step(params, token, cache):
        logits, cache = model.decode_step(params, token, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step


def greedy_generate(model: Model, params, batch: dict, *, max_new: int,
                    max_len: int) -> np.ndarray:
    """Prefill the prompt then decode ``max_new`` tokens greedily."""
    logits, cache = model.prefill(params, batch, max_len)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(make_serve_step(model))
    out = [np.asarray(tok)]
    for _ in range(max_new - 1):
        tok, cache = step(params, tok, cache)
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


# ----------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching: a fixed decode batch of ``slots``;
    finished requests release their slot, queued requests are prefis
    prefilled into it.  Host-side control, device-side caches —
    the standard serving shape (vLLM-lite) on top of serve_step."""

    def __init__(self, model: Model, params, *, slots: int, max_len: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.cache = model.init_cache(slots, max_len)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._step = jax.jit(make_serve_step(model))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self.active[slot] = req
                # prefill one request, splice its cache into the batch
                b = {"tokens": req.prompt[None, :]}
                logits, c1 = self.model.prefill(self.params, b,
                                                self.max_len)
                first = int(np.argmax(np.asarray(logits)[0, -1]))
                req.generated.append(first)
                self.tokens = self.tokens.at[slot, 0].set(first)
                self.cache = _splice_cache(self.cache, c1, slot)

    def run(self) -> list[Request]:
        finished = []
        while self.queue or any(self.active):
            self._admit()
            self.tokens, self.cache = self._step(self.params, self.tokens,
                                                 self.cache)
            toks = np.asarray(self.tokens)
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                req.generated.append(int(toks[slot, 0]))
                if len(req.generated) >= req.max_new:
                    req.done = True
                    finished.append(req)
                    self.active[slot] = None
        return finished


def _splice_cache(batch_cache, single_cache, slot: int):
    """Write a single-request cache into slot ``slot`` of the batched
    cache.  Batch dims are found structurally: any leaf dim equal to the
    single cache's batch-1 axis is updated via dynamic_update_slice."""

    def splice(b, s):
        if not hasattr(b, "shape") or b.ndim == 0:
            return s if b.ndim == 0 else b
        # locate the batch axis: first axis where b vs s differ
        axes = [i for i in range(b.ndim)
                if i < s.ndim and b.shape[i] != s.shape[i]]
        if not axes:
            return b
        ax = axes[0]
        start = [0] * b.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(b, s.astype(b.dtype),
                                            tuple(start))

    return jax.tree.map(splice, batch_cache, single_cache)
