"""Production serving driver: continuous-batched greedy decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
        --smoke --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from ..configs import ARCH_IDS, get_config
from ..models import build_model, make_rules, use_rules
from ..training import ContinuousBatcher, Request
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    model = build_model(cfg)
    rng = np.random.default_rng(0)

    with use_rules(make_rules(mesh)), mesh:
        params = model.init(jax.random.key(0))
        batcher = ContinuousBatcher(model, params, slots=args.slots,
                                    max_len=args.max_len)
        for i in range(args.requests):
            batcher.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    (args.prompt_len,)).astype(np.int32),
                max_new=args.max_new))
        t0 = time.time()
        done = batcher.run()
        wall = time.time() - t0
        total = sum(len(r.generated) for r in done)
        print(f"served {len(done)} requests, {total} tokens in "
              f"{wall:.2f}s ({total / wall:.1f} tok/s, "
              f"{args.slots} slots)")
        for r in done[:3]:
            print(f"  req {r.rid}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
