"""Pipeline jobs — one submitted process list, tracked from admission to
completion.

A job's lifecycle mirrors the paper's run states plus the service-layer
extras: ``queued → checking → running(plugin i/N) → done | failed |
cancelled``.  The *chain signature* (structural identity of the process
list) is what the scheduler batches on and what the compile cache and
checkpoint store validate against.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
import json
import threading
import time
from typing import Any

import numpy as np

from ..core.framework import PluginRunner
from ..core.plugin import _is_jsonable
from ..core.process_list import ProcessList
from ..obs.trace import Trace, new_trace_id


class JobState(str, enum.Enum):
    QUEUED = "queued"
    CHECKING = "checking"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"

    def terminal(self) -> bool:
        return self in (JobState.DONE, JobState.FAILED, JobState.CANCELLED)


def chain_signature(process_list: ProcessList) -> tuple:
    """Structural identity of a process list: per-entry (class, jsonable
    params, dataset wiring).  Equal signatures ⇒ identical plugin chains
    that may share compiled programs and be gang-executed; non-jsonable
    params (inline arrays, geometry objects) are data, not structure, and
    deliberately excluded.  ``data_params`` (which dataset) and
    ``tunable_params`` (sweepable calibration values whose effect rides
    in ``jit_constants``) are excluded too — a parameter sweep's
    variants are "the same pipeline" and must gang."""
    sig = []
    for e in process_list.entries:
        skip = set(getattr(e.cls, "data_params", ())) \
            | set(getattr(e.cls, "tunable_params", ()))
        jsonable, opaque = {}, []
        for k, v in sorted(e.params.items()):
            if k in skip:
                continue
            if _is_jsonable(v):
                jsonable[k] = v
            else:
                # opaque params (callables, objects) can't be
                # fingerprinted; keep at least the qualname so swapping
                # e.g. LambdaFilter(fn=double) for fn=triple reads as a
                # different pipeline (checkpoint restore must not mix)
                opaque.append((k, getattr(v, "__qualname__",
                                          type(v).__qualname__)))
        sig.append((
            f"{e.cls.__module__}.{e.cls.__qualname__}",
            json.dumps(jsonable, sort_keys=True), tuple(opaque),
            tuple(e.in_datasets), tuple(e.out_datasets)))
    return tuple(sig)


class StreamState:
    """Server-side frame buffer for one streaming job (docs/streaming.md).

    The HTTP front end appends contiguous frame chunks under ``lock``
    and notifies ``cond``; consumers (the scheduler's driver thread, or
    a broker-mode worker polling ``GET /jobs/{id}/frames``) read any
    suffix with :meth:`fetch`.  Chunks are retained until the job is
    terminal so a lease expiry + checkpoint-resume on another worker can
    re-fetch from its restored watermark.  ``exec_lock`` serialises the
    in-process runner's pump loop against on-demand previews."""

    def __init__(self):
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        #: serialises runner execution vs. preview (scheduler mode)
        self.exec_lock = threading.Lock()
        self.watermark = 0            # frames accepted so far
        self.eof = False
        self._starts: list[int] = []  # chunk start frames (sorted)
        self._chunks: list[np.ndarray] = []
        self._arrived: list[float] = []   # per-chunk ingest time (epoch)

    def append(self, frames: np.ndarray, start: int) -> int:
        """Accept a contiguous chunk; the caller validates ordering and
        holds ``lock``.  Returns the new watermark."""
        self._starts.append(start)
        self._chunks.append(frames)
        self._arrived.append(time.time())
        self.watermark = start + frames.shape[0]
        return self.watermark

    def fetch(self, start: int, max_frames: int | None = None
              ) -> tuple[np.ndarray | None, int]:
        """Frames from ``start`` (up to ``max_frames``), or (None,
        start) when nothing new has arrived.  Caller holds ``lock``."""
        if start >= self.watermark:
            return None, start
        i = bisect.bisect_right(self._starts, start) - 1
        pieces: list[np.ndarray] = []
        got = 0
        want = (self.watermark - start if max_frames is None
                else min(max_frames, self.watermark - start))
        while i < len(self._chunks) and got < want:
            c, s = self._chunks[i], self._starts[i]
            lo = max(0, start + got - s)
            hi = min(c.shape[0], lo + (want - got))
            pieces.append(c[lo:hi])
            got += hi - lo
            i += 1
        return np.concatenate(pieces, axis=0), start

    def arrival_time(self, frame: int) -> float | None:
        """Ingest timestamp of the chunk containing ``frame`` — the
        broker derives ingest lag (arrival -> consumption) from it."""
        if not self._starts or frame >= self.watermark:
            return None
        i = bisect.bisect_right(self._starts, frame) - 1
        return self._arrived[i] if i >= 0 else None

    def drop_buffers(self) -> None:
        """Release retained chunks (job terminal)."""
        with self.lock:
            self._starts, self._chunks, self._arrived = [], [], []


@dataclasses.dataclass
class Job:
    """One submitted process list, tracked from admission to completion.

    Created by :meth:`JobQueue.submit`; mutated by the scheduler as the
    job advances (``state``, ``plugin_index``, timestamps, ``runner``).
    ``snapshot()`` is the read API — everything a remote monitor needs,
    JSON-able.  The live ``runner`` (datasets, transport, profiler) is
    kept after completion so results stay retrievable until the queue's
    ``max_history`` evicts the job.
    """

    job_id: str
    process_list: ProcessList
    priority: int = 0
    seq: int = 0                         # submission order (FIFO tiebreak)
    submitted_at: float = dataclasses.field(default_factory=time.time)
    metadata: dict[str, Any] = dataclasses.field(default_factory=dict)

    state: JobState = JobState.QUEUED
    error: str | None = None
    plugin_index: int = 0                # completed plugin steps
    n_plugins: int = 0
    resumed_from: int = 0                # >0: restored from a checkpoint
    started_at: float | None = None
    finished_at: float | None = None
    #: the live runner (datasets/transport/profiler) once checking starts
    runner: PluginRunner | None = None
    chain_sig: tuple = ()
    # -- broker-mode (worker-pull) fields -------------------------------
    #: worker currently (or last) holding this job's lease
    worker_id: str | None = None
    #: times the job has been leased; >1 means a lease expired and the
    #: job was requeued onto another worker
    attempt: int = 0
    #: a cancel arrived while a worker held the lease; the worker's next
    #: heartbeat is answered with verdict "cancelled"
    cancel_requested: bool = False
    #: dataset name -> server-readable .npy path, filled by remote
    #: workers (upload spool or shared-fs hand-off)
    remote_results: dict[str, str] = dataclasses.field(default_factory=dict)
    # -- telemetry (docs/observability.md) ------------------------------
    #: trace identity, assigned at submission (callers may supply one to
    #: correlate with an external tracing system)
    trace_id: str = ""
    #: the merged cross-process span timeline (``GET /jobs/{id}/trace``)
    trace: Trace | None = None
    #: last requeue time (lease expiry) — queue.wait spans for attempt
    #: >1 measure from here, not from submission
    requeued_at: float | None = None
    # -- streaming (docs/streaming.md) ----------------------------------
    #: spec had ``"streaming": true``: the loader dataset is fed over
    #: POST /jobs/{id}/frames instead of being complete at step 0
    streaming: bool = False
    #: server-side frame buffer (set at submission for streaming jobs)
    stream: StreamState | None = None
    #: highest frame index the executor reported consuming (broker: via
    #: the heartbeat's ``ingest_watermark``; scheduler: set directly)
    frames_consumed: int = 0
    #: frames covered by the newest uploaded preview (broker mode)
    preview_watermark: int = 0
    # -- workflow DAGs (docs/workflows.md) ------------------------------
    #: upstream job ids this job depends on (fan-in); the job is only
    #: poppable once every upstream is terminal-ok (DONE)
    after: tuple[str, ...] = ()
    #: upstream ids not yet DONE — maintained by the queue under its
    #: lock; empty ⇒ dependencies satisfied
    waiting: set[str] = dataclasses.field(default_factory=set)
    #: subset of ``after`` whose RESULTS this job consumes (output
    #: addressing); evicting such an upstream before this job runs
    #: cancels it with ``upstream_evicted``
    data_deps: tuple[str, ...] = ()
    #: machine-readable reason for a CANCELLED state
    #: ("user" | "upstream_failed" | "upstream_cancelled" |
    #: "upstream_evicted"); None while not cancelled
    cancel_reason: str | None = None

    def __post_init__(self):
        if not self.chain_sig:
            self.chain_sig = chain_signature(self.process_list)
        if not self.trace_id:
            self.trace_id = new_trace_id()
        if self.trace is None:
            self.trace = Trace(self.trace_id)
        if not self.streaming and getattr(self.process_list, "streaming",
                                          False):
            self.streaming = True
        if self.streaming and self.stream is None:
            self.stream = StreamState()
        self.after = tuple(self.after)
        self.data_deps = tuple(self.data_deps)
        if self.after and not self.waiting:
            self.waiting = set(self.after)

    def deps_ready(self) -> bool:
        """Queue-eligibility gate: every upstream job is terminal-ok.
        The queue clears ids from ``waiting`` as upstreams reach DONE
        (and cascade-cancels this job when one fails), so an empty set
        means "all dependencies satisfied"."""
        return not self.waiting

    def stream_ready(self) -> bool:
        """Queue-eligibility gate: a streaming job may only be
        dispatched/leased while it has work — unconsumed frames or the
        EOF marker.  While starved of frames it parks in the queue
        without burning a lease (docs/streaming.md)."""
        if not self.streaming:
            return True
        st = self.stream
        return st.eof or st.watermark > self.frames_consumed

    # ------------------------------------------------------------------
    @property
    def status(self) -> str:
        if self.state is JobState.RUNNING:
            return f"running(plugin {self.plugin_index}/{self.n_plugins})"
        if self.state is JobState.FAILED:
            return f"failed: {self.error}"
        return self.state.value

    @property
    def wall(self) -> float | None:
        if self.started_at is None:
            return None
        return (self.finished_at or time.time()) - self.started_at

    def snapshot(self) -> dict[str, Any]:
        """JSON-able point-in-time view of the job — what the service
        layer reports (``GET /jobs/{id}``): identity, state +
        human-readable ``status`` (``running(plugin i/N)``), priority,
        ``resumed_from`` (>0 when restored from a checkpoint),
        submission/start/finish timestamps, elapsed ``wall``, the
        failure ``error`` if any, the broker-mode ``worker_id`` /
        ``attempt`` (attempt >1 = requeued after a lease expiry), and
        the JSON-able subset of ``metadata``."""
        snap = {"job_id": self.job_id, "state": self.state.value,
                "status": self.status, "priority": self.priority,
                "plugin_index": self.plugin_index,
                "n_plugins": self.n_plugins,
                "resumed_from": self.resumed_from,
                "submitted_at": self.submitted_at,
                "started_at": self.started_at,
                "finished_at": self.finished_at, "wall": self.wall,
                "error": self.error,
                "trace_id": self.trace_id,
                "worker_id": self.worker_id, "attempt": self.attempt,
                "metadata": {k: v for k, v in self.metadata.items()
                             if _is_jsonable(v)}}
        if self.after:
            snap["after"] = list(self.after)
            snap["waiting_on"] = sorted(self.waiting)
        if self.cancel_reason is not None:
            snap["cancel_reason"] = self.cancel_reason
        if self.streaming:
            snap["streaming"] = True
            snap["ingest_watermark"] = self.stream.watermark
            snap["frames_consumed"] = self.frames_consumed
            snap["eof"] = self.stream.eof
            snap["preview_watermark"] = self.preview_watermark
        return snap
