"""Distributed job tracing — the cross-process half of the paper's
per-process profiler (§IV.B, Fig 9).

Savu's MPI profiler shows what each *process* spent per plugin; a
multi-host service additionally needs to follow ONE JOB across
processes: queued at the broker, leased, executed (possibly twice,
after a lease expiry) on different workers, results handed back.  This
module is the substrate:

* a :class:`Span` is one timed operation (``queue.wait``, ``lease``,
  ``compile``, ``plugin.<name>.<phase>``, ``checkpoint.save``,
  ``result.upload``...) with a ``trace_id`` (the job), a ``span_id``
  (itself), an optional ``parent_id`` and the ``worker_id`` of the
  process that recorded it.  Timestamps are **epoch seconds**
  (``time.time()``), not a monotonic clock — spans from different
  processes must land on one comparable timeline.
* a :class:`Trace` is a thread-safe span collection for one job.  Its
  ``span()`` context manager keeps a per-thread stack so nested spans
  get ``parent_id`` links automatically; ``merge()`` folds wire spans
  in with span-id dedup, so a heartbeat that is retried (or delivered
  twice) is idempotent.
* :func:`render_gantt` draws the Fig-9-style ASCII timeline served by
  ``GET /jobs/{id}/trace?format=text``.

Workers ship finished spans to the broker piggybacked on progress
heartbeats (``take_unshipped`` / ``merge``); the "current trace" is a
:mod:`contextvars` slot so deep layers (the compile cache) can record
spans without threading a handle through every call.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import hashlib
import json
import os
import threading
import time
import uuid
from typing import Any, Iterable


def new_trace_id() -> str:
    """A fresh trace id (one per job/sweep-variant submission)."""
    return uuid.uuid4().hex[:16]


def new_span_id() -> str:
    return uuid.uuid4().hex[:16]


@dataclasses.dataclass
class Span:
    """One timed operation inside a trace.

    ``start``/``end`` are epoch seconds (``time.time()``); ``end`` is
    None while the span is open.  ``attrs`` carries JSON-able
    annotations (plugin name, phase, attempt number, outcome, flops...).
    """

    name: str
    start: float
    end: float | None = None
    trace_id: str = ""
    span_id: str = dataclasses.field(default_factory=new_span_id)
    parent_id: str | None = None
    worker_id: str | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def wall(self) -> float:
        return (self.end if self.end is not None else time.time()) \
            - self.start

    def to_wire(self) -> dict[str, Any]:
        """JSON-able wire form (heartbeat ``spans`` field, trace
        endpoint payload)."""
        out: dict[str, Any] = {"name": self.name, "start": self.start,
                               "end": self.end, "span_id": self.span_id}
        if self.trace_id:
            out["trace_id"] = self.trace_id
        if self.parent_id:
            out["parent_id"] = self.parent_id
        if self.worker_id:
            out["worker_id"] = self.worker_id
        if self.attrs:
            out["attrs"] = self.attrs
        return out

    @staticmethod
    def from_wire(d: dict[str, Any]) -> "Span":
        """Inverse of :meth:`to_wire`; tolerant of missing optionals
        (raises ``KeyError``/``TypeError`` only on a span without a
        name or start)."""
        return Span(name=str(d["name"]), start=float(d["start"]),
                    end=(None if d.get("end") is None
                         else float(d["end"])),
                    trace_id=str(d.get("trace_id", "")),
                    span_id=str(d.get("span_id") or new_span_id()),
                    parent_id=d.get("parent_id") or None,
                    worker_id=d.get("worker_id") or None,
                    attrs=dict(d.get("attrs") or {}))


class Trace:
    """Thread-safe span collection for one job.

    The per-thread parent stack means ``span()`` context managers nest
    naturally: a ``plugin.x.process`` span opened inside an ``attempt``
    span records ``parent_id = attempt.span_id`` without the caller
    threading anything through.  Stacks are keyed per (trace, thread),
    so interleaving several jobs' traces on one thread (gang execution)
    keeps each job's links straight.
    """

    def __init__(self, trace_id: str | None = None,
                 worker_id: str | None = None):
        self.trace_id = trace_id or new_trace_id()
        self.worker_id = worker_id
        self._spans: dict[str, Span] = {}      # span_id -> Span, insertion-ordered
        self._shipped: set[str] = set()
        self._lock = threading.Lock()
        self._stacks = threading.local()

    # -- recording ------------------------------------------------------
    def _stack(self) -> list[Span]:
        st = getattr(self._stacks, "stack", None)
        if st is None:
            st = self._stacks.stack = []
        return st

    def add(self, span: Span) -> Span:
        """Register ``span`` (idempotent per ``span_id``); stamps the
        trace id."""
        span.trace_id = self.trace_id
        with self._lock:
            self._spans.setdefault(span.span_id, span)
        return span

    def record(self, name: str, start: float, end: float, *,
               worker_id: str | None = None,
               parent_id: str | None = None,
               attrs: dict[str, Any] | None = None) -> Span:
        """Add one already-finished span (broker-side bookkeeping:
        ``queue.wait`` and ``lease`` are only known in hindsight).
        ``parent_id`` defaults to the thread's innermost open span, so
        e.g. a ``compile`` recorded while ``plugin.x.process`` is open
        links under it."""
        if parent_id is None:
            stack = self._stack()
            parent_id = stack[-1].span_id if stack else None
        return self.add(Span(name, start, end,
                             worker_id=worker_id or self.worker_id,
                             parent_id=parent_id,
                             attrs=dict(attrs or {})))

    def begin(self, name: str, *, worker_id: str | None = None,
              attrs: dict[str, Any] | None = None) -> Span:
        """Open a span (parent = the thread's current innermost span)
        and push it on the parent stack.  Close with :meth:`finish`."""
        stack = self._stack()
        span = Span(name, time.time(),
                    parent_id=stack[-1].span_id if stack else None,
                    worker_id=worker_id or self.worker_id,
                    attrs=dict(attrs or {}))
        self.add(span)
        stack.append(span)
        return span

    def finish(self, span: Span) -> Span:
        """Close a span opened with :meth:`begin` and pop the stack."""
        span.end = time.time()
        stack = self._stack()
        if span in stack:
            del stack[stack.index(span):]
        return span

    @contextlib.contextmanager
    def span(self, name: str, *, worker_id: str | None = None,
             **attrs: Any):
        """Context manager: open → yield → close, with automatic
        parent links.  An exception closes the span with
        ``attrs["error"]`` set before propagating."""
        s = self.begin(name, worker_id=worker_id, attrs=attrs)
        try:
            yield s
        except BaseException as e:
            s.attrs["error"] = type(e).__name__
            raise
        finally:
            self.finish(s)

    # -- reading / shipping ---------------------------------------------
    def spans(self) -> list[Span]:
        """Every span, ordered by start time (ties: insertion order)."""
        with self._lock:
            vals = list(self._spans.values())
        return sorted(vals, key=lambda s: s.start)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def merge(self, wire_spans: Iterable[dict[str, Any]]) -> list[Span]:
        """Fold wire spans in, deduplicating on ``span_id`` — a
        re-delivered heartbeat adds nothing.  Returns only the NEWLY
        added spans (what a metrics observer should count once).
        Malformed entries are skipped, not fatal: telemetry must never
        take down the control channel."""
        new: list[Span] = []
        for d in wire_spans or ():
            try:
                span = Span.from_wire(d)
            except (KeyError, TypeError, ValueError):
                continue
            span.trace_id = self.trace_id
            with self._lock:
                if span.span_id in self._spans:
                    continue
                self._spans[span.span_id] = span
            new.append(span)
        return new

    def take_unshipped(self) -> list[Span]:
        """Finished spans not yet handed to the wire, marking them
        shipped.  The receiver dedups on span_id, so a send that fails
        mid-flight may simply be retried — :meth:`unship` restores the
        batch for the next heartbeat."""
        with self._lock:
            out = [s for s in self._spans.values()
                   if s.end is not None and s.span_id not in self._shipped]
            self._shipped.update(s.span_id for s in out)
        return out

    def unship(self, spans: Iterable[Span]) -> None:
        """Undo :meth:`take_unshipped` for a failed send."""
        with self._lock:
            self._shipped.difference_update(s.span_id for s in spans)

    def to_wire(self) -> dict[str, Any]:
        """``{"trace_id": ..., "spans": [...]}`` — the
        ``GET /jobs/{id}/trace`` payload."""
        return {"trace_id": self.trace_id,
                "spans": [s.to_wire() for s in self.spans()]}


# -- current trace (contextvar) ----------------------------------------
_current: contextvars.ContextVar[Trace | None] = \
    contextvars.ContextVar("repro_obs_current_trace", default=None)


def current_trace() -> Trace | None:
    """The trace of the job executing on this thread/context, if any —
    how layers with no job handle (the compile cache) attach spans."""
    return _current.get()


@contextlib.contextmanager
def use_trace(trace: Trace | None):
    """Bind ``trace`` as the current trace for the duration."""
    token = _current.set(trace)
    try:
        yield trace
    finally:
        _current.reset(token)


# -- retention ----------------------------------------------------------
class TraceSpool:
    """Bounded on-disk ring of terminal-job traces.

    ``max_history`` pruning evicts a terminal :class:`~..service.job.Job`
    — and with it the in-RAM trace.  The service registers a queue evict
    hook that spools the trace here first, so ``GET /jobs/{id}/trace``
    keeps answering for jobs whose results are long gone.  One JSON file
    per job (filename = sha1 of the job id, so arbitrary ids stay
    filesystem-safe), written atomically (tmp + rename); past
    ``max_traces`` the oldest files (mtime) are deleted — a ring, not a
    leak.
    """

    def __init__(self, root: str, max_traces: int = 256):
        """Args:
            root: spool directory (created if missing).
            max_traces: retained trace files; oldest-by-mtime beyond
                this are evicted at each :meth:`put`.
        """
        if max_traces < 1:
            raise ValueError(f"max_traces must be >= 1, got {max_traces}")
        self.root = root
        self.max_traces = max_traces
        self._lock = threading.Lock()
        os.makedirs(root, exist_ok=True)

    def _path(self, job_id: str) -> str:
        digest = hashlib.sha1(job_id.encode()).hexdigest()
        return os.path.join(self.root, f"{digest}.trace.json")

    def put(self, job_id: str, trace: Trace | None) -> None:
        """Spool one job's trace (overwrites any earlier spool of the
        same id), then evict past ``max_traces``.  A None/empty trace is
        spooled too — "this job existed" beats a 404."""
        payload = {"job_id": job_id,
                   **(trace.to_wire() if trace is not None
                      else {"trace_id": "", "spans": []})}
        path = self._path(job_id)
        tmp = path + ".tmp"
        with self._lock:
            with open(tmp, "w") as fh:
                json.dump(payload, fh)
            os.replace(tmp, path)
            self._evict_locked()

    def get(self, job_id: str) -> dict[str, Any] | None:
        """The spooled wire payload (``{"job_id", "trace_id", "spans"}``)
        or None — absent and corrupt both read as "not spooled"."""
        try:
            with open(self._path(job_id)) as fh:
                return json.load(fh)
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            return None

    def _evict_locked(self) -> None:
        try:
            files = [os.path.join(self.root, f)
                     for f in os.listdir(self.root)
                     if f.endswith(".trace.json")]
        except OSError:
            return
        if len(files) <= self.max_traces:
            return
        files.sort(key=lambda p: (os.path.getmtime(p), p))
        for p in files[:len(files) - self.max_traces]:
            try:
                os.remove(p)
            except OSError:
                pass                      # raced with another evictor

    def __len__(self) -> int:
        try:
            return sum(1 for f in os.listdir(self.root)
                       if f.endswith(".trace.json"))
        except OSError:
            return 0


# -- rendering ----------------------------------------------------------
def render_gantt(spans: Iterable[Span], width: int = 60) -> str:
    """Fig-9-style ASCII gantt over a list of (possibly multi-process)
    spans: one row per span, start-ordered, bars positioned on the
    common timeline, worker ids in the gutter.  Open spans render to
    "now"."""
    spans = sorted(spans, key=lambda s: (s.start, s.name))
    if not spans:
        return "(no spans)"
    t0 = min(s.start for s in spans)
    t1 = max((s.end if s.end is not None else time.time())
             for s in spans)
    total = max(t1 - t0, 1e-9)
    name_w = max(24, min(40, max(len(s.name) for s in spans)))
    lines = [f"{'span':<{name_w}} {'worker':<12} {'start':>8} "
             f"{'wall':>9}  timeline ({total:.3f}s total)"]
    for s in spans:
        end = s.end if s.end is not None else time.time()
        lo = int(width * (s.start - t0) / total)
        hi = int(width * (end - t0) / total)
        hi = min(max(hi, lo + 1), width)
        bar = " " * lo + "#" * (hi - lo)
        mark = "" if s.end is not None else "…"
        lines.append(
            f"{s.name[:name_w]:<{name_w}} {(s.worker_id or '-')[:12]:<12} "
            f"{s.start - t0:8.3f} {end - s.start:8.4f}s  |{bar:<{width}}|"
            f"{mark}")
    return "\n".join(lines)
