from .train_step import init_training, make_train_step
from .serve_step import (ContinuousBatcher, Request, greedy_generate,
                         make_serve_step)

__all__ = ["make_train_step", "init_training", "make_serve_step",
           "greedy_generate", "ContinuousBatcher", "Request"]
