# The service layer — from processing *framework* to facility *service*
# (the step Nanosurveyor/Daisy make explicit): a multi-tenant scheduler
# that runs many process lists concurrently over shared workers, with a
# process-level compiled-plugin cache, checkpoint/resume, a
# JSON-over-HTTP front end (server/client/wire) for remote submission,
# worker-pull multi-host scheduling (broker/worker) — one queue, many
# worker processes — and parameter sweeps (sweep): Savu-style parameter
# tuning expanded into gang-batched variant jobs.
from .compile_cache import CompileCache
from .checkpoint import CheckpointError, CheckpointStore
from .client import PipelineClient, ServiceError
from .job import Job, JobState, chain_signature
from .queue import JobQueue, QueueFull
from .scheduler import (LeaseLost, PipelineScheduler, WorkerBroker,
                        WorkerInfo)
from .server import PipelineService
from .sweep import (METRICS, SweepAxis, SweepError, SweepGroup,
                    SweepManager, expand_sweep, parse_sweep_block)
from .wire import (WireError, chain_plugin_names, from_spec,
                   register_plugin, registered_plugins, registry_spec,
                   to_spec)
from .worker import PipelineWorker
from .workflow import (WorkflowError, WorkflowGroup, WorkflowManager,
                       toposort)

__all__ = [
    "Job", "JobState", "chain_signature", "JobQueue", "QueueFull",
    "CompileCache", "CheckpointError", "CheckpointStore",
    "PipelineScheduler", "PipelineService", "PipelineClient",
    "PipelineWorker", "WorkerBroker", "WorkerInfo", "LeaseLost",
    "ServiceError", "WireError", "from_spec", "to_spec",
    "register_plugin", "registered_plugins", "registry_spec",
    "chain_plugin_names",
    "METRICS", "SweepAxis", "SweepError", "SweepGroup", "SweepManager",
    "expand_sweep", "parse_sweep_block",
    "WorkflowError", "WorkflowGroup", "WorkflowManager", "toposort",
]
