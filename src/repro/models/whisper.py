"""Whisper-style encoder–decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
feeds precomputed frame embeddings (B, T, d_model) straight into the
encoder.  Encoder layers are bidirectional attention + GELU MLP;
decoder layers add cross-attention into the encoded audio.  Sinusoidal
positions (no rope), pre-LayerNorm.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .attention import _qkv, attention_decode, attention_fwd, init_attention
from .common import ModelConfig, dense_init, split_keys
from .layers import embed_tokens, init_embedding, layer_norm, unembed
from .mlp import init_mlp, mlp_fwd
from .remat import _remat_policy
from .sharding import get_rules, sp_residual


def _sinusoids(length: int, d: int) -> np.ndarray:
    t = np.arange(length)[:, None]
    inv = np.exp(-np.log(10000.0) * np.arange(0, d, 2) / d)
    ang = t * inv[None]
    out = np.zeros((length, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def _init_ln(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _ln(x, p, eps, dtype):
    return layer_norm(x, p["scale"].astype(dtype), p["bias"].astype(dtype),
                      eps)


def _init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = split_keys(key, 2)
    return {
        "ln1": _init_ln(cfg.d_model, cfg.param_dtype),
        "attn": init_attention(k1, cfg),
        "ln2": _init_ln(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype,
                        gated=False),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = split_keys(key, 3)
    return {
        "ln1": _init_ln(cfg.d_model, cfg.param_dtype),
        "attn": init_attention(k1, cfg),
        "ln_x": _init_ln(cfg.d_model, cfg.param_dtype),
        "xattn": init_attention(k2, cfg),
        "ln2": _init_ln(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.param_dtype,
                        gated=False),
    }


def init_whisper(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 4)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_ln_f": _init_ln(cfg.d_model, cfg.param_dtype),
        "dec_ln_f": _init_ln(cfg.d_model, cfg.param_dtype),
        "embed": init_embedding(ks[2], cfg),
    }


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray
           ) -> jnp.ndarray:
    """frames (B, T, d) -> encoded (B, T, d)."""
    dt = cfg.dtype
    b, t, d = frames.shape
    pos = jnp.asarray(_sinusoids(t, d), dt)
    x = frames.astype(dt) + pos[None]

    def body(x, layer):
        h = _ln(x, layer["ln1"], cfg.norm_eps, dt)
        x = sp_residual(x + attention_fwd(layer["attn"], h, cfg,
                                          causal=False))
        h = _ln(x, layer["ln2"], cfg.norm_eps, dt)
        x = sp_residual(x + mlp_fwd(layer["mlp"], h, dt,
                                    activation="gelu"))
        return x, None

    step = body
    if cfg.remat:
        step = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return _ln(x, params["enc_ln_f"], cfg.norm_eps, dt)


def whisper_forward(params: dict, cfg: ModelConfig, *,
                    frames: jnp.ndarray, tokens: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    dt = cfg.dtype
    ctx = encode(params, cfg, frames)
    x = embed_tokens(params["embed"], tokens, dt)
    b, s, d = x.shape
    x = x + jnp.asarray(_sinusoids(s, d), dt)[None]

    def body(x, layer):
        h = _ln(x, layer["ln1"], cfg.norm_eps, dt)
        x = sp_residual(x + attention_fwd(layer["attn"], h, cfg,
                                          causal=True))
        h = _ln(x, layer["ln_x"], cfg.norm_eps, dt)
        x = sp_residual(x + attention_fwd(layer["xattn"], h, cfg,
                                          kv_override=(ctx,)))
        h = _ln(x, layer["ln2"], cfg.norm_eps, dt)
        x = sp_residual(x + mlp_fwd(layer["mlp"], h, dt,
                                    activation="gelu"))
        return x, None

    step = body
    if cfg.remat:
        step = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = _ln(x, params["dec_ln_f"], cfg.norm_eps, dt)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------
def whisper_prefill(params: dict, cfg: ModelConfig, frames: jnp.ndarray,
                    tokens: jnp.ndarray, max_len: int
                    ) -> tuple[jnp.ndarray, dict]:
    """Encode audio + run prompt tokens; build self- and cross-KV caches."""
    r = get_rules()
    dt = cfg.dtype
    ctx = encode(params, cfg, frames)
    x = embed_tokens(params["embed"], tokens, dt)
    b, s, d = x.shape
    x = x + jnp.asarray(_sinusoids(s, d), dt)[None]
    positions = jnp.arange(s, dtype=jnp.int32)
    pad = max_len - s

    def body(x, layer):
        h = _ln(x, layer["ln1"], cfg.norm_eps, dt)
        q, k, v = _qkv(layer["attn"], h, cfg, positions)
        x = x + attention_fwd(layer["attn"], h, cfg, causal=True)
        h = _ln(x, layer["ln_x"], cfg.norm_eps, dt)
        xk = jnp.einsum("bsd,dhk->bshk", ctx,
                        layer["xattn"]["wk"].astype(dt))
        xv = jnp.einsum("bsd,dhk->bshk", ctx,
                        layer["xattn"]["wv"].astype(dt))
        x = x + attention_fwd(layer["xattn"], h, cfg, kv_override=(ctx,))
        h = _ln(x, layer["ln2"], cfg.norm_eps, dt)
        x = x + mlp_fwd(layer["mlp"], h, dt, activation="gelu")
        kc = jnp.pad(k.transpose(0, 2, 1, 3),
                     ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(v.transpose(0, 2, 1, 3),
                     ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x, (kc, vc, xk.transpose(0, 2, 1, 3),
                   xv.transpose(0, 2, 1, 3))

    x, (k_all, v_all, xk_all, xv_all) = jax.lax.scan(
        body, x, params["dec_layers"])
    x = _ln(x, params["dec_ln_f"], cfg.norm_eps, dt)
    logits = unembed(params["embed"], x[:, -1:, :])
    cache = {"k": k_all, "v": v_all, "xk": xk_all, "xv": xv_all,
             "length": jnp.asarray(s, jnp.int32)}
    return logits, cache


def whisper_decode_step(params: dict, cfg: ModelConfig,
                        token: jnp.ndarray, cache: dict
                        ) -> tuple[jnp.ndarray, dict]:
    dt = cfg.dtype
    length = cache["length"]
    x = embed_tokens(params["embed"], token, dt)
    b, _, d = x.shape
    pos_table = jnp.asarray(_sinusoids(cache["k"].shape[3], d), dt)
    x = x + jax.lax.dynamic_slice_in_dim(pos_table, length, 1, 0)[None]

    def body(x, inp):
        layer, ck, cv, xk, xv = inp
        h = _ln(x, layer["ln1"], cfg.norm_eps, dt)
        y, nk, nv = attention_decode(layer["attn"], h, ck, cv, length, cfg)
        x = x + y
        h = _ln(x, layer["ln_x"], cfg.norm_eps, dt)
        # cross-attention: full (non-causal) attention over encoder kv
        q = jnp.einsum("bsd,dhk->bshk", h,
                       layer["xattn"]["wq"].astype(dt)).transpose(0, 2, 1, 3)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.hd, jnp.float32))
        group = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, cfg.n_kv_heads, group, cfg.hd)
        logits = jnp.einsum("bhgk,bhsk->bhgs", qg.astype(xk.dtype), xk,
                            preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgs,bhsk->bhgk", probs.astype(xv.dtype), xv,
                       preferred_element_type=jnp.float32)
        o = o.reshape(b, cfg.n_heads, 1, cfg.hd).transpose(0, 2, 1, 3)
        y = jnp.einsum("bshk,hkd->bsd", o.astype(dt),
                       layer["xattn"]["wo"].astype(dt))
        x = x + y
        h = _ln(x, layer["ln2"], cfg.norm_eps, dt)
        x = x + mlp_fwd(layer["mlp"], h, dt, activation="gelu")
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = _ln(x, params["dec_ln_f"], cfg.norm_eps, dt)
    logits = unembed(params["embed"], x)
    return logits, dict(cache, k=nk, v=nv, length=length + 1)
