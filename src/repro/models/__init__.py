from .common import ModelConfig
from .model_zoo import Model, build_model, cross_entropy
from .sharding import ShardingRules, get_rules, make_rules, set_rules, use_rules

__all__ = ["ModelConfig", "Model", "build_model", "cross_entropy",
           "ShardingRules", "make_rules", "get_rules", "set_rules",
           "use_rules"]
