# Telemetry layer: distributed job tracing, metrics registry,
# structured event log, SLO alerting, OTLP export
# (docs/observability.md).  Deliberately dependency-free — core and
# service both import obs, never the other way round.
from .export import (OtlpSpool, iter_spans, metrics_to_otlp,
                     trace_to_otlp)
from .log import EventLog
from .metrics import (CATALOGUE, QUANTILES, Counter, Gauge, Histogram,
                      MetricsRegistry, catalogue_names, prometheus_name,
                      register_catalogue)
from .slo import SloEngine, SloRule, default_rules, rules_from_spec
from .trace import (Span, Trace, TraceSpool, current_trace, new_span_id,
                    new_trace_id, render_gantt, use_trace)

__all__ = [
    "Span", "Trace", "TraceSpool", "current_trace", "use_trace",
    "new_trace_id",
    "new_span_id", "render_gantt", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "register_catalogue", "catalogue_names",
    "prometheus_name", "CATALOGUE", "QUANTILES",
    "EventLog", "SloEngine", "SloRule", "default_rules",
    "rules_from_spec", "OtlpSpool", "trace_to_otlp", "metrics_to_otlp",
    "iter_spans",
]
