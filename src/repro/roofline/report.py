"""EXPERIMENTS.md §Dry-run/§Roofline table generation from the per-cell
JSON records."""
from __future__ import annotations

import glob
import json
import os


def load_cells(out_dir: str = "experiments/dryrun") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def dryrun_table(cells: list[dict]) -> str:
    lines = [
        "| cell | mesh | state/dev | peak HBM/dev | compile | knobs |",
        "|---|---|---|---|---|---|",
    ]
    for r in sorted(cells, key=lambda r: (r["shape"], r["arch"],
                                          r["tag"])):
        knobs = []
        if r.get("microbatch"):
            knobs.append(f"mb={r['microbatch']}")
        if r.get("remat_policy") not in (None, "dots"):
            knobs.append(f"remat={r['remat_policy']}")
        if r.get("moments") not in (None, "fp32"):
            knobs.append(f"adam={r['moments']}")
        mesh = "x".join(str(s) for s in r["mesh"])
        peak = r["memory"]["peak_estimate"] / 2**30
        flag = " ⚠" if peak > 16 else ""
        lines.append(
            f"| {r['arch']} {r['shape']} | {mesh} | "
            f"{r['state_bytes_per_device'] / 2**30:.2f} GiB | "
            f"{peak:.2f} GiB{flag} | {r['compile_s']:.0f}s | "
            f"{' '.join(knobs) or '—'} |")
    return "\n".join(lines)


def roofline_table(cells: list[dict], mesh_filter: str = "pod") -> str:
    lines = [
        "| cell | compute | memory | collective | bottleneck | "
        "6ND/HLO | roofline-frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(cells, key=lambda r: (r["shape"], r["arch"])):
        if not r["tag"].endswith("__" + mesh_filter):
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        frac = ro["compute_s"] / dom if dom else 0.0
        lines.append(
            f"| {r['arch']} {r['shape']} | "
            f"{ro['compute_s'] * 1e3:.1f} ms | "
            f"{ro['memory_s'] * 1e3:.1f} ms | "
            f"{ro['collective_s'] * 1e3:.1f} ms | "
            f"{ro['bottleneck']} | {ro['useful_ratio']:.2f} | "
            f"{frac:.2f} |")
    return "\n".join(lines)


def summary_stats(cells: list[dict]) -> dict:
    out = {"n_cells": len(cells), "over_hbm": 0, "bottlenecks": {}}
    for r in cells:
        if r["memory"]["peak_estimate"] > 16 * 2**30:
            out["over_hbm"] += 1
        b = r["roofline"]["bottleneck"]
        out["bottlenecks"][b] = out["bottlenecks"].get(b, 0) + 1
    return out


if __name__ == "__main__":
    cells = load_cells()
    print(dryrun_table(cells))
    print()
    print(roofline_table(cells))
    print()
    print(json.dumps(summary_stats(cells), indent=1))
