"""qwen3-moe-235b-a22b [moe] — 128 experts top-8
[hf:Qwen/Qwen3-*; hf].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
every layer MoE (moe_every=1), head_dim=128 (decoupled from d_model).
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=0, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, moe_d_ff=1536, moe_every=1,
    capacity_factor=1.25, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=0, vocab=277, head_dim=16,
    n_experts=4, top_k=2, moe_d_ff=48, moe_every=1,
    dtype=jnp.float32, remat=False)
