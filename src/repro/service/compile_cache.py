"""Process-level compiled-plugin cache.

The paper's headline workload is "the same pipeline over many datasets":
at a facility, hundreds of scans a day run one tuned process list.  On
the jax substrate the expensive part of a repeat submission is the
``jax.jit`` retrace+compile of every plugin, so the service keeps ONE
cache for the whole process, shared by every job's
:class:`~repro.core.transport.ShardedTransport`.

Keys come from ``ShardedTransport._plugin_key``: (plugin static identity,
in/out dataset shapes/dtypes/patterns, constants structure, driver, mesh,
donation).  Values are compiled callables whose setup-derived constants
(dark/flat fields, filter banks...) are jit *arguments*, so a hit is
valid across jobs even when calibration data differs.

Thread-safety: one build per key even under concurrent misses — losers
of the build race block on the winner's per-key event rather than
compiling twice.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable

from ..obs.trace import current_trace


class CompileCache:
    """Process-level compiled-plugin cache (paper §I: "the same
    pipeline, many datasets" — resubmission must not retrace)."""

    def __init__(self, max_entries: int | None = None):
        """Args:
            max_entries: FIFO-evict beyond this many compiled programs
                (None = unbounded).

        Note: an EMPTY cache is falsy (``__len__``) — test ``is None``,
        never truthiness, when defaulting."""
        self.max_entries = max_entries
        self._entries: dict[Any, Any] = {}
        self._building: dict[Any, threading.Event] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.build_s = 0.0               # total wall spent compiling

    def get_or_build(self, key, builder: Callable[[], Any]):
        """Return the cached value for ``key``, building it (once) on a
        miss.

        Args:
            key: hashable identity (see
                ``ShardedTransport._plugin_key`` / ARCHITECTURE.md).
            builder: zero-arg callable producing the compiled program;
                invoked at most once per key even under concurrent
                misses — losers of the build race block on the winner.

        Returns: the cached/built value.  A ``builder`` that raises
        propagates to its caller; waiting losers retry (and one of them
        becomes the next builder).
        """
        while True:
            with self._lock:
                if key in self._entries:
                    self.hits += 1
                    return self._entries[key]
                ev = self._building.get(key)
                if ev is None:
                    self._building[key] = threading.Event()
                    self.misses += 1
                    break
            ev.wait()                    # someone else is compiling this key
        try:
            t0 = time.perf_counter()
            t0_epoch = time.time()
            fn = builder()
            dt = time.perf_counter() - t0
            tr = current_trace()
            if tr is not None:
                # actual builds (never hits) show up as ``compile``
                # spans on whichever job triggered them
                tr.record("compile", t0_epoch, t0_epoch + dt,
                          attrs={"kind": key[0] if isinstance(key, tuple)
                                 and key else "plugin"})
            with self._lock:
                self.build_s += dt
                self._entries[key] = fn
                if (self.max_entries is not None
                        and len(self._entries) > self.max_entries):
                    # FIFO eviction — plugin programs are all roughly the
                    # same size; recency tracking is not worth the locking
                    oldest = next(iter(self._entries))
                    del self._entries[oldest]
                    self.evictions += 1
            return fn
        finally:
            with self._lock:
                self._building.pop(key).set()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        """Drop every cached program (counters are kept)."""
        with self._lock:
            self._entries.clear()

    def stats(self) -> dict[str, Any]:
        """Counters for ``GET /stats``: ``hits``, ``misses``,
        ``entries``, ``evictions``, and total compile ``build_s``."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._entries),
                    "evictions": self.evictions,
                    "build_s": round(self.build_s, 4)}
