"""Public wrapper: FFT (XLA) + Pallas spectrum scale + iFFT."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import scale_spectrum_pallas
from .ref import filter_sino_ref, make_filter  # noqa: F401 (re-export)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def filter_sino(sino: jnp.ndarray, filt: jnp.ndarray, *,
                use_pallas: bool = True, interpret: bool = True
                ) -> jnp.ndarray:
    """Apply a precomputed rfft-domain filter along the detector axis.

    sino: (..., n_det); filt: (n_rfft_bins,).
    """
    if not use_pallas:
        return filter_sino_ref(sino, filt)
    n_det = sino.shape[-1]
    lead = sino.shape[:-1]
    n_fft = 2 * (filt.shape[-1] - 1)
    spec = jnp.fft.rfft(sino.reshape((-1, n_det)), n=n_fft, axis=-1)
    re, im = jnp.real(spec), jnp.imag(spec)
    fre, fim = scale_spectrum_pallas(re, im, filt.reshape(1, -1),
                                     interpret=interpret)
    out = jnp.fft.irfft(jax.lax.complex(fre, fim), n=n_fft, axis=-1)
    return out[..., :n_det].reshape(lead + (n_det,)).astype(sino.dtype)
