"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch × shape × mesh):

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = Σ collective_bytes_per_device / ICI_BW_EFF

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device numbers:
the module is the SPMD per-device program).  collective bytes are NOT
in cost_analysis — they are parsed from the optimized HLO text: the
output shapes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (per-device shapes after SPMD
partitioning), with an all-reduce counted twice (RS+AG decomposition).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ICI_BW_EFF uses 45 GB/s (ring efficiency on one
link; multi-link meshes only improve this, so the collective term is
conservative).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_EFF = 45e9            # effective bytes/s on the collective path

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every array shape in an HLO result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-type byte totals from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-producing op lines look like:  %name = TYPE op-name(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        base = op.rstrip("0123456789.-")
        # match e.g. all-gather, all-gather-start, all-reduce-scatter…
        for coll in _COLLECTIVES:
            if base == coll or base == coll + "-start":
                out[coll] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: float            # per device, weighted
    coll_detail: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0     # 6·N·D (global)
    useful_ratio: float = 0.0    # model / (hlo × devices)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyse(cost: dict, hlo_text: str, *, n_devices: int,
            model_flops: float = 0.0) -> Roofline:
    """Trip-count-aware terms (hlo_cost parser); falls back to XLA's
    cost_analysis numbers only if parsing yields nothing.  XLA's own
    numbers count while bodies once — wrong for scan-over-layers."""
    from .hlo_cost import analyse_hlo
    parsed = analyse_hlo(hlo_text)
    flops = parsed["flops"] or float(cost.get("flops", 0.0))
    byts = parsed["bytes"] or float(cost.get("bytes accessed", 0.0))
    cb = parsed["coll_detail"]
    cb["count"] = -1
    weighted = parsed["collective_bytes"]
    if weighted == 0:
        cb = collective_bytes(hlo_text)
        weighted = (cb["all-gather"] + 2 * cb["all-reduce"] +
                    cb["reduce-scatter"] + cb["all-to-all"] +
                    cb["collective-permute"])
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = weighted / ICI_BW_EFF
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops / (flops * n_devices)
              if flops and model_flops else 0.0)
    return Roofline(flops, byts, float(weighted), cb, compute_s, memory_s,
                    coll_s, bottleneck, model_flops, useful)


def summarise(r: Roofline) -> str:
    return (f"compute={r.compute_s * 1e3:8.2f}ms  "
            f"memory={r.memory_s * 1e3:8.2f}ms  "
            f"collective={r.collective_s * 1e3:8.2f}ms  "
            f"bottleneck={r.bottleneck:10s}  useful={r.useful_ratio:.2f}")
