"""PipelineWorker — a detached worker process pulling jobs from the
broker over HTTP (the cluster half of the paper's "serial on a PC, or in
parallel across a cluster").

One worker = one process (one device mesh).  It registers its
capabilities with the broker (``POST /workers``), leases jobs
(``POST /jobs/lease``), executes each job's process list with a local
:class:`~repro.core.framework.PluginRunner`, heartbeats + streams
per-plugin progress back (``POST /jobs/{id}/progress``) — renewing its
lease and obeying the returned verdict — checkpoints after every step
when ``--checkpoint-dir`` is set, and hands results over either by
uploading ``.npy`` bytes (``PUT /jobs/{id}/result``) or, with
``--shared-fs``, by writing them directly into the broker's shared
results directory (atomic rename).  Wire messages are specified in ``docs/worker-protocol.md``.

Gang execution: a ``--max-batch N`` worker that leases several jobs
with IDENTICAL chain signatures (the broker's batch pop gangs them —
notably parameter-sweep variants, ``docs/sweeps.md``) steps them in
lockstep through ``run_plugin_batch`` when the transport supports it:
each plugin step is ONE compiled call over the whole gang, so remote
sweeps gang exactly like local ones.  Transports without batch support
(inmemory/chunked) fall back to sequential execution.

Fault model: if this process dies (SIGKILL, OOM, node loss) it simply
stops heartbeating; the broker expires the lease and requeues the job,
and the next worker to lease it restores the last checkpoint from the
shared ``--checkpoint-dir`` (``resumed_from`` reported via progress).
A worker that *loses* a lease (verdict ``lost``) abandons the job and
discards any local state — exactly one owner survives.

CLI::

    PYTHONPATH=src python -m repro.service.worker \\
        --url http://127.0.0.1:8973 --transport inmemory \\
        --checkpoint-dir /shared/ckpts --worker-id w0
"""
from __future__ import annotations

import argparse
import importlib
import io
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Callable

import numpy as np

from ..core.framework import PluginRunner
from ..core.profiler import Profiler
from ..core.transport import ChunkedFileTransport, InMemoryTransport, \
    Transport
from ..obs.trace import Trace, use_trace
from .checkpoint import CheckpointStore
from .client import PipelineClient, ServiceError
from .compile_cache import CompileCache
from .job import chain_signature
from .wire import from_spec, registered_plugins


class _Abandon(Exception):
    """Stop working on the current job (lease lost or job cancelled)."""

    def __init__(self, verdict: str):
        super().__init__(verdict)
        self.verdict = verdict


class _Heartbeat(threading.Thread):
    """Background lease renewal while a (possibly slow) plugin step or
    result upload runs: posts a progress message every ``interval``
    seconds for the active job — and a bare renewal for every other
    job leased in the same batch but not yet started, so a batch
    member's lease cannot expire while it waits its turn — and records
    the verdicts; a non-``ok`` verdict on the active job aborts the
    run loop at the next step boundary, one on a pending job drops it
    from the batch.  ``job_id=None`` (gang execution) renews only the
    ``pending`` set — gang members all post their own progress from the
    lockstep loop."""

    def __init__(self, worker: "PipelineWorker", job_id: str | None,
                 interval: float, pending: tuple[str, ...] = ()):
        super().__init__(name=f"heartbeat-{job_id or 'gang'}", daemon=True)
        self.worker = worker
        self.job_id = job_id
        self.interval = interval
        self.pending = list(pending)
        self.abort: str | None = None     # set to the fatal verdict
        self.dropped: set[str] = set()    # pending ids we lost
        self._stop = threading.Event()

    def run(self) -> None:
        while not self._stop.wait(self.interval):
            for jid in [j for j in self.pending
                        if j not in self.dropped]:
                try:                      # bare renewal, no fields
                    out = self.worker.client.progress(
                        jid, self.worker.worker_id)
                except (ServiceError, OSError):
                    continue
                if out.get("verdict") != "ok":
                    self.dropped.add(jid)
            if self.job_id is None:       # gang mode: pending-only
                continue
            # piggyback any finished-but-unshipped spans: mid-plugin
            # heartbeats are the ONLY channel that gets a slow (or
            # about-to-die) worker's history to the broker in time
            body = dict(self.worker._progress_fields)
            tr = self.worker._trace
            shipped = tr.take_unshipped() if tr is not None else []
            if shipped:
                body["spans"] = [s.to_wire() for s in shipped]
            try:
                out = self.worker.client.progress(
                    self.job_id, self.worker.worker_id, **body)
            except (ServiceError, OSError):
                if shipped:
                    tr.unship(shipped)
                continue                  # transient server hiccup
            if out.get("verdict") != "ok":
                self.abort = out.get("verdict", "lost")
                return

    def stop(self) -> None:
        self._stop.set()


class PipelineWorker:
    """Lease → run → heartbeat → hand over results, forever.

    Args:
        base_url: the broker's HTTP address.
        transport_factory: job descriptor -> Transport for each leased
            job (default: a fresh ``InMemoryTransport``).
        checkpoint_dir: save per-plugin checkpoints here and restore on
            lease (point every worker at the SAME directory — shared
            filesystem — to get cross-worker resume of killed jobs).
        shared_fs: write results straight into the broker's
            ``results_dir`` (shared filesystem) instead of uploading
            bytes.
        plugins: advertised wire plugin names (default: everything in
            this process's registry).
        mesh_shape: advertised device-mesh shape (capacity filter).
        max_batch: largest lease the worker accepts; leased jobs with
            identical chain signatures are gang-executed
            (``run_plugin_batch``) when the transport supports it.
        sweeps: advertise willingness to run parameter-sweep variants
            (False keeps this worker out of sweep fan-outs).
        poll: idle sleep between empty leases, seconds.
        heartbeat: lease-renewal cadence; default ``lease_ttl / 3``
            once registered.
        worker_id: explicit id (handy for tests/ops); default assigned
            by the broker.
        token: bearer token for a token-armed broker (sent on every
            request; mutating calls are 401 without it).
        preview_interval: minimum seconds between preview uploads while
            executing a streaming job (0 disables previews).
        compile_cache: the transport's :class:`CompileCache` — when it
            has a persistent store, registration wires it to the
            broker's executable warm pool: hot signatures are
            prefetched BEFORE the first lease, broker payloads are
            fetched on local disk misses, and fresh builds are uploaded
            (docs/worker-protocol.md).
    """

    def __init__(self, base_url: str, *,
                 transport_factory: Callable[[dict], Transport]
                 | None = None,
                 checkpoint_dir: str | None = None,
                 shared_fs: bool = False,
                 plugins: list[str] | None = None,
                 mesh_shape: list[int] | None = None,
                 max_batch: int = 1,
                 sweeps: bool = True,
                 poll: float = 0.5,
                 heartbeat: float | None = None,
                 worker_id: str | None = None,
                 timeout: float = 60.0,
                 token: str | None = None,
                 preview_interval: float = 0.5,
                 compile_cache: CompileCache | None = None):
        self.client = PipelineClient(base_url, timeout=timeout,
                                     token=token)
        self.preview_interval = preview_interval
        self.compile_cache = compile_cache
        self.prefetched = 0              # warm-pool payloads landed
        self.transport_factory = (transport_factory
                                  or (lambda desc: InMemoryTransport()))
        self.checkpoints = (CheckpointStore(checkpoint_dir)
                            if checkpoint_dir else None)
        self.shared_fs = shared_fs
        self.plugins = (plugins if plugins is not None
                        else sorted(registered_plugins()))
        self.mesh_shape = mesh_shape
        self.max_batch = max_batch
        self.sweeps = sweeps
        self.poll = poll
        self.heartbeat = heartbeat
        self.worker_id = worker_id
        self.lease_ttl = 15.0
        self.results_dir: str | None = None
        self.jobs_done = 0
        self.jobs_failed = 0
        self._registered = False
        self._progress_fields: dict[str, Any] = {}
        #: the active (solo) job's trace — heartbeats ship its finished
        #: spans to the broker (docs/observability.md)
        self._trace: Trace | None = None

    # -- registration ---------------------------------------------------
    def register(self) -> str:
        """Announce capabilities; adopt the broker's ``lease_ttl``,
        the minted per-worker secret (the client attaches it to every
        subsequent call) and ``results_dir`` when shared-fs.  With a
        persistent compile cache, also wire the executable warm pool
        and prefetch the broker's hottest signatures BEFORE the first
        lease — a fresh worker deserializes the hot chains instead of
        paying N compile storms.  Returns the worker id."""
        reply = self.client.register_worker(
            worker_id=self.worker_id, plugins=self.plugins,
            mesh_shape=self.mesh_shape, max_batch=self.max_batch,
            shared_fs=self.shared_fs, sweeps=self.sweeps)
        self.worker_id = reply["worker_id"]
        self.lease_ttl = float(reply.get("lease_ttl", self.lease_ttl))
        self.results_dir = reply.get("results_dir")
        if self.heartbeat is None:
            self.heartbeat = max(0.05, self.lease_ttl / 3)
        self._registered = True
        cache = self.compile_cache
        if cache is not None and cache.store is not None:
            cache.fetch = self.client.fetch_executable
            # uploads read self.worker_id at call time so a re-register
            # (new secret, maybe new id) stays wired
            cache.publish = lambda sig, payload: \
                self.client.upload_executable(sig, self.worker_id,
                                              payload)
            self.prefetched = cache.prefetch(
                reply.get("hot_executables") or [])
        return self.worker_id

    # -- main loop ------------------------------------------------------
    def run_forever(self) -> None:
        """Register, then lease-and-run until the process is killed."""
        while True:
            if not self.run_once():
                time.sleep(self.poll)

    def run_once(self) -> bool:
        """One lease round: identical-chain runs of the leased batch are
        gang-executed, the rest run solo.  Returns True if any job was
        run."""
        if not self._registered:
            self.register()
        try:
            # prefetched piggybacks the warm-pool count for the broker's
            # /cluster scoreboard (docs/worker-protocol.md)
            leases = self.client.lease(self.worker_id,
                                       max_jobs=self.max_batch,
                                       prefetched=self.prefetched)
        except ServiceError as e:
            if e.status in (403, 404):
                # 404: broker restarted and lost the registry.  403: our
                # secret was rotated out from under us (another process
                # re-registered this id).  Either way: re-register.
                self._registered = False
            return False
        except OSError:
            return False
        # group consecutive identical chain signatures (the broker's
        # batch pop already delivers gangs contiguously); a spec that
        # fails to parse gets a unique sentinel and fails loudly solo
        sigs: list[Any] = []
        for d in leases:
            try:
                sigs.append(chain_signature(from_spec(d["process_list"])))
            except Exception:            # noqa: BLE001
                sigs.append(("unparseable", d["job_id"]))
        dropped: set[str] = set()
        i = 0
        while i < len(leases):
            j = i + 1
            while j < len(leases) and sigs[j] == sigs[i]:
                j += 1
            group = [d for d in leases[i:j]
                     if d["job_id"] not in dropped]
            rest = tuple(d["job_id"] for d in leases[j:]
                         if d["job_id"] not in dropped)
            if len(group) > 1:
                dropped |= self._run_gang(group, pending=rest)
            elif group:
                dropped |= self._run_leased(group[0], pending=rest)
            i = j
        return bool(leases)

    # -- one job --------------------------------------------------------
    def _run_leased(self, desc: dict[str, Any],
                    pending: tuple[str, ...] = ()) -> set[str]:
        """Run one leased job; keep ``pending`` batch-mates' leases
        renewed meanwhile.  Returns the pending ids whose leases were
        lost (the caller must skip them)."""
        job_id = desc["job_id"]
        hb = _Heartbeat(self, job_id, self.heartbeat or 1.0,
                        pending=pending)
        try:
            self._execute(desc, hb)
        except _Abandon:
            pass          # broker said lost/cancelled: walk away quietly
        except Exception as e:           # noqa: BLE001 — report upstream
            self.jobs_failed += 1
            try:
                tr = self._trace
                self.client.complete(
                    job_id, self.worker_id, "failed",
                    error=f"{type(e).__name__}: {e}",
                    spans=[s.to_wire() for s in tr.take_unshipped()]
                    if tr is not None else [])
            except (ServiceError, OSError):
                pass                     # lease lost: nothing to report
        finally:
            hb.stop()
            self._trace = None
        return hb.dropped

    def _check(self, job_id: str, transient: dict[str, Any] | None = None,
               **fields: Any) -> None:
        """Post a progress heartbeat and enforce the verdict.

        ``transient`` fields ride on THIS post only — they never enter
        ``_progress_fields``, which the heartbeat thread re-posts
        verbatim (a one-shot measurement like ``window_latency`` must
        not be re-observed on every renewal)."""
        # rebind instead of .update(): the heartbeat thread snapshots
        # this dict concurrently, and a dict is never mutated once
        # published (no resize-during-copy race)
        self._progress_fields = {**self._progress_fields, **fields}
        # spans ride along transiently — NOT in _progress_fields, which
        # the heartbeat thread re-posts verbatim (the broker dedups on
        # span_id anyway, this just keeps payloads lean)
        body = {**self._progress_fields, **(transient or {})}
        tr = self._trace
        shipped = tr.take_unshipped() if tr is not None else []
        if shipped:
            body["spans"] = [s.to_wire() for s in shipped]
        try:
            out = self.client.progress(job_id, self.worker_id, **body)
        except (ServiceError, OSError):
            if shipped:
                tr.unship(shipped)       # retry on the next heartbeat
            raise
        verdict = out.get("verdict")
        if verdict != "ok":
            raise _Abandon(verdict or "lost")

    def _execute(self, desc: dict[str, Any], hb: _Heartbeat) -> None:
        job_id = desc["job_id"]
        self._progress_fields = {}
        # adopt the broker's trace id so this attempt's spans land on
        # the same cross-process timeline as the queue/lease spans (and
        # any earlier attempt's) — docs/observability.md
        trace = Trace(desc.get("trace_id") or None,
                      worker_id=self.worker_id)
        self._trace = trace
        # cheap lease confirm BEFORE any expensive prepare/restore — a
        # batch-mate whose lease expired while it waited abandons here
        self._check(job_id)
        # renewals (this job bare, batch-mates pending) start NOW, not
        # after prepare: a slow first prepare must not eat the TTL of
        # every lease in the batch
        hb.start()
        with use_trace(trace), \
                trace.span("attempt", attempt=desc.get("attempt")):
            pl = from_spec(desc["process_list"])
            self._resolve_upstream(pl, trace)
            runner = PluginRunner(pl, self.transport_factory(desc),
                                  profiler=Profiler(
                                      trace=trace,
                                      worker_id=self.worker_id))
            runner.prepare()
            resumed = 0
            if self.checkpoints is not None:
                with trace.span("checkpoint.restore"):
                    resumed = self.checkpoints.restore(job_id, runner)
            self._check(job_id, plugin_index=runner.current_step,
                        n_plugins=runner.n_steps, resumed_from=resumed,
                        **({"checkpoint": self.checkpoints.root}
                           if self.checkpoints else {}))
            if getattr(pl, "streaming", False):
                self._stream_steps(job_id, runner, hb, trace)
            else:
                while True:
                    if hb.abort:
                        raise _Abandon(hb.abort)
                    if not runner.step():
                        break
                    if self.checkpoints is not None:
                        with trace.span("checkpoint.save"):
                            self.checkpoints.save(job_id, runner)
                    self._check(job_id, plugin_index=runner.current_step)
            runner.finalise()
            # the heartbeat keeps renewing through hand-over + complete:
            # a result upload slower than lease_ttl must not lose the
            # lease (hb is stopped by _run_leased's finally)
            with trace.span("result.upload"):
                results = self._hand_over(job_id, runner)
        self.client.complete(job_id, self.worker_id, "done",
                             results=results,
                             plugin_index=runner.current_step,
                             n_plugins=runner.n_steps,
                             spans=[s.to_wire()
                                    for s in trace.take_unshipped()])
        self.jobs_done += 1
        if self.checkpoints is not None:
            self.checkpoints.clear(job_id)

    def _resolve_upstream(self, pl: Any, trace: Trace) -> None:
        """Fetch upstream workflow outputs referenced by split-form
        ``from_job``/``dataset`` params (the broker normalises
        descriptor references to this form for upload-mode workers;
        shared-fs descriptors carry a ``path`` instead, which
        ``upstream_loader`` reads directly) — docs/workflows.md."""
        for e in pl.entries:
            params = e.params
            fj = params.get("from_job")
            if not isinstance(fj, str) or params.get("data") is not None \
                    or params.get("path"):
                continue
            with trace.span("upstream.fetch", from_job=fj):
                params["data"] = self.client.result(
                    fj, params.get("dataset") or None)

    # -- streaming --------------------------------------------------------
    def _stream_steps(self, job_id: str, runner: PluginRunner,
                      hb: _Heartbeat, trace: Trace) -> None:
        """Arrival-driven execution of a streaming job
        (docs/streaming.md): fetch newly-ingested frames from the
        broker, feed them to the runner, pump whatever became runnable,
        and ship rate-limited previews.  A starved stream does not hold
        a lease hostage: with checkpoints enabled the worker saves and
        asks to be PARKED — the broker ends the lease without burning
        an attempt and requeues the job, freeing this worker until
        more frames land."""
        runner.enable_streaming()        # idempotent after restore
        state = runner.stream_state()
        total = state["total"]
        fed = state["ingested"]
        eof_marked = state["eof"]
        last_preview = 0.0
        while runner.current_step < runner.n_steps:
            if hb.abort:
                raise _Abandon(hb.abort)
            try:
                frames, start, eof, _ = self.client.fetch_frames(
                    job_id, start=fed)
            except (ServiceError, OSError):
                time.sleep(min(self.poll, 0.25))
                continue                 # transient broker hiccup
            if frames is None and not eof:
                # starved.  Checkpoint + park so the broker can hand the
                # lease to nobody (the queue holds the job until frames
                # arrive); without checkpoints parking would restart the
                # job from scratch on re-lease, so hold on and wait.
                if self.checkpoints is not None:
                    with trace.span("checkpoint.save"):
                        self.checkpoints.save(job_id, runner)
                    try:
                        out = self.client.progress(
                            job_id, self.worker_id,
                            ingest_watermark=fed, park=True)
                    except (ServiceError, OSError):
                        time.sleep(min(self.poll, 0.25))
                        continue
                    if out.get("verdict") != "ok":
                        raise _Abandon(out.get("verdict", "parked"))
                time.sleep(min(self.poll, 0.25))
                continue
            if frames is None and eof and fed < total:
                raise RuntimeError(
                    f"stream ended at frame {fed} but the loader "
                    f"declares {total} frames")
            if frames is not None:
                fed = runner.feed(frames, int(start))
            if eof and fed == total and not eof_marked:
                runner.mark_eof()
                eof_marked = True
            t0 = time.time()
            did = runner.pump()
            pumped = time.time() - t0
            if frames is None and not did and \
                    runner.current_step < runner.n_steps:
                raise RuntimeError("streaming job stalled after EOF: "
                                   "no step is runnable")
            if self.checkpoints is not None:
                with trace.span("checkpoint.save"):
                    self.checkpoints.save(job_id, runner)
            # window latency is a one-shot observation → transient, so
            # lease renewals can't re-observe it (docs/streaming.md)
            self._check(job_id, plugin_index=runner.current_step,
                        ingest_watermark=fed,
                        transient={"window_latency": pumped}
                        if did else None)
            if self.preview_interval > 0 and \
                    time.time() - last_preview >= self.preview_interval:
                last_preview = time.time()
                self._ship_preview(job_id, runner)

    def _ship_preview(self, job_id: str, runner: PluginRunner) -> None:
        """Best-effort upload of the partial reconstruction as the
        ``__preview__`` result, then report its watermark.  Failures are
        swallowed — previews are advisory, the stream must not die for
        one."""
        try:
            arr, cut = runner.preview()
        except ValueError:
            return                       # nothing reconstructed yet
        buf = io.BytesIO()
        np.save(buf, np.ascontiguousarray(arr))
        try:
            self.client.upload_result(job_id, self.worker_id,
                                      "__preview__", buf.getvalue())
            self._check(job_id, preview_watermark=int(cut))
        except (ServiceError, OSError):
            pass

    # -- gang execution ---------------------------------------------------
    def _verdict(self, job_id: str, trace: Trace | None = None,
                 **fields: Any) -> str:
        """One per-job progress post (shipping ``trace``'s unshipped
        spans when given); returns the broker's verdict."""
        shipped = trace.take_unshipped() if trace is not None else []
        if shipped:
            fields = {**fields,
                      "spans": [s.to_wire() for s in shipped]}
        try:
            out = self.client.progress(job_id, self.worker_id, **fields)
        except (ServiceError, OSError):
            if shipped:
                trace.unship(shipped)    # retry on the next post
            raise
        return out.get("verdict", "lost")

    def _fail_remote(self, job_id: str, exc: Exception,
                     trace: Trace | None = None) -> None:
        self.jobs_failed += 1
        try:
            self.client.complete(
                job_id, self.worker_id, "failed",
                error=f"{type(exc).__name__}: {exc}",
                spans=[s.to_wire() for s in trace.take_unshipped()]
                if trace is not None else [])
        except (ServiceError, OSError):
            pass                         # lease lost: nothing to report

    def _run_gang(self, descs: list[dict[str, Any]],
                  pending: tuple[str, ...] = ()) -> set[str]:
        """Execute leased jobs with identical chain signatures in
        lockstep: ONE transport, each single-plugin step as one
        ``run_plugin_batch`` call over the whole gang — so remote
        parameter sweeps gang exactly like local ones.  Transports
        without batch support fall back to sequential solo runs; a
        member restored from a checkpoint is handed back to the solo
        path (a gang would drag it to step 0).  Returns the ids whose
        leases were lost (caller must skip them)."""
        ids = [d["job_id"] for d in descs]
        transport = self.transport_factory(descs[0])
        if not hasattr(transport, "run_plugin_batch"):
            dropped: set[str] = set()
            for i, d in enumerate(descs):
                if d["job_id"] in dropped:
                    continue
                rest = tuple(x for x in ids[i + 1:]
                             if x not in dropped) + tuple(pending)
                dropped |= self._run_leased(d, pending=rest)
            return dropped
        hb = _Heartbeat(self, None, self.heartbeat or 1.0,
                        pending=tuple(ids) + tuple(pending))
        dropped = set()
        live: list[tuple[dict[str, Any], PluginRunner]] = []
        # per-job traces: gang members interleave on this thread, and
        # the per-(trace, thread) parent stacks keep each job's span
        # links straight
        traces: dict[str, Trace] = {
            d["job_id"]: Trace(d.get("trace_id") or None,
                               worker_id=self.worker_id)
            for d in descs}
        try:
            hb.start()
            solo: list[dict[str, Any]] = []
            for d in descs:
                jid = d["job_id"]
                if self.checkpoints is not None and \
                        self.checkpoints.load(jid) is not None:
                    # a checkpoint exists: resume solo (a gang would
                    # drag it back to step 0); manifest-only probe — the
                    # solo path does the actual restore
                    solo.append(d)
                    continue
                tr = traces[jid]
                try:
                    if self._verdict(jid) != "ok":
                        dropped.add(jid)
                        continue
                    pl = from_spec(d["process_list"])
                    self._resolve_upstream(pl, tr)
                    runner = PluginRunner(pl, transport,
                                          profiler=Profiler(
                                              trace=tr,
                                              worker_id=self.worker_id))
                    runner.prepare()
                    if self._verdict(jid, trace=tr, plugin_index=0,
                                     n_plugins=runner.n_steps,
                                     **({"checkpoint": self.checkpoints.root}
                                        if self.checkpoints else {})) != "ok":
                        dropped.add(jid)
                        continue
                except (ServiceError, OSError):
                    dropped.add(jid)
                    continue
                except Exception as e:   # noqa: BLE001 — report upstream
                    self._fail_remote(jid, e, trace=tr)
                    continue
                live.append((d, runner))
            # lockstep: one batched compiled call per plugin step
            exc: Exception | None = None
            step_total = live[0][1].n_steps if live else 0
            for _ in range(step_total):
                if not live:
                    break
                try:
                    groups = [r.begin_step() for _, r in live]
                    t0 = time.time()
                    if len(live) > 1 and len(groups[0]) == 1:
                        try:
                            transport.run_plugin_batch(
                                [g[0] for g in groups])
                        except ValueError:   # runtime-shape mismatch
                            for g in groups:
                                transport.run_plugin(g[0])
                    else:
                        for g in groups:
                            if len(g) > 1:
                                transport.run_fused(g)
                            else:
                                transport.run_plugin(g[0])
                    t1 = time.time()
                    for (_, r), g in zip(live, groups):
                        # one compiled call over the gang: every
                        # member's trace gets the shared wall
                        r.profiler.record(g[0].name, "process", t0, t1,
                                          gang=len(live))
                        r.complete_step()
                except Exception as e:   # noqa: BLE001 — fails the gang
                    exc = e
                    break
                keep = []
                for d, r in live:
                    jid = d["job_id"]
                    if jid in hb.dropped:
                        dropped.add(jid)
                        continue
                    if self.checkpoints is not None:
                        self.checkpoints.save(jid, r)
                    try:
                        v = self._verdict(jid, trace=traces.get(jid),
                                          plugin_index=r.current_step)
                    except (ServiceError, OSError):
                        v = "ok"        # transient; hb catches real loss
                    if v != "ok":
                        dropped.add(jid)
                        continue
                    keep.append((d, r))
                live = keep
            if exc is not None:
                for d, _ in live:
                    self._fail_remote(d["job_id"], exc,
                                      trace=traces.get(d["job_id"]))
                live = []
            for d, r in live:
                jid = d["job_id"]
                tr = traces[jid]
                try:
                    r.finalise()
                    with tr.span("result.upload"):
                        results = self._hand_over(jid, r)
                    self.client.complete(jid, self.worker_id, "done",
                                         results=results,
                                         plugin_index=r.current_step,
                                         n_plugins=r.n_steps,
                                         spans=[s.to_wire() for s in
                                                tr.take_unshipped()])
                    self.jobs_done += 1
                    if self.checkpoints is not None:
                        self.checkpoints.clear(jid)
                except (ServiceError, OSError):
                    dropped.add(jid)     # lease lost at hand-over
                except Exception as e:   # noqa: BLE001
                    self._fail_remote(jid, e)
            # checkpointed members go back through the solo path (fresh
            # transport + restore; leases were renewed by hb meanwhile)
            for i, d in enumerate(solo):
                if d["job_id"] in dropped | hb.dropped:
                    continue
                rest = tuple(x["job_id"] for x in solo[i + 1:]) \
                    + tuple(pending)
                dropped |= self._run_leased(d, pending=rest)
        finally:
            hb.stop()
        return dropped | hb.dropped
    def _hand_over(self, job_id: str,
                   runner: PluginRunner) -> dict[str, Any]:
        """Deliver every saver output: write an ``.npy`` into the
        broker's shared results_dir, or upload the bytes."""
        results: dict[str, Any] = {}
        for name in runner.result_names():
            ds = runner.datasets[name]
            arr = np.ascontiguousarray(
                np.asarray(runner.transport.read(ds)))
            if self.shared_fs and self.results_dir:
                results[name] = {
                    "path": self._link_result(job_id, name, arr)}
            else:
                buf = io.BytesIO()
                np.save(buf, arr)
                self.client.upload_result(job_id, self.worker_id, name,
                                          buf.getvalue())
                results[name] = {"uploaded": True}
        return results

    def _link_result(self, job_id: str, name: str,
                     arr: np.ndarray) -> str:
        """Write the ``.npy`` straight into the broker's shared
        results_dir (per-worker tmp name + atomic rename, so two
        owners racing a requeue can never interleave bytes)."""
        d = os.path.join(self.results_dir, job_id.replace(os.sep, "_"))
        os.makedirs(d, exist_ok=True)
        dst = os.path.join(d, f"{name}.npy")
        tmp = f"{dst}.{self.worker_id}.tmp"
        with open(tmp, "wb") as fh:
            np.save(fh, arr)
        os.replace(tmp, dst)
        return dst


# ----------------------------------------------------------------------
def spawn_local_workers(url: str, n: int, *, transport: str = "inmemory",
                        checkpoint_dir: str | None = None,
                        shared_fs: bool = False, poll: float = 0.1,
                        heartbeat: float | None = None,
                        max_batch: int = 1,
                        imports: tuple[str, ...] = (),
                        worker_ids: list[str] | None = None,
                        pythonpath_extra: tuple[str, ...] = (),
                        token: str | None = None,
                        executables_dir: str | None = None,
                        cost_analysis: bool = False,
                        stdout: Any = None) -> list:
    """Spawn ``n`` worker subprocesses against a broker URL — the
    ``pipeline_serve --workers-remote N`` demo, benchmarks and tests all
    use this.  Each worker is a real OS process (kill one to exercise
    the lease-expiry/resume path).  Returns the ``Popen`` handles;
    caller terminates them."""
    import subprocess
    import sys
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    parts = [src, *pythonpath_extra]
    if env.get("PYTHONPATH"):
        parts.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(parts)
    procs = []
    for i in range(n):
        # -c instead of -m: repro.service.__init__ imports this module,
        # so runpy would warn about the double import
        cmd = [sys.executable, "-c",
               "from repro.service.worker import main; main()",
               "--url", url, "--transport", transport,
               "--poll", str(poll),
               "--worker-id",
               (worker_ids[i] if worker_ids else f"local-{i}")]
        if checkpoint_dir:
            cmd += ["--checkpoint-dir", checkpoint_dir]
        if shared_fs:
            cmd += ["--shared-fs"]
        if heartbeat is not None:
            cmd += ["--heartbeat", str(heartbeat)]
        if max_batch != 1:
            cmd += ["--max-batch", str(max_batch)]
        for mod in imports:
            cmd += ["--import", mod]
        if token is not None:
            cmd += ["--token", token]
        if executables_dir is not None:
            cmd += ["--executables-dir", executables_dir]
        if cost_analysis:
            cmd += ["--cost-analysis"]
        procs.append(subprocess.Popen(cmd, env=env, stdout=stdout,
                                      stderr=stdout))
    return procs


def _transport_factory(kind: str, scratch: str, donate: bool = True,
                       compile_cache: CompileCache | None = None,
                       cost_analysis: bool = False
                       ) -> Callable[[dict], Transport]:
    if kind == "sharded":
        import jax
        from jax.sharding import Mesh
        from ..core.transport import ShardedTransport
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        # process-level: reused per job; the caller may hand in a cache
        # with a persistent store (the executable warm pool)
        cache = (compile_cache if compile_cache is not None
                 else CompileCache())
        return lambda desc: ShardedTransport(mesh, donate=donate,
                                             compile_cache=cache,
                                             cost_analysis=cost_analysis)
    if kind == "chunked":
        return lambda desc: ChunkedFileTransport(
            os.path.join(scratch, desc["job_id"]))
    return lambda desc: InMemoryTransport()


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.service.worker",
        description=__doc__.split("\n\n")[0])
    ap.add_argument("--url", default="http://127.0.0.1:8973",
                    help="broker base URL")
    ap.add_argument("--transport", default="inmemory",
                    choices=("inmemory", "chunked", "sharded"))
    ap.add_argument("--checkpoint-dir", default=None,
                    help="shared checkpoint directory (cross-worker "
                         "resume needs every worker pointed here)")
    ap.add_argument("--shared-fs", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="write results straight into the broker's "
                         "results_dir (shared filesystem) instead of "
                         "uploading")
    ap.add_argument("--worker-id", default=None)
    ap.add_argument("--max-batch", type=int, default=1,
                    help="largest lease accepted; identical-chain "
                         "batches (e.g. sweep variants) gang-execute")
    ap.add_argument("--sweeps", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="accept parameter-sweep variant jobs "
                         "(--no-sweeps keeps this worker out of sweep "
                         "fan-outs)")
    ap.add_argument("--poll", type=float, default=0.5,
                    help="idle sleep between empty leases, seconds")
    ap.add_argument("--heartbeat", type=float, default=None,
                    help="lease-renewal cadence (default lease_ttl/3)")
    ap.add_argument("--import", dest="imports", action="append",
                    default=[], metavar="MODULE",
                    help="import MODULE before serving (register extra "
                         "wire plugins; repeatable)")
    ap.add_argument("--token", default=None,
                    help="bearer token for a token-armed broker "
                         "(mutating requests are 401 without it)")
    ap.add_argument("--preview-interval", type=float, default=0.5,
                    help="minimum seconds between preview uploads on "
                         "streaming jobs (0 disables previews)")
    ap.add_argument("--executables-dir", default=None,
                    help="local disk tier for serialized executables "
                         "(sharded transport only; default: a subdir "
                         "of the worker scratch directory)")
    ap.add_argument("--cost-analysis",
                    action=argparse.BooleanOptionalAction, default=False,
                    help="attach XLA cost/memory analysis (flops, bytes "
                         "accessed, peak memory) to every jitted "
                         "plugin's process span (sharded transport)")
    args = ap.parse_args(argv)
    for mod in args.imports:
        importlib.import_module(mod)
    scratch = tempfile.mkdtemp(prefix="pipeline-worker-")
    compile_cache = None
    if args.transport == "sharded":
        exe_dir = args.executables_dir or os.path.join(scratch,
                                                       "executables")
        compile_cache = CompileCache(store=exe_dir)
    worker = PipelineWorker(
        args.url,
        # gang execution stacks job inputs — donation would invalidate
        # buffers the stack still references (mirrors the scheduler's
        # --batch rule), so donate only when leases stay solo
        transport_factory=_transport_factory(
            args.transport, scratch, donate=args.max_batch == 1,
            compile_cache=compile_cache,
            cost_analysis=args.cost_analysis),
        checkpoint_dir=args.checkpoint_dir, shared_fs=args.shared_fs,
        worker_id=args.worker_id, max_batch=args.max_batch,
        sweeps=args.sweeps, poll=args.poll, heartbeat=args.heartbeat,
        token=args.token, preview_interval=args.preview_interval,
        compile_cache=compile_cache)
    wid = worker.register()
    print(f"worker {wid} serving {args.url} "
          f"(transport={args.transport}, plugins={len(worker.plugins)}"
          f"{', checkpointed' if worker.checkpoints else ''}"
          f"{', shared-fs' if args.shared_fs else ''}"
          f"{f', prefetched={worker.prefetched}' if worker.prefetched else ''}"
          f")", flush=True)
    try:
        worker.run_forever()
    except KeyboardInterrupt:
        pass
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    main()
