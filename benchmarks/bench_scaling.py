"""Reproduces the cluster-scaling claim (6h→15min in §V): the same
process list run serially vs sharded over N (host-faked) devices.

One physical core backs every faked device here, so *wall time cannot
drop*; what the benchmark verifies instead is that per-device work
(HLO FLOPs from cost_analysis) scales as 1/N while total work stays
flat — the dry-run analogue of strong scaling.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(n)d")
    import numpy as np
    import jax
    from repro.core import PluginRunner, ShardedTransport
    from repro.tomo import standard_chain

    mesh = jax.make_mesh((%(n)d,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tr = ShardedTransport(mesh)
    runner = PluginRunner(standard_chain(n_det=64, n_angles=128,
                                         n_rows=%(n)d), tr, fuse=True)
    import time
    t0 = time.perf_counter()
    out = runner.run()
    wall = time.perf_counter() - t0
    # per-device flops of the fused group via a fresh lowering
    print(json.dumps({"n": %(n)d, "wall": wall}))
""")


def run(report):
    for n in (1, 2, 4):
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD % {"n": n}],
            capture_output=True, text=True, env=None)
        line = proc.stdout.strip().splitlines()[-1] if proc.stdout else ""
        try:
            rec = json.loads(line)
            report(f"scaling_devices_{n}", rec["wall"] * 1e6,
                   "same chain, data axis sharded (1 physical core)")
        except (json.JSONDecodeError, IndexError):
            report(f"scaling_devices_{n}", -1.0,
                   f"FAILED: {proc.stderr.strip().splitlines()[-1:]}")
