"""Logical-axis sharding for the LM substrate, built on core patterns.

The Savu insight — "data declares patterns; the framework derives
placement" — applied to model tensors: every weight/activation carries
*logical axes* (('batch','seq','embed'), ('embed','ffn'), …) and a rules
table maps logical axes -> mesh axes.  This module is the LM analogue of
Pattern.to_pspec and the single source of sharding truth for the zoo.

Divisibility-aware: a logical axis only binds to a mesh axis when the
dimension divides the axis size (e.g. granite's single KV head never
shards over a 16-way model axis; it silently replicates instead, the
standard MQA fallback).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# default rules: logical axis -> preferred mesh axis (None = replicate)
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    # activations
    "batch": ("pod", "data"),       # dp over pod×data jointly
    "seq": None,                    # sharded only in CP mode (see below)
    "seq_cp": "data",               # context-parallel prefill
    "seq_sp": "model",              # sequence-parallel residual stream
    #   (Korthikanti-style SP: the layer-scan carry/residual is sharded
    #   over the TP axis along seq; attention/mlp re-gather per shard.
    #   Auto-disabled for seq==1 (decode) by the divisibility gate.)
    "embed_act": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",              # cache seq dim: takes `model` when
    #   the kv-head dim can't (MQA/GQA with few heads) — split-K decode
    "ffn_act": "model",
    "vocab_act": "model",
    "expert_act": ("pod", "model"),
    # weights (2-D sharded: fsdp over data, tp over model)
    "embed": "data",                # fsdp shard of d_model weight dim
    "ffn": "model",
    "kv_embed": None,
    "vocab": "model",
    "expert": ("pod", "model"),     # expert parallelism
    "expert_ffn": None,
    "layers": None,                 # stacked-layer leading dim
    "state": None,                  # ssm / recurrent state dims
    "conv": None,
    "frames": None,
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh | None
    rules: dict[str, str | tuple[str, ...] | None]

    def spec(self, *logical_axes: str | None) -> PartitionSpec:
        """PartitionSpec for a tensor with the given logical axes.

        Each mesh axis may be used at most once per spec (XLA rule); later
        duplicates replicate instead.
        """
        used: set[str] = set()
        out = []
        for ax in logical_axes:
            m = self.rules.get(ax) if ax else None
            if m is None:
                out.append(None)
                continue
            cands = (m,) if isinstance(m, str) else tuple(m)
            cands = tuple(c for c in cands
                          if self.mesh is None or c in self.mesh.axis_names)
            cands = tuple(c for c in cands if c not in used)
            if not cands:
                out.append(None)
            elif len(cands) == 1:
                used.add(cands[0])
                out.append(cands[0])
            else:
                used.update(cands)
                out.append(cands)
        return PartitionSpec(*out)

    def divisible_spec(self, shape: Sequence[int],
                       *logical_axes: str | None) -> PartitionSpec:
        """Allocation-aware spec: walk the dims in order, binding each
        logical axis's mesh axis only when (a) still unused and (b) the
        dim divides the axis extent.  A later dim can therefore pick up
        a mesh axis an earlier dim had to decline (e.g. the KV-cache seq
        dim takes ``model`` when kv_heads isn't divisible — MQA)."""
        if self.mesh is None:
            return self.spec(*logical_axes)
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        used: set[str] = set()
        out: list = []
        padded = tuple(logical_axes) + (None,) * (len(shape) -
                                                  len(logical_axes))
        for dim, ax in zip(shape, padded):
            m = self.rules.get(ax) if ax else None
            if m is None:
                out.append(None)
                continue
            cands = (m,) if isinstance(m, str) else tuple(m)
            cands = tuple(c for c in cands if c in self.mesh.axis_names
                          and c not in used)
            # try the full compound binding first, then single axes
            bound = None
            if len(cands) > 1:
                extent = 1
                for c in cands:
                    extent *= sizes[c]
                if dim % extent == 0:
                    bound = cands
            if bound is None:
                for c in cands:
                    if dim % sizes[c] == 0 and sizes[c] > 1:
                        bound = c
                        break
            if bound is None:
                out.append(None)
            else:
                out.append(bound)
                used.update((bound,) if isinstance(bound, str) else bound)
        return PartitionSpec(*out)

    def sharding(self, shape: Sequence[int], *logical_axes: str | None
                 ) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.divisible_spec(shape,
                                                            *logical_axes))

    def constrain(self, x: jax.Array, *logical_axes: str | None) -> jax.Array:
        """with_sharding_constraint when a mesh is active; no-op otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh,
                             self.divisible_spec(x.shape, *logical_axes)))


def make_rules(mesh: Mesh | None = None,
               overrides: Mapping[str, str | tuple[str, ...] | None] | None
               = None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return ShardingRules(mesh, rules)


# A module-level "current rules" the model code reads; the launcher sets
# it under the production mesh, tests leave it at no-mesh (no-op).
_CURRENT = make_rules(None)


def set_rules(rules: ShardingRules) -> None:
    global _CURRENT
    _CURRENT = rules


def get_rules() -> ShardingRules:
    return _CURRENT


def sp_residual(x):
    """Sequence-parallel constraint for the residual stream / scan carry
    (B, S, d): batch->data, seq->model.  The saved per-layer carries are
    the dominant training-memory term; SP divides them by the TP size."""
    return get_rules().constrain(x, "batch", "seq_sp", "embed_act")


class use_rules:
    """Context manager: with use_rules(make_rules(mesh)): ..."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        self.prev = get_rules()
        set_rules(self.rules)
        return self.rules

    def __exit__(self, *exc):
        set_rules(self.prev)
        return False
