"""Batched serving example: continuous batching over decode slots.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "zamba2-1.2b", "--smoke",
                "--requests", "6", "--slots", "3",
                "--max-new", "8", "--max-len", "32"] + sys.argv[1:]
    main()
