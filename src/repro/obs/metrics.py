"""Metrics registry — counters, gauges and bounded-reservoir histograms
behind one namespace, replacing the hand-rolled per-component ``stats()``
dicts.

The facility papers this repo tracks (Savu's profiler, Nanosurveyor's
live status stream, CHESS's facility-wide dashboards) all treat
monitoring as infrastructure, not printf.  Design points:

* **One registry per service** (no process-global state — tests can run
  many services in one process).  Components take the registry as an
  optional constructor argument and no-op cleanly without it.
* **Counters** only go up.  **Gauges** hold a value or call a function
  at read time (``queue.depth`` reads the live queue, nothing pushes).
* **Histograms** keep a bounded reservoir (Vitter's algorithm R with a
  seeded RNG — deterministic under test) so p50/p95/p99 stay O(1) RAM
  no matter how many jobs flow through; ``count``/``sum`` stay exact.
* **Prometheus text exposition** (``GET /metrics``): dots become
  underscores, histograms render as summaries with ``quantile`` labels.
* A **catalogue** of well-known names is pre-registered by the service
  so ``/metrics`` is complete from the first scrape (and CI can fail on
  a missing name rather than on a race with traffic).
"""
from __future__ import annotations

import random
import re
import threading
from typing import Any, Callable, Iterable

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

#: quantiles every histogram reports
QUANTILES = (0.5, 0.95, 0.99)


def prometheus_name(name: str) -> str:
    """Sanitise a dotted metric name for the Prometheus exposition
    format (``job.latency.e2e`` -> ``job_latency_e2e``)."""
    name = _NAME_RE.sub("_", name.replace(".", "_"))
    if name and name[0].isdigit():
        name = "_" + name
    return name


class Counter:
    """Monotonic counter (``jobs.completed``, ``lease.expired``...)."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) — counters "
                             f"only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value: either set explicitly or computed by a
    zero-arg callback at read time (``queue.depth`` must reflect the
    queue NOW, not the last event)."""

    def __init__(self, name: str, help: str = "",
                 fn: Callable[[], float] | None = None):
        self.name, self.help = name, help
        self._fn = fn
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:        # noqa: BLE001 — scrape must not 500
                return float("nan")
        with self._lock:
            return self._value


class Histogram:
    """Bounded-reservoir histogram with exact count/sum and
    reservoir-estimated quantiles.

    Reservoir sampling (algorithm R) keeps a uniform sample of all
    observations in ``reservoir_size`` slots; with the default 1024
    slots the p99 estimate is stable to a few percent while RAM stays
    constant over a service's lifetime.  The RNG is seeded per-instance
    so test runs are reproducible.
    """

    def __init__(self, name: str, help: str = "",
                 reservoir_size: int = 1024, seed: int = 0):
        if reservoir_size < 1:
            raise ValueError("reservoir_size must be >= 1")
        self.name, self.help = name, help
        self.reservoir_size = reservoir_size
        self._rng = random.Random(seed)
        self._reservoir: list[float] = []
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            if len(self._reservoir) < self.reservoir_size:
                self._reservoir.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < self.reservoir_size:
                    self._reservoir[j] = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float | None:
        """The q-quantile (0 <= q <= 1) of the reservoir sample — None
        while empty.  Nearest-rank on the sorted sample: q=0 is the
        min, q=1 the max, and every returned value is an actual
        observation."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            if not self._reservoir:
                return None
            data = sorted(self._reservoir)
        idx = min(len(data) - 1, int(q * len(data)))
        return data[idx]

    def quantiles(self, qs: Iterable[float] = QUANTILES
                  ) -> dict[float, float | None]:
        return {q: self.quantile(q) for q in qs}


class MetricsRegistry:
    """Name -> instrument registry for one service.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent,
    so components can declare what they use without coordinating);
    re-registering a name as a different kind raises.  ``snapshot()``
    is the JSON view (folded into ``GET /stats``),
    ``render_prometheus()`` the text exposition for ``GET /metrics``.
    """

    #: content type of the Prometheus text exposition format
    CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

    def __init__(self):
        self._metrics: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, kind: type, **kw) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(name, **kw)
            elif not isinstance(m, kind):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help=help)

    def gauge(self, name: str, help: str = "",
              fn: Callable[[], float] | None = None) -> Gauge:
        g = self._get_or_create(name, Gauge, help=help)
        if fn is not None:
            g.set_function(fn)
        return g

    def histogram(self, name: str, help: str = "",
                  reservoir_size: int = 1024) -> Histogram:
        return self._get_or_create(name, Histogram, help=help,
                                   reservoir_size=reservoir_size)

    def get(self, name: str) -> Any | None:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    # -- export ---------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        """JSON-able view: counters/gauges as numbers, histograms as
        ``{count, sum, p50, p95, p99}``."""
        with self._lock:
            items = list(self._metrics.items())
        out: dict[str, Any] = {}
        for name, m in sorted(items):
            if isinstance(m, Histogram):
                qs = m.quantiles()
                out[name] = {"count": m.count, "sum": round(m.sum, 6),
                             **{f"p{int(q * 100)}": qs[q]
                                for q in QUANTILES}}
            else:
                out[name] = m.value
        return out

    def render_prometheus(self) -> str:
        """The text exposition format, one block per metric: ``# HELP``
        / ``# TYPE`` then the samples; histograms as summaries with
        ``quantile`` labels plus ``_count``/``_sum``."""
        with self._lock:
            items = list(self._metrics.items())
        lines: list[str] = []
        for name, m in sorted(items):
            pname = prometheus_name(name)
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_fmt(m.value)}")
            else:
                lines.append(f"# TYPE {pname} summary")
                for q, v in m.quantiles().items():
                    if v is not None:
                        lines.append(
                            f'{pname}{{quantile="{q}"}} {_fmt(v)}')
                lines.append(f"{pname}_count {m.count}")
                lines.append(f"{pname}_sum {_fmt(m.sum)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v != v:                       # NaN
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


# -- the service's well-known metric names ------------------------------
#: (dotted name, kind, help) — pre-registered by the service so the
#: ``/metrics`` exposition is complete from the first scrape.  CI fails
#: if any of these is missing from a live endpoint.
CATALOGUE: tuple[tuple[str, str, str], ...] = (
    ("queue.depth", "gauge", "jobs waiting in the admission queue"),
    ("queue.oldest_age_s", "gauge",
     "age in seconds of the oldest still-queued job (starvation signal)"),
    ("jobs.submitted", "counter", "jobs admitted via submit"),
    ("jobs.completed", "counter", "jobs that reached done"),
    ("jobs.failed", "counter", "jobs that reached failed"),
    ("jobs.cancelled", "counter", "jobs cancelled before completion"),
    ("jobs.requeued", "counter",
     "jobs requeued after a lease expiry (broker mode)"),
    ("lease.expired", "counter", "leases expired by the broker sweep"),
    ("leases.active", "gauge", "leases currently held by workers"),
    ("workers.registered", "gauge", "worker processes registered"),
    ("compile.cache.hits", "gauge", "compile-cache hits (process cache)"),
    ("compile.cache.misses", "gauge",
     "compile-cache misses (process cache)"),
    ("compile.cache.disk.hits", "gauge",
     "compile-cache disk-tier hits (deserialized executables)"),
    ("compile.cache.disk.misses", "gauge",
     "compile-cache disk-tier misses (fresh compiles)"),
    ("executables.uploaded", "counter",
     "serialized executables accepted over PUT /executables/{sig}"),
    ("executables.served", "counter",
     "serialized executables streamed over GET /executables/{sig}"),
    ("executables.spool.bytes", "gauge",
     "bytes currently held in the broker's executable spool"),
    ("job.latency.e2e", "histogram",
     "submit-to-terminal latency, seconds"),
    ("job.latency.queue", "histogram",
     "submit-to-dispatch queue wait, seconds"),
    ("plugin.wall", "histogram",
     "per-plugin-step wall time across all jobs, seconds"),
    # -- streaming acquisition (docs/streaming.md) ----------------------
    ("stream.frames.ingested", "counter",
     "frames accepted over POST /jobs/{id}/frames"),
    ("jobs.parked", "counter",
     "streaming-job leases ended early for frame starvation (parked)"),
    ("stream.ingest_lag_s", "histogram",
     "frame arrival to executor consumption lag, seconds"),
    ("stream.window_latency_s", "histogram",
     "wall time of one arrival-driven pump over new frames, seconds"),
    # -- health plane (docs/observability.md: events + SLO) -------------
    ("executables.rejected", "counter",
     "executable uploads the broker spool refused (unframed/corrupt)"),
    ("alerts.fired", "counter",
     "SLO alert pending->firing transitions"),
    ("alerts.resolved", "counter",
     "SLO alert firing->resolved transitions"),
    ("slo.firing", "gauge", "SLO rules currently in the firing state"),
    ("events.head", "gauge",
     "newest structured-event sequence number (event-log write head)"),
)


def register_catalogue(reg: MetricsRegistry) -> None:
    """Pre-register every well-known metric (idempotent)."""
    for name, kind, help_ in CATALOGUE:
        getattr(reg, kind)(name, help=help_)


def catalogue_names() -> list[str]:
    return [name for name, _, _ in CATALOGUE]
