import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lowers the three chosen cells with one
knob flipped per iteration and records before/after JSON pairs in
experiments/perf/.

    PYTHONPATH=src python -m repro.launch.perf --thread A
"""
import argparse
import json

from .dryrun import lower_cell
from .mesh import make_production_mesh

OUT = "experiments/perf"


def save(rec, name):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name + ".json"), "w") as fh:
        json.dump(rec, fh, indent=1)
    ro = rec["roofline"]
    print(f"{name:52s} mem/dev={rec['memory']['peak_estimate'] / 2**30:7.2f}GiB "
          f"comp={ro['compute_s'] * 1e3:9.1f} mem={ro['memory_s'] * 1e3:9.1f} "
          f"coll={ro['collective_s'] * 1e3:9.1f} -> {ro['bottleneck']}",
          flush=True)
    return rec


def thread_a3(mesh):
    """A3: grouped dispatch + explicit ZeRO-3 gather of expert weights
    (contract-over-sharded-d otherwise all-reduces full partials)."""
    base = dict(microbatch=8, remat_policy="nothing")
    save(lower_cell("qwen3-moe-235b-a22b", "train_4k", mesh,
                    moe_grouped=True, **base),
         "A3_qwen3_train_grouped_zero3gather")


def thread_b2(mesh):
    """B2: is the decode collective the seq-sharded (split-K) cache?"""
    save(lower_cell("granite-34b", "decode_32k", mesh,
                    rules_overrides={"kv_seq": None}),
         "B2_g34_decode_no_kvseq")


def thread_b3(mesh):
    """B3: TP-only bf16 weights for serving (no per-layer FSDP weight
    all-gathers; decode batch can't amortise them)."""
    import jax.numpy as jnp
    save(lower_cell("granite-34b", "decode_32k", mesh,
                    param_dtype=jnp.bfloat16, serve_params="serve"),
         "B3_g34_decode_tp_only_bf16")


def thread_a(mesh):
    """qwen3-moe train_4k: MoE dispatch collective volume."""
    base = dict(microbatch=8, remat_policy="nothing")
    save(lower_cell("qwen3-moe-235b-a22b", "train_4k", mesh, **base),
         "A0_qwen3_train_flat")
    save(lower_cell("qwen3-moe-235b-a22b", "train_4k", mesh,
                    moe_grouped=True, **base),
         "A1_qwen3_train_grouped")
    # A2: grouped + no-SP (does SP still pay under grouped dispatch?)
    save(lower_cell("qwen3-moe-235b-a22b", "train_4k", mesh,
                    moe_grouped=True, sp=False, **base),
         "A2_qwen3_train_grouped_nosp")


def thread_b(mesh):
    """granite-34b decode_32k: serving memory floor."""
    save(lower_cell("granite-34b", "decode_32k", mesh),
         "B0_g34_decode_fp32params")
    import jax.numpy as jnp
    save(lower_cell("granite-34b", "decode_32k", mesh,
                    param_dtype=jnp.bfloat16),
         "B1_g34_decode_bf16params")


def thread_c(mesh):
    """llava train_4k: 56 heads don't divide the 16-way TP axis."""
    base = dict(microbatch=16, remat_policy="nothing")
    save(lower_cell("llava-next-34b", "train_4k", mesh, **base),
         "C0_llava_train_replicated_heads")
    save(lower_cell("llava-next-34b", "train_4k", mesh,
                    seq_fallback=True, **base),
         "C1_llava_train_seqshard")
    # C2: seq-fallback + tighter microbatch
    save(lower_cell("llava-next-34b", "train_4k", mesh, seq_fallback=True,
                    microbatch=16, remat_policy="dots"),
         "C2_llava_train_seqshard_dots")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--thread", default="all",
                    choices=["A", "A3", "B", "B2", "B3", "C", "all", "round2"])
    args = ap.parse_args()
    mesh = make_production_mesh()
    if args.thread in ("A", "all"):
        thread_a(mesh)
    if args.thread in ("B", "all"):
        thread_b(mesh)
    if args.thread in ("C", "all"):
        thread_c(mesh)
    if args.thread in ("A3", "round2"):
        thread_a3(mesh)
    if args.thread in ("B2", "round2"):
        thread_b2(mesh)
    if args.thread in ("B3", "round2"):
        thread_b3(mesh)


if __name__ == "__main__":
    main()
