"""Zamba2-style hybrid: a Mamba-2 backbone with a *shared* attention+MLP
block applied periodically (arXiv:2411.15242).  The shared block's
weights are reused at every application (Zamba's parameter-sharing
trick); each application keeps its own KV cache.

Layer layout for n_layers = G·k + r with ``attn_every = k``:
  G groups of [k stacked mamba layers → shared transformer block]
  followed by r trailing mamba layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import attention_decode, attention_fwd, init_attention
from .common import ModelConfig, split_keys
from .layers import embed_tokens, init_embedding, rms_norm, unembed
from .mamba2 import (init_mamba_block, init_mamba_cache, mamba_fwd,
                     mamba_step)
from .mlp import init_mlp, mlp_fwd
from .remat import _remat_policy
from .sharding import get_rules, sp_residual


def _layout(cfg: ModelConfig) -> tuple[int, int, int]:
    k = cfg.attn_every or cfg.n_layers
    g = cfg.n_layers // k
    r = cfg.n_layers - g * k
    return g, k, r


def init_zamba(key, cfg: ModelConfig) -> dict:
    g, k, r = _layout(cfg)
    ks = split_keys(key, 6)
    group_keys = jax.random.split(ks[0], (g, k))
    groups = jax.vmap(jax.vmap(lambda kk: init_mamba_block(kk, cfg)))(
        group_keys)
    params = {
        "embed": init_embedding(ks[1], cfg),
        "groups": groups,                       # leaves (G, k, ...)
        "shared_ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "shared_attn": init_attention(ks[2], cfg),
        "shared_ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "shared_mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                               cfg.param_dtype),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if r:
        tail_keys = jax.random.split(ks[4], r)
        params["tail"] = jax.vmap(lambda kk: init_mamba_block(kk, cfg))(
            tail_keys)
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(ks[5], cfg)
    return params


def _shared_block(params, x, cfg: ModelConfig, positions):
    h = rms_norm(x, params["shared_ln1"].astype(cfg.dtype), cfg.norm_eps)
    x = x + attention_fwd(params["shared_attn"], h, cfg,
                          positions=positions)
    h = rms_norm(x, params["shared_ln2"].astype(cfg.dtype), cfg.norm_eps)
    return x + mlp_fwd(params["shared_mlp"], h, cfg.dtype)


def zamba_forward(params: dict, cfg: ModelConfig, *,
                  tokens: jnp.ndarray | None = None,
                  embeds: jnp.ndarray | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    g, k, r = _layout(cfg)
    x = (embed_tokens(params["embed"], tokens, cfg.dtype)
         if embeds is None else embeds.astype(cfg.dtype))
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    def group_body(x, group):
        def mamba_body(x, layer):
            return sp_residual(x + mamba_fwd(layer, x, cfg)), None
        x, _ = jax.lax.scan(mamba_body, x, group)
        x = sp_residual(_shared_block(params, x, cfg, positions))
        return x, None

    step = group_body
    if cfg.remat:
        step = jax.checkpoint(group_body, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(step, x, params["groups"])
    if r:
        def mamba_body(x, layer):
            return sp_residual(x + mamba_fwd(layer, x, cfg)), None
        x, _ = jax.lax.scan(mamba_body, x, params["tail"])
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    return unembed(table, x), jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------
def init_zamba_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    from .sharding import get_rules
    rules = get_rules()
    g, k, r = _layout(cfg)
    one = init_mamba_cache(cfg, batch)

    def pin(lead, tree):
        # conv (B, W-1, conv) and ssd (B, H, N, P) leaves, stacked `lead`
        return type(tree)(
            conv=rules.constrain(
                jnp.broadcast_to(tree.conv, lead + tree.conv.shape),
                *([None] * len(lead)), "batch", None, "ffn_act"),
            ssd=rules.constrain(
                jnp.broadcast_to(tree.ssd, lead + tree.ssd.shape),
                *([None] * len(lead)), "batch", "heads", None, None))

    cache = {
        "mamba": pin((g, k), one),
        "attn_k": rules.constrain(
            jnp.zeros((g, batch, cfg.n_kv_heads, max_len, cfg.hd),
                      cfg.dtype), None, "batch", "kv_heads", "kv_seq", None),
        "attn_v": rules.constrain(
            jnp.zeros((g, batch, cfg.n_kv_heads, max_len, cfg.hd),
                      cfg.dtype), None, "batch", "kv_heads", "kv_seq", None),
        "length": jnp.zeros((), jnp.int32),
    }
    if r:
        cache["tail"] = pin((r,), one)
    return cache


def zamba_decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                      cache: dict) -> tuple[jnp.ndarray, dict]:
    g, k, r = _layout(cfg)
    x = embed_tokens(params["embed"], token, cfg.dtype)
    length = cache["length"]

    def group_body(x, inp):
        group, mcaches, ck, cv = inp

        def mamba_body(carry, inp2):
            x = carry
            layer, mc = inp2
            y, mc_new = mamba_step(layer, x, mc, cfg)
            return x + y, mc_new

        x, mcaches_new = jax.lax.scan(mamba_body, x, (group, mcaches))
        h = rms_norm(x, params["shared_ln1"].astype(cfg.dtype),
                     cfg.norm_eps)
        y, nk, nv = attention_decode(params["shared_attn"], h, ck, cv,
                                     length, cfg)
        x = x + y
        h = rms_norm(x, params["shared_ln2"].astype(cfg.dtype),
                     cfg.norm_eps)
        x = x + mlp_fwd(params["shared_mlp"], h, cfg.dtype)
        return x, (mcaches_new, nk, nv)

    x, (mc_new, nk, nv) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["mamba"], cache["attn_k"],
         cache["attn_v"]))
    new_cache = dict(cache, mamba=mc_new, attn_k=nk, attn_v=nv,
                     length=length + 1)
    if r:
        def mamba_body(carry, inp2):
            x = carry
            layer, mc = inp2
            y, mc_new = mamba_step(layer, x, mc, cfg)
            return x + y, mc_new
        x, tail_new = jax.lax.scan(mamba_body, x,
                                   (params["tail"], cache["tail"]))
        new_cache["tail"] = tail_new
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    return unembed(table, x), new_cache
