"""llava-next-34b [vlm] — anyres tiling STUB
[hf:llava-hf/llava-v1.6-*; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
input_specs() provides pre-projected patch embeddings (the anyres
vision tower + projector are stubbed per the assignment); patches are
prepended to the token embeddings.
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "llava-next-34b"
PATCH_TOKENS = 2048          # anyres tiles x 576 patches, truncated stub

FULL = ModelConfig(
    arch_id=ARCH_ID, family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    frontend="patch", dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=283, head_dim=16,
    frontend="patch", dtype=jnp.float32, remat=False)
