"""Per-plugin profiler — the MPI-profiler analogue (paper §IV.B, Fig 9).

Savu ships a profiler that visualises, per MPI process, the time each
processing step took.  Since the telemetry layer landed
(``repro.obs``), the profiler is a thin *view* over a
:class:`~repro.obs.trace.Trace` rather than a parallel event system:
every ``timer()`` records a ``plugin.<name>.<phase>`` span (epoch
timestamps, so spans from different processes align on one timeline),
and the classic API — ``record``/``totals``/``report``/``save`` — keeps
working on top of it.  A :class:`PluginRunner` handed a profiler whose
trace is the job's trace therefore feeds the distributed timeline at
``GET /jobs/{id}/trace`` for free.

``report()`` renders the Fig-9-style ASCII bar chart; ``save()`` emits
the historical event-list JSON for the benchmark harness.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

from ..obs.trace import Span, Trace


@dataclasses.dataclass
class Event:
    """Legacy per-phase event view (kept for API compatibility); the
    authoritative record is the underlying :class:`Span`."""

    plugin: str
    phase: str          # 'setup' | 'pre' | 'process' | 'post' | 'io'
    start: float
    end: float
    devices: int = 1
    flops: float | None = None
    bytes: float | None = None
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def wall(self) -> float:
        return self.end - self.start


def _span_to_event(s: Span) -> Event:
    a = dict(s.attrs)
    plugin = a.pop("plugin", None)
    phase = a.pop("phase", None)
    if plugin is None or phase is None:
        # span name is "plugin.<name>.<phase>"; plugin names may
        # themselves contain dots only via fused "a+b" labels, which
        # don't — split from the ends
        parts = s.name.split(".")
        plugin = plugin or ".".join(parts[1:-1]) or s.name
        phase = phase or (parts[-1] if len(parts) > 1 else "")
    return Event(plugin, phase, s.start,
                 s.end if s.end is not None else s.start,
                 devices=a.pop("devices", 1), flops=a.pop("flops", None),
                 bytes=a.pop("bytes", None), extra=a)


class Profiler:
    """Record plugin-phase timings as spans on a trace.

    Args:
        trace: the trace spans land on — pass the JOB's trace to make
            plugin timings part of its cross-process timeline; default
            a private one (classic in-process profiling).
        worker_id: stamped on every recorded span (multi-process
            attribution in merged traces).
    """

    def __init__(self, trace: Trace | None = None,
                 worker_id: str | None = None):
        self.trace = trace if trace is not None else Trace()
        self.worker_id = worker_id
        self._t0 = time.time()

    # ------------------------------------------------------------------
    def record(self, plugin: str, phase: str, start: float, end: float,
               devices: int = 1, flops=None, bytes=None, **extra) -> None:
        attrs: dict[str, Any] = {"plugin": plugin, "phase": phase,
                                 "devices": devices, **extra}
        if flops is not None:
            attrs["flops"] = flops
        if bytes is not None:
            attrs["bytes"] = bytes
        self.trace.record(f"plugin.{plugin}.{phase}", start, end,
                          worker_id=self.worker_id, attrs=attrs)

    class _Timer:
        def __init__(self, prof, plugin, phase, devices, extra):
            self.prof, self.plugin, self.phase = prof, plugin, phase
            self.devices, self.extra = devices, extra

        def __enter__(self):
            self.span = self.prof.trace.begin(
                f"plugin.{self.plugin}.{self.phase}",
                worker_id=self.prof.worker_id,
                attrs={"plugin": self.plugin, "phase": self.phase,
                       "devices": self.devices, **self.extra})
            return self

        def __exit__(self, exc_type, *exc):
            if exc_type is not None:
                self.span.attrs["error"] = exc_type.__name__
            self.prof.trace.finish(self.span)
            return False

    def timer(self, plugin: str, phase: str, devices: int = 1, **extra):
        """Context manager timing one plugin phase (epoch clock)."""
        return Profiler._Timer(self, plugin, phase, devices, extra)

    # ------------------------------------------------------------------
    @property
    def events(self) -> list[Event]:
        """The plugin-phase spans as legacy :class:`Event` records
        (computed view; ordered by start time)."""
        return [_span_to_event(s) for s in self.trace.spans()
                if s.name.startswith("plugin.")]

    def totals(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for e in self.events:
            out[e.plugin] = out.get(e.plugin, 0.0) + e.wall
        return out

    def report(self, width: int = 50) -> str:
        """Fig-9-style per-plugin bar chart."""
        events = self.events
        totals: dict[str, float] = {}
        for e in events:
            totals[e.plugin] = totals.get(e.plugin, 0.0) + e.wall
        if not totals:
            return "(no events)"
        tmax = max(totals.values()) or 1.0
        lines = [f"{'plugin':<32} {'wall(s)':>9}  profile"]
        for name, t in totals.items():
            bar = "#" * max(1, int(width * t / tmax))
            lines.append(f"{name:<32} {t:9.4f}  {bar}")
        phases: dict[str, float] = {}
        for e in events:
            phases[e.phase] = phases.get(e.phase, 0.0) + e.wall
        lines.append("")
        lines.append("per-phase: " + "  ".join(
            f"{k}={v:.4f}s" for k, v in sorted(phases.items())))
        return "\n".join(lines)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump([dataclasses.asdict(e) for e in self.events], fh,
                      indent=2, default=str)

    @staticmethod
    def load(path: str) -> "Profiler":
        p = Profiler()
        with open(path) as fh:
            for d in json.load(fh):
                extra = d.pop("extra", {}) or {}
                p.record(d["plugin"], d["phase"], d["start"], d["end"],
                         devices=d.get("devices", 1),
                         flops=d.get("flops"), bytes=d.get("bytes"),
                         **extra)
        return p
