"""Quickstart: the paper's standard full-field chain on a synthetic
scan, serial (PC) mode — loader → dark/flat correction → ring removal →
sinogram filter → FBP → saver.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import InMemoryTransport, PluginRunner
from repro.tomo import standard_chain


def main():
    chain = standard_chain(n_det=64, n_angles=96, n_rows=2, ring=True)
    runner = PluginRunner(chain, InMemoryTransport(), output_dir="out")
    datasets = runner.run()

    recon = np.asarray(datasets["recon"].materialise())
    truth = next(d.metadata["truth"] for d in runner.lineage
                 if d.metadata.get("truth") is not None)
    sl = slice(8, -8)
    corr = np.corrcoef(truth[:, sl, sl].ravel(),
                       recon[:, sl, sl].ravel())[0, 1]
    print(f"reconstructed volume: {recon.shape}, "
          f"corr vs phantom = {corr:.3f}")
    print()
    print(runner.profiler.report())
    print("\nmanifest + intermediates described in out/savu_manifest.nxs.json")


if __name__ == "__main__":
    main()
