"""Unit tests for the telemetry substrate (``repro.obs``): the trace
model (span identity, parent links, merge dedup, the shipping protocol),
the metrics registry (counters/gauges/reservoir histograms and the
Prometheus exposition), the span-backed Profiler's back-compat surface,
and the health plane — the structured event log (ring + cursor), the
SLO rule engine's alert lifecycle (deterministic via explicit clocks),
the OTLP export bridge (1:1 span mapping, metric shapes, the spool),
and the registry↔CATALOGUE completeness guard.  Quantile math gets a
hypothesis property test when hypothesis is installed."""
import math
import os
import re
import threading
import time

import pytest

from repro.core.profiler import Profiler
from repro.obs import (CATALOGUE, Counter, EventLog, Gauge, Histogram,
                       MetricsRegistry, OtlpSpool, SloEngine, SloRule,
                       Span, Trace, catalogue_names, current_trace,
                       default_rules, iter_spans, metrics_to_otlp,
                       prometheus_name, register_catalogue, render_gantt,
                       rules_from_spec, trace_to_otlp, use_trace)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ============================================================== tracing
def test_span_wire_roundtrip():
    s = Span("plugin.fbp.process", 10.0, 11.5, worker_id="w0",
             parent_id="abc", attrs={"phase": "process", "gang": 2})
    back = Span.from_wire(s.to_wire())
    assert back.name == s.name and back.span_id == s.span_id
    assert back.start == 10.0 and back.end == 11.5
    assert back.worker_id == "w0" and back.parent_id == "abc"
    assert back.attrs == s.attrs


def test_span_context_manager_nests_parent_links():
    tr = Trace("t1", worker_id="w0")
    with tr.span("attempt", attempt=1) as outer:
        with tr.span("plugin.fbp.process") as inner:
            pass
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.end is not None and inner.end is not None
    assert all(s.worker_id == "w0" for s in tr.spans())


def test_span_error_attr_on_exception():
    tr = Trace()
    with pytest.raises(RuntimeError):
        with tr.span("attempt"):
            raise RuntimeError("boom")
    (s,) = tr.spans()
    assert s.attrs["error"] == "RuntimeError" and s.end is not None


def test_record_defaults_parent_to_open_span():
    tr = Trace()
    with tr.span("plugin.fbp.process") as p:
        tr.record("compile", time.time() - 1, time.time())
    compile_span = [s for s in tr.spans() if s.name == "compile"][0]
    assert compile_span.parent_id == p.span_id


def test_merge_dedups_on_span_id_and_returns_only_new():
    tr = Trace("job-1")
    wire = [Span("lease", 1.0, 2.0, span_id="aaa").to_wire(),
            Span("plugin.x.process", 1.2, 1.8, span_id="bbb").to_wire()]
    first = tr.merge(wire)
    assert [s.span_id for s in first] == ["aaa", "bbb"]
    # a redelivered heartbeat adds nothing
    assert tr.merge(wire) == []
    assert len(tr) == 2
    # malformed entries are skipped, not fatal
    assert tr.merge([{"nonsense": True}, None]) == []


def test_ship_unship_protocol():
    tr = Trace()
    tr.record("a", 1.0, 2.0)
    open_span = tr.begin("b")                # unfinished: never shipped
    batch = tr.take_unshipped()
    assert [s.name for s in batch] == ["a"]
    assert tr.take_unshipped() == []         # marked shipped
    tr.unship(batch)                         # failed send: retry later
    assert [s.name for s in tr.take_unshipped()] == ["a"]
    tr.finish(open_span)
    assert [s.name for s in tr.take_unshipped()] == ["b"]


def test_per_thread_parent_stacks_keep_traces_straight():
    tr = Trace()
    seen = {}

    def worker(tag):
        with tr.span(f"outer.{tag}") as o, tr.span(f"inner.{tag}") as i:
            seen[tag] = (o.span_id, i.parent_id)

    ts = [threading.Thread(target=worker, args=(t,)) for t in "ab"]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for tag in "ab":
        outer_id, inner_parent = seen[tag]
        assert inner_parent == outer_id


def test_current_trace_contextvar():
    assert current_trace() is None
    tr = Trace()
    with use_trace(tr):
        assert current_trace() is tr
    assert current_trace() is None


def test_render_gantt_layout():
    spans = [Span("queue.wait", 0.0, 1.0),
             Span("plugin.fbp.process", 1.0, 3.0, worker_id="w1")]
    out = render_gantt(spans, width=40)
    lines = out.splitlines()
    assert "timeline" in lines[0] and "3.000s total" in lines[0]
    assert lines[1].startswith("queue.wait")
    assert "w1" in lines[2] and "#" in lines[2]
    assert render_gantt([]) == "(no spans)"


# ======================================================= profiler bridge
def test_profiler_is_span_backed():
    tr = Trace("job-9", worker_id="w3")
    prof = Profiler(trace=tr)
    prof.record("fbp", "process", 1.0, 3.0, devices=2, flops=1e9)
    with prof.timer("fbp", "post", 1):
        pass
    names = [s.name for s in tr.spans()]
    assert "plugin.fbp.process" in names and "plugin.fbp.post" in names
    evs = prof.events
    assert {e.phase for e in evs} == {"process", "post"}
    proc = [e for e in evs if e.phase == "process"][0]
    assert proc.devices == 2 and proc.flops == 1e9 and proc.wall == 2.0
    assert "profile" in prof.report()


def test_profiler_default_trace_standalone():
    prof = Profiler()                        # no trace given: owns one
    prof.record("x", "process", 0.0, 1.0)
    assert len(prof.events) == 1
    tot = prof.totals()
    assert tot["x"] == pytest.approx(1.0)


# ============================================================== metrics
def test_counter_monotonic():
    c = Counter("jobs.completed")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_callback_and_error_isolation():
    g = Gauge("queue.depth", fn=lambda: 7)
    assert g.value == 7.0
    g2 = Gauge("bad")
    g2.set(3)
    assert g2.value == 3.0
    g2.set_function(lambda: 1 / 0)           # scrape must not raise
    assert math.isnan(g2.value)


def test_histogram_exact_count_sum_and_quantiles():
    h = Histogram("lat", reservoir_size=100)
    for v in range(100):
        h.observe(v)
    assert h.count == 100 and h.sum == pytest.approx(4950.0)
    assert h.quantile(0.0) == 0
    assert h.quantile(1.0) == 99
    assert h.quantile(0.5) == 50
    with pytest.raises(ValueError):
        h.quantile(1.5)
    assert Histogram("empty").quantile(0.5) is None


def test_histogram_reservoir_bounds_memory():
    h = Histogram("lat", reservoir_size=64, seed=1)
    for v in range(10_000):
        h.observe(float(v))
    assert len(h._reservoir) == 64
    assert h.count == 10_000
    # the sample stays representative: median of U[0, 10k) within 25%
    assert 2_500 <= h.quantile(0.5) <= 7_500


def test_histogram_quantile_properties_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                              width=32),
                    min_size=1, max_size=200),
           st.floats(min_value=0.0, max_value=1.0))
    def prop(values, q):
        h = Histogram("x", reservoir_size=1000)
        for v in values:
            h.observe(v)
        got = h.quantile(q)
        # every quantile is an actual observation, bracketed by min/max,
        # and monotone in q
        assert got in [float(v) for v in values]
        assert min(values) <= got <= max(values)
        assert h.quantile(0.0) == min(values)
        assert h.quantile(1.0) == max(values)
        qs = [h.quantile(x) for x in (0.0, 0.25, 0.5, 0.75, 1.0)]
        assert qs == sorted(qs)

    prop()


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    c1 = reg.counter("jobs.completed")
    assert reg.counter("jobs.completed") is c1
    with pytest.raises(ValueError):
        reg.gauge("jobs.completed")
    reg.histogram("job.latency.e2e").observe(1.0)
    snap = reg.snapshot()
    assert snap["jobs.completed"] == 0
    assert snap["job.latency.e2e"]["count"] == 1
    assert snap["job.latency.e2e"]["p50"] == 1.0


def test_prometheus_rendering_format():
    reg = MetricsRegistry()
    reg.counter("jobs.completed", help="done jobs").inc(3)
    reg.gauge("queue.depth").set(2)
    h = reg.histogram("job.latency.e2e")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    text = reg.render_prometheus()
    assert "# HELP jobs_completed done jobs" in text
    assert "# TYPE jobs_completed counter" in text
    assert "jobs_completed 3" in text
    assert "queue_depth 2" in text
    assert "# TYPE job_latency_e2e summary" in text
    assert 'job_latency_e2e{quantile="0.5"} 0.2' in text
    assert "job_latency_e2e_count 3" in text
    assert text.endswith("\n")
    # every line is a comment or `name[{labels}] value`
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name and not name[0].isdigit()
        float(value)


def test_prometheus_name_sanitisation():
    assert prometheus_name("job.latency.e2e") == "job_latency_e2e"
    assert prometheus_name("plugin.wall.fbp-recon") == "plugin_wall_fbp_recon"
    assert prometheus_name("9lives") == "_9lives"


def test_catalogue_registers_every_name():
    reg = MetricsRegistry()
    register_catalogue(reg)
    assert set(catalogue_names()) <= set(reg.names())
    assert len(CATALOGUE) == len(set(catalogue_names()))
    text = reg.render_prometheus()
    for name in catalogue_names():
        assert prometheus_name(name) in text
    register_catalogue(reg)                  # idempotent


# ==================================================== completeness guard
#: per-plugin metrics minted from plugin names at runtime — the only
#: names allowed to live outside the CATALOGUE
DYNAMIC_METRIC_PREFIXES = ("plugin.wall.", "plugin.flops.")
_METRIC_CALL_RE = re.compile(
    r"""\.(counter|gauge|histogram)\(\s*["']([^"']+)["']""")


def _scan_metric_literals() -> dict[str, set[tuple[str, str]]]:
    """Every literal ``.counter("x") / .gauge("x") / .histogram("x")``
    in ``src/repro`` -> {name: {(kind, relpath), ...}}."""
    src = os.path.join(REPO_ROOT, "src", "repro")
    found: dict[str, set[tuple[str, str]]] = {}
    for root, _, files in os.walk(src):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(root, fname)
            with open(path) as fh:
                text = fh.read()
            rel = os.path.relpath(path, REPO_ROOT)
            for kind, name in _METRIC_CALL_RE.findall(text):
                found.setdefault(name, set()).add((kind, rel))
    return found


def test_every_created_metric_is_catalogued_and_vice_versa():
    """The CATALOGUE is the single source of truth: any metric name a
    service module creates must be pre-registered there (so /metrics is
    complete from the first scrape), and every catalogued name must
    actually be produced somewhere (no dead documentation)."""
    used = _scan_metric_literals()
    cat = {name: kind for name, kind, _ in CATALOGUE}
    dynamic = {n for n in used
               if n.startswith(DYNAMIC_METRIC_PREFIXES)}
    uncatalogued = set(used) - set(cat) - dynamic
    assert not uncatalogued, (
        f"metric names created in src/repro but missing from "
        f"CATALOGUE: { {n: sorted(used[n]) for n in uncatalogued} }")
    unused = set(cat) - set(used)
    assert not unused, (f"CATALOGUE names never created anywhere in "
                        f"src/repro: {sorted(unused)}")
    # and the creation kind agrees with the catalogued kind — a
    # mismatch would raise at runtime on the first conflicting create
    for name, sites in used.items():
        if name in cat:
            kinds = {k for k, _ in sites}
            assert kinds == {cat[name]}, (name, sorted(sites))


# ============================================================ event log
def test_eventlog_emit_since_and_cursor():
    log = EventLog(max_events=16)
    assert log.head == 0 and len(log) == 0
    log.emit("job.submit", trace_id="t1", job_id="j1", priority=5)
    log.emit("job.lease", trace_id="t1", job_id="j1", worker_id="w0")
    page = log.since(0)
    assert [e["event"] for e in page["events"]] == ["job.submit",
                                                   "job.lease"]
    assert page["cursor"] == 2 and page["dropped"] == 0
    rec = page["events"][0]
    assert rec["trace_id"] == "t1" and rec["job_id"] == "j1"
    assert rec["worker_id"] == "" and rec["attrs"] == {"priority": 5}
    assert rec["seq"] == 1 and rec["ts"] <= time.time()
    # resuming from the cursor sees only what is new
    assert log.since(page["cursor"])["events"] == []
    assert log.since(page["cursor"])["cursor"] == page["cursor"]
    log.emit("job.complete", trace_id="t1", job_id="j1")
    nxt = log.since(page["cursor"])
    assert [e["event"] for e in nxt["events"]] == ["job.complete"]
    assert nxt["cursor"] == 3 and log.head == 3


def test_eventlog_ring_reports_dropped_gap():
    log = EventLog(max_events=4)
    for i in range(10):
        log.emit("e", trace_id=f"t{i}")
    page = log.since(0)                  # seqs 7..10 retained
    assert [e["seq"] for e in page["events"]] == [7, 8, 9, 10]
    assert page["dropped"] == 6          # 1..6 fell off unseen
    # a reader who already saw seq 8 lost nothing
    assert log.since(8)["dropped"] == 0
    assert [e["seq"] for e in log.since(8)["events"]] == [9, 10]


def test_eventlog_limit_and_validation():
    log = EventLog(max_events=8)
    for _ in range(5):
        log.emit("e")
    page = log.since(0, limit=2)
    assert [e["seq"] for e in page["events"]] == [1, 2]
    assert page["cursor"] == 2           # paging resumes mid-ring
    with pytest.raises(ValueError):
        log.since(-1)
    with pytest.raises(ValueError):
        EventLog(max_events=0)


# =========================================================== SLO engine
def test_slo_gauge_rule_full_lifecycle_with_holddowns():
    """ok -> pending -> (for_s held) firing -> (resolve_s held) ok,
    with exactly one event per lifecycle transition."""
    reg = MetricsRegistry()
    log = EventLog()
    eng = SloEngine(reg, events=log)
    g = reg.gauge("queue.oldest_age_s")
    g.set(200.0)                         # rule: > 120 for 5s
    assert eng.evaluate(now=1000.0) == ["alert.pending"]
    assert eng.evaluate(now=1004.0) == []        # hold-down not met
    assert eng.evaluate(now=1005.0) == ["alert.firing"]
    assert eng.n_firing() == 1
    snap = eng.snapshot()
    (rule,) = [r for r in snap["rules"]
               if r["name"] == "queue-oldest-age"]
    assert rule["state"] == "firing" and rule["value"] == 200.0
    assert snap["firing"] == ["queue-oldest-age"]
    assert snap["critical_firing"] == []         # not a critical rule
    g.set(10.0)                          # clears; resolve_s=5 holds
    assert eng.evaluate(now=1006.0) == []
    assert eng.evaluate(now=1010.9) == []
    assert eng.evaluate(now=1011.0) == ["alert.resolved"]
    assert eng.n_firing() == 0
    names = [e["event"] for e in log.since(0)["events"]]
    assert names == ["alert.pending", "alert.firing", "alert.resolved"]
    # every alert record joins the common schema via the engine's trace
    for e in log.since(0)["events"]:
        assert e["trace_id"] == eng.trace_id
        assert e["attrs"]["rule"] == "queue-oldest-age"
    assert reg.counter("alerts.fired").value == 1
    assert reg.counter("alerts.resolved").value == 1
    (rule,) = [r for r in eng.snapshot()["rules"]
               if r["name"] == "queue-oldest-age"]
    assert rule["fired"] == 1 and rule["resolved"] == 1


def test_slo_pending_that_never_fires_folds_back_silently():
    reg = MetricsRegistry()
    log = EventLog()
    eng = SloEngine(reg, events=log)
    g = reg.gauge("queue.oldest_age_s")
    g.set(500.0)
    assert eng.evaluate(now=0.0) == ["alert.pending"]
    g.set(0.0)                           # clear before for_s elapsed
    assert eng.evaluate(now=1.0) == []
    assert eng.n_firing() == 0
    assert [e["event"] for e in log.since(0)["events"]] == \
        ["alert.pending"]                # no firing, no resolved
    assert reg.counter("alerts.fired").value == 0


def test_slo_rate_rule_fires_on_counter_increase_and_resolves():
    """kind="rate" reads the counter's increase over window_s: a lease
    expiry fires the critical rule immediately (for_s=0) and the rule
    resolves once the window slides past the increase."""
    reg = MetricsRegistry()
    log = EventLog()
    eng = SloEngine(reg, events=log)
    c = reg.counter("lease.expired")
    assert eng.evaluate(now=0.0) == []           # increase of 0
    c.inc()
    assert eng.evaluate(now=1.0) == ["alert.pending", "alert.firing"]
    (detail,) = eng.critical_firing()
    assert detail["name"] == "lease-expiry-rate"
    assert detail["value"] == 1.0
    # inside the 30s window the rule stays firing...
    assert eng.evaluate(now=20.0) == []
    assert eng.n_firing() == 1
    # ...and resolves once the window slides past the expiry
    assert eng.evaluate(now=32.0) == ["alert.resolved"]
    assert eng.critical_firing() == [] and eng.n_firing() == 0


def test_slo_quantile_rule_ignores_empty_histogram():
    reg = MetricsRegistry()
    eng = SloEngine(reg)
    reg.histogram("job.latency.e2e")     # empty: quantile() is None
    assert eng.evaluate(now=0.0) == []
    for _ in range(3):
        reg.histogram("job.latency.e2e").observe(400.0)  # p99 > 300
    assert eng.evaluate(now=1.0) == ["alert.pending"]
    assert eng.evaluate(now=6.0) == ["alert.firing"]     # for_s=5


def test_slo_missing_metric_never_breaches():
    eng = SloEngine(MetricsRegistry())   # registry has no metrics at all
    assert eng.evaluate(now=0.0) == []
    assert all(r["state"] == "ok" and r["value"] is None
               for r in eng.snapshot()["rules"])


def test_rules_from_spec_patch_add_disable():
    names = {r.name for r in default_rules()}
    assert names == {"queue-oldest-age", "job-latency-p99",
                     "lease-expiry-rate", "ingest-lag",
                     "executable-rejects"}
    rules = rules_from_spec({
        "lease-expiry-rate": {"window_s": 5.0},          # patch
        "my-depth": {"metric": "queue.depth",            # add
                     "threshold": 50.0, "critical": True},
        "ingest-lag": None,                              # disable
    })
    by_name = {r.name: r for r in rules}
    assert by_name["lease-expiry-rate"].window_s == 5.0
    assert by_name["lease-expiry-rate"].critical is True  # kept
    assert by_name["my-depth"].metric == "queue.depth"
    assert by_name["my-depth"].critical is True
    assert "ingest-lag" not in by_name
    assert len(rules) == 5


def test_rules_from_spec_rejects_bad_specs():
    with pytest.raises(ValueError):
        rules_from_spec({"queue-oldest-age": {"nope": 1}})
    with pytest.raises(ValueError):
        rules_from_spec({"queue-oldest-age": 42})
    with pytest.raises(ValueError):
        rules_from_spec({"new-rule": {"metric": "queue.depth"}})
    with pytest.raises(ValueError):
        SloRule("x", "m", 1.0, kind="nope")
    with pytest.raises(ValueError):
        SloRule("x", "m", 1.0, op=">=")


# ========================================================== OTLP export
def test_trace_to_otlp_maps_spans_one_to_one():
    s1 = Span("queue.wait", 1.0, 2.0, span_id="aaa1")
    s2 = Span("plugin.fbp.process", 2.0, 3.5, span_id="bbb2",
              parent_id="aaa1", worker_id="w0",
              attrs={"flops": 1e9, "gang": 2, "ok": True, "tag": "x"})
    doc = {"trace_id": "deadbeefdeadbeef",
           "spans": [s1.to_wire(), s2.to_wire()]}
    otlp = trace_to_otlp(doc, {"job.id": "j1"})
    spans = list(iter_spans(otlp))
    assert len(spans) == 2                       # 1:1, nothing dropped
    for s in spans:
        assert len(s["traceId"]) == 32
        assert s["traceId"].endswith("deadbeefdeadbeef")
        assert len(s["spanId"]) == 16
    proc = {s["name"]: s for s in spans}
    assert proc["queue.wait"]["spanId"] == "aaa1".rjust(16, "0")
    assert proc["plugin.fbp.process"]["parentSpanId"] == \
        "aaa1".rjust(16, "0")
    assert proc["plugin.fbp.process"]["startTimeUnixNano"] == \
        str(int(2.0e9))
    attrs = {a["key"]: a["value"]
             for a in proc["plugin.fbp.process"]["attributes"]}
    assert attrs["flops"] == {"doubleValue": 1e9}
    assert attrs["gang"] == {"intValue": "2"}
    assert attrs["ok"] == {"boolValue": True}
    assert attrs["tag"] == {"stringValue": "x"}
    # grouped per recording process; broker-side spans -> "broker"
    procs = []
    for rs in otlp["resourceSpans"]:
        res = {a["key"]: a["value"] for a in rs["resource"]["attributes"]}
        assert res["service.name"] == {"stringValue": "repro.pipeline"}
        assert res["job.id"] == {"stringValue": "j1"}
        procs.append(res["service.instance.id"]["stringValue"])
    assert procs == ["broker", "w0"]


def test_trace_to_otlp_accepts_live_trace_and_open_spans():
    tr = Trace("job-7", worker_id="w1")
    with tr.span("attempt", attempt=1):
        tr.record("compile", 1.0, 2.0)
    open_span = tr.begin("lease")                # never finished
    otlp = trace_to_otlp(tr)
    spans = list(iter_spans(otlp))
    assert len(spans) == len(tr.spans()) == 3
    (lease,) = [s for s in spans if s["name"] == "lease"]
    # OTLP has no "open": an unfinished span exports end == start
    assert lease["endTimeUnixNano"] == lease["startTimeUnixNano"]
    tr.finish(open_span)


def test_otlp_id_handles_non_hex_ids():
    doc = {"trace_id": "not hex at all!", "spans": [
        Span("a", 0.0, 1.0, span_id="zzz").to_wire()]}
    one = list(iter_spans(trace_to_otlp(doc)))[0]
    two = list(iter_spans(trace_to_otlp(doc)))[0]
    assert one["traceId"] == two["traceId"]      # deterministic
    int(one["traceId"], 16)                      # valid 32-hex
    assert len(one["traceId"]) == 32
    int(one["spanId"], 16)
    assert len(one["spanId"]) == 16


def test_metrics_to_otlp_shapes():
    snap = {"jobs.completed": 3,                 # counter -> sum
            "queue.depth": 2.5,                  # gauge
            "bad.scrape": float("nan"),          # NaN -> empty points
            "job.latency.e2e": {"count": 3, "sum": 0.6, "p50": 0.2,
                                "p95": 0.3, "p99": 0.3},
            "not_a_metric": "text", "flag": True}
    otlp = metrics_to_otlp(snap, identity="w9", now=100.0)
    (rm,) = otlp["resourceMetrics"]
    res = {a["key"]: a["value"] for a in rm["resource"]["attributes"]}
    assert res["service.instance.id"] == {"stringValue": "w9"}
    metrics = {m["name"]: m for m in rm["scopeMetrics"][0]["metrics"]}
    # strings/bools are not samples
    assert set(metrics) == {"jobs.completed", "queue.depth",
                            "bad.scrape", "job.latency.e2e"}
    ctr = metrics["jobs.completed"]["sum"]
    assert ctr["isMonotonic"] is True
    assert ctr["dataPoints"][0] == {"timeUnixNano": str(int(100e9)),
                                    "asDouble": 3.0}
    assert metrics["queue.depth"]["gauge"]["dataPoints"][0][
        "asDouble"] == 2.5
    assert metrics["bad.scrape"]["gauge"]["dataPoints"] == []
    summ = metrics["job.latency.e2e"]["summary"]["dataPoints"][0]
    assert summ["count"] == "3" and summ["sum"] == 0.6
    assert [q["quantile"] for q in summ["quantileValues"]] == \
        [0.5, 0.95, 0.99]


def test_otlp_spool_write_sanitise_evict(tmp_path):
    import json
    spool = OtlpSpool(str(tmp_path / "otlp"), max_files=2)
    tr = Trace("job-1")
    tr.record("a", 0.0, 1.0)
    p1 = spool.export_trace("job/../1 x", tr)
    assert os.path.basename(p1) == "trace-job_.._1_x.otlp.json"
    with open(p1) as fh:
        doc = json.load(fh)
    assert len(list(iter_spans(doc))) == 1
    res = {a["key"]: a["value"] for a in
           doc["resourceSpans"][0]["resource"]["attributes"]}
    assert res["job.id"] == {"stringValue": "job/../1 x"}
    # bounded: oldest (mtime) beyond max_files are evicted at put time
    p2 = spool.put("two", {"resourceSpans": []})
    os.utime(p1, (1, 1))
    os.utime(p2, (2, 2))
    p3 = spool.put("three", {"resourceSpans": []})
    assert len(spool) == 2
    assert not os.path.exists(p1)
    assert os.path.exists(p2) and os.path.exists(p3)
    with pytest.raises(ValueError):
        OtlpSpool(str(tmp_path / "x"), max_files=0)
