"""Pure-jnp oracles: (G)QA scaled-dot-product attention, plus a
chunked online-softmax variant (flash-attention dataflow expressed in
XLA: lax.scan over query blocks) whose peak memory is O(S·bq) instead
of O(S²) — the compile path for the 32k/500k sequence cells on hosts
where the Pallas kernel can't lower."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True) -> jnp.ndarray:
    """q (B, Hq, S, D); k/v (B, Hkv, S, D) with Hq % Hkv == 0.

    fp32 softmax accumulation regardless of input dtype (matches the
    kernel's accumulator precision)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 512
                    ) -> jnp.ndarray:
    """Blockwise online-softmax attention (flash dataflow in XLA).

    Scans over query blocks; each block sees the full K/V but only a
    (bq × S) score tile lives at once.  Matches mha_ref to fp32
    accumulation error.  q (B,Hq,S,D), k/v (B,Hkv,S,D).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    n_blocks = s // bq
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # fold group into batch for a single einsum pattern
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, s, d)
    q_blocks = qf.reshape(b, hkv, group, n_blocks, bq, d)
    q_blocks = jnp.moveaxis(q_blocks, 3, 0)          # (nb, b, hkv, g, bq, d)
    kpos = jnp.arange(s)

    def one_block(i, qb):
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kf)
        if causal:
            qpos = i * bq + jnp.arange(bq)
            mask = qpos[:, None] >= kpos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        m = logits.max(-1, keepdims=True)
        p = jnp.exp(logits - m)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        return out / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)

    outs = jax.lax.map(lambda args: one_block(*args),
                       (jnp.arange(n_blocks), q_blocks))
    out = jnp.moveaxis(outs, 0, 3)                   # (b,hkv,g,nb,bq,d)
    return out.reshape(b, hq, s, d).astype(q.dtype)
