"""The paper's §IV.A chunking optimiser: bounds, budget, and that it
beats the pattern-oblivious baseline on the paper's own access regime."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (DEFAULT_CACHE_BYTES, Pattern, chunks_touched,
                        naive_chunks, optimise_chunks)
from repro.core.chunking import optimise_block_shape

PROJ = Pattern("PROJECTION", core_dims=(1, 2), slice_dims=(0,))
SINO = Pattern("SINOGRAM", core_dims=(0, 2), slice_dims=(1,))


def _total_cost(shape, chunks, pattern, m=8):
    return sum(chunks_touched(shape, chunks, idx)
               for idx in pattern.frame_slices(shape, m))


def test_chunk_fits_budget_and_bounds():
    shape = (3000, 2000, 2000)
    c = optimise_chunks(shape, PROJ, SINO, itemsize=4, frames=8)
    assert np.prod(c) * 4 <= DEFAULT_CACHE_BYTES
    assert all(1 <= ci <= si for ci, si in zip(c, shape))


def test_core_core_dim_maximised():
    # dim 2 is core in both patterns -> should get the largest chunk
    c = optimise_chunks((3000, 2000, 2000), PROJ, SINO, itemsize=4,
                        frames=8)
    assert c[2] == max(c)


def test_optimised_beats_naive_on_projection_to_sinogram():
    """The paper's scenario: written as projections, read as sinograms.
    The optimiser must touch fewer chunks in total than the row-major
    baseline."""
    shape = (96, 64, 64)
    copt = optimise_chunks(shape, PROJ, SINO, itemsize=4, frames=8,
                           cache_bytes=64_000)
    cnaive = naive_chunks(shape, 4, 64_000)
    cost_opt = (_total_cost(shape, copt, PROJ) +
                _total_cost(shape, copt, SINO))
    cost_naive = (_total_cost(shape, cnaive, PROJ) +
                  _total_cost(shape, cnaive, SINO))
    assert cost_opt < cost_naive, (copt, cnaive, cost_opt, cost_naive)


@given(
    shape=st.tuples(st.integers(2, 400), st.integers(2, 400),
                    st.integers(2, 400)),
    frames=st.integers(1, 16),
    cache=st.sampled_from([10_000, 100_000, 1_000_000]),
)
@settings(max_examples=60, deadline=None)
def test_chunking_invariants(shape, frames, cache):
    """Property: any shape/frames/budget -> chunk within bounds+budget."""
    c = optimise_chunks(shape, PROJ, SINO, itemsize=4, frames=frames,
                        cache_bytes=cache)
    assert all(1 <= ci <= si for ci, si in zip(c, shape))
    assert np.prod(c) * 4 <= max(cache, 4)


def test_single_pattern_no_next():
    c = optimise_chunks((64, 32, 32), PROJ, None, itemsize=2, frames=4)
    assert all(1 <= ci for ci in c)
    assert np.prod(c) * 2 <= DEFAULT_CACHE_BYTES


def test_block_shape_hardware_alignment():
    b = optimise_block_shape((512, 512), PROJ.with_shard_axes({}),
                             None, itemsize=4)
    # minor dim multiple of 128 (or full), second-minor multiple of 8
    assert b[-1] % 128 == 0 or b[-1] == 512
    assert b[-2] % 8 == 0 or b[-2] == 512
    assert np.prod(b) * 4 <= 4 * 1024 * 1024


def test_block_shape_small_dims_not_padded():
    b = optimise_block_shape((4, 64), Pattern("P", core_dims=(1,),
                                              slice_dims=(0,)), None,
                             itemsize=4)
    assert b[0] <= 4 and b[1] <= 64
